//! Scenario: compare vLLM / INFERCEPT / LAMPS on the paper's multi-API
//! compound-AI workload (chatbots, image generation, VE agents...) at a
//! contended memory budget — a miniature of the paper's Fig 6/Fig 10
//! evaluation, runnable in seconds on the simulator.
//!
//!     cargo run --release --example augmented_serving
use lamps::bench::{improvement_pct, Dataset, ModelPreset};
use lamps::config::SystemConfig;
use lamps::core::types::Tokens;
use lamps::engine::Engine;

fn main() {
    let trace = Dataset::MultiApi.generate(250, 6.0, 7);
    println!("workload: {} multi-API requests @ {}/s (classes: math, qa, \
              ve, chatbot, image, tts)\n",
             trace.len(), trace.rate);
    println!("{:<15} {:>11} {:>11} {:>11} {:>11} {:>9} {:>7}", "system",
             "lat_mean(s)", "lat_p99(s)", "ttft_mean", "ttft_p99",
             "thr(r/s)", "preempt");
    let mut lamps_lat = 0.0;
    let mut baseline_lat = Vec::new();
    for system in ["vllm", "infercept", "lamps-no-sched", "lamps"] {
        let mut cfg = SystemConfig::preset(system).unwrap();
        cfg.cost = ModelPreset::GptJ6b.cost();
        cfg.memory_budget = Tokens(12_000);
        let report = Engine::simulated(cfg).run_trace(&trace);
        println!("{:<15} {:>11.2} {:>11.2} {:>11.2} {:>11.2} {:>9.3} \
                  {:>7}",
                 system, report.latency.mean_secs(),
                 report.latency.p99_secs(),
                 report.ttft.mean_us / 1e6, report.ttft.p99_us / 1e6,
                 report.throughput_rps, report.preemptions);
        if system == "lamps" {
            lamps_lat = report.latency.mean_us;
        } else {
            baseline_lat.push((system, report.latency.mean_us));
        }
    }
    println!();
    for (system, lat) in baseline_lat {
        println!("LAMPS vs {:<13}: {:+.1}% mean latency", system,
                 improvement_pct(lamps_lat, lat));
    }
}
