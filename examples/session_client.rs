//! External-tool serving round-trip — the protocol v2 "hello world".
//!
//! Spins up a simulated-backend server with `--api-source external`
//! semantics, serves the JSON-lines wire protocol on a local TCP port,
//! and then plays the client side end to end: open a session with one
//! API call, stream event frames until `api_call_started`, run the
//! "tool" (a sleep standing in for the real calculator), post the
//! `tool_result`, and stream to the `finished` frame.
//!
//! The printed transcript is the same NDJSON exchange documented in
//! `examples/protocol_v2.ndjson`. Run with:
//!
//! ```sh
//! cargo run --example session_client
//! ```

use std::borrow::Cow;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use lamps::config::{ApiSourceKind, CostModel, SystemConfig};
use lamps::core::request::ApiType;
use lamps::core::types::Micros;
use lamps::server;
use lamps::util::json;
use lamps::wire::{CallFrame, RequestFrame, ToolResultFrame};

fn main() -> anyhow::Result<()> {
    // A fast cost model so the demo finishes in milliseconds of model
    // time; API waits are real wall time either way.
    let mut cfg = SystemConfig::preset("lamps")
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    cfg.cost = CostModel {
        decode_base: Micros(200),
        decode_per_ctx_token_us: 0.0,
        prefill_per_token_us: 5.0,
        swap_base_us: 0.0,
        swap_per_token_us: 0.0,
        rank_overhead_per_request_us: 0.0,
    };
    cfg.api_source = ApiSourceKind::External;
    let (handle, _join) = server::spawn_sim(cfg);

    let addr = "127.0.0.1:17093";
    let server_handle = handle.clone();
    std::thread::spawn(move || {
        let _ = server::serve_tcp(server_handle, addr);
    });

    // Wait for the listener.
    let mut stream = None;
    for _ in 0..100 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let stream =
        stream.ok_or_else(|| anyhow::anyhow!("server did not come up"))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    // The typed client-side constructor emits the same canonical line
    // documented in examples/protocol_v2.ndjson.
    let request = RequestFrame {
        prompt: Cow::Borrowed("what is 6 times 7?"),
        api_calls: vec![CallFrame {
            decode_before: 2,
            api_ms: None,
            api_type: ApiType::Math,
            response_tokens: 2,
        }],
        output_tokens: 4,
    }
    .to_line();
    println!("-> {request}");
    writer.write_all(request.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;

    let mut line = String::new();
    let mut session_id = None;
    let mut finished = false;
    while !finished {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server closed the connection early");
        }
        print!("<- {line}");
        let frame = json::parse(&line)?;
        match frame.str_field("type")?.as_str() {
            "queued" => session_id = Some(frame.u64_field("id")?),
            "api_call_started" => {
                let id = session_id
                    .ok_or_else(|| anyhow::anyhow!("no session id"))?;
                let index = frame.u64_field("index")?;
                // "Run the tool" — the whole point: the server cannot
                // know when (or with how many tokens) this returns.
                std::thread::sleep(Duration::from_millis(25));
                let result = ToolResultFrame {
                    id,
                    index,
                    response_tokens: 2,
                }
                .to_line();
                println!("-> {result}");
                writer.write_all(result.as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
            }
            "finished" => {
                assert_eq!(frame.u64_field("tokens_decoded")?, 6,
                           "2 pre-API + 4 final decode tokens");
                finished = true;
            }
            "dropped" | "error" => {
                anyhow::bail!("unexpected frame: {line}");
            }
            _ => {}
        }
    }
    handle.shutdown();
    println!("ok: external tool call served end to end");
    Ok(())
}
