//! Scenario: the §4.4 starvation-prevention mechanism in action — sweep
//! the promotion threshold on an overloaded multi-API mix and watch the
//! P99 tail collapse while throughput holds (the paper's Fig 9).
//!
//!     cargo run --release --example starvation_demo
use lamps::bench::{Dataset, ModelPreset};
use lamps::config::SystemConfig;
use lamps::core::types::Tokens;
use lamps::engine::Engine;

fn main() {
    let trace = Dataset::MultiApi.generate(250, 8.0, 3);
    println!("overloaded: {} requests @ {}/s, 12k-token KV budget\n",
             trace.len(), trace.rate);
    println!("{:>10} {:>12} {:>12} {:>12} {:>10}", "threshold",
             "lat_mean(s)", "lat_p99(s)", "ttft_p99(s)", "thr(r/s)");
    for (label, threshold) in [("5", Some(5)), ("50", Some(50)),
                               ("100", Some(100)), ("500", Some(500)),
                               ("off", None)] {
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        cfg.cost = ModelPreset::GptJ6b.cost();
        cfg.memory_budget = Tokens(12_000);
        cfg.starvation_threshold = threshold;
        let r = Engine::simulated(cfg).run_trace(&trace);
        println!("{:>10} {:>12.2} {:>12.2} {:>12.2} {:>10.3}", label,
                 r.latency.mean_secs(), r.latency.p99_secs(),
                 r.ttft.p99_us / 1e6, r.throughput_rps);
    }
    println!("\npaper §4.4: threshold 100 balances tail latency against \
              throughput.");
}
