//! Scenario: ToolBench-style serving with the REAL AOT predictor in the
//! scheduling loop — prompts are tokenized and classified through the
//! exported OPT-125M-stand-in HLO on every admission (the paper's §5
//! deployment), while serving itself runs on the fast simulator.
//! Compares prediction-driven LAMPS against the complete-information
//! oracle.
//!
//!     make artifacts && cargo run --release --example toolbench_trace
use lamps::bench::{Dataset, ModelPreset};
use lamps::config::{PredictorKind, SystemConfig};
use lamps::core::types::Tokens;
use lamps::engine::backend::SimBackend;
use lamps::engine::clock::Clock;
use lamps::engine::Engine;
use lamps::predictor::opt_classifier::PjrtPredictor;
use lamps::runtime::{ArtifactMeta, PredictorRuntime, RuntimeClient};

fn main() -> anyhow::Result<()> {
    let trace = Dataset::ToolBench.generate(200, 4.0, 11);
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = ModelPreset::GptJ6b.cost();
    cfg.memory_budget = Tokens(12_000);
    cfg.score_update_interval = 10; // paper §5: interval 10 on ToolBench

    // Oracle (complete information) run.
    let mut oracle_cfg = cfg.clone();
    oracle_cfg.predictor = PredictorKind::Oracle;
    let oracle = Engine::simulated(oracle_cfg).run_trace(&trace);

    // Real-predictor run: prompt -> FNV tokenizer -> HLO classifier.
    let meta = ArtifactMeta::load_default()?;
    let client = RuntimeClient::cpu()?;
    let pred = PredictorRuntime::load(&client, &meta)?;
    println!("predictor: {} bins x {} tokens (python val: acc5 {:.3}, \
              acc15 {:.3})",
             pred.meta.num_bins, pred.meta.bin_width, pred.meta.acc5,
             pred.meta.acc15);
    let mut engine = Engine::new(cfg.clone(),
                                 Box::new(SimBackend::new(cfg.cost)),
                                 Box::new(PjrtPredictor::new(pred)),
                                 Clock::virtual_clock());
    let predicted = engine.run_trace(&trace);

    println!("\n{:<22} {:>11} {:>11} {:>11} {:>9}", "predictor",
             "lat_mean(s)", "lat_p99(s)", "ttft_mean", "thr(r/s)");
    for (name, r) in [("oracle", &oracle), ("pjrt classifier",
                                            &predicted)] {
        println!("{:<22} {:>11.2} {:>11.2} {:>11.2} {:>9.3}", name,
                 r.latency.mean_secs(), r.latency.p99_secs(),
                 r.ttft.mean_us / 1e6, r.throughput_rps);
    }
    let gap = (predicted.latency.mean_us - oracle.latency.mean_us)
        / oracle.latency.mean_us * 100.0;
    println!("\nprediction cost vs complete information: {gap:+.1}% mean \
              latency (paper §6.4: small as long as predictions are \
              reasonably accurate)");
    Ok(())
}
