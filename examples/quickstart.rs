//! Quickstart: serve three augmented requests end-to-end on the REAL
//! model — PJRT prefill/decode of the AOT-compiled TinyGPT, the PJRT
//! length predictor feeding the LAMPS scheduler, simulated external API
//! calls, wall-clock latencies.
//!
//!     make artifacts && cargo run --release --example quickstart
use lamps::config::SystemConfig;
use lamps::core::request::{ApiCallSpec, ApiType, RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::clock::Clock;
use lamps::engine::pjrt_backend::PjrtBackend;
use lamps::engine::Engine;
use lamps::predictor::opt_classifier::PjrtPredictor;
use lamps::runtime::{ArtifactMeta, ModelRuntime, PredictorRuntime,
                     RuntimeClient};

fn main() -> anyhow::Result<()> {
    let meta = ArtifactMeta::load_default()?;
    let client = RuntimeClient::cpu()?;
    println!("PJRT platform: {} | model: gptj-tiny", client.platform());
    let model = ModelRuntime::load(&client, &meta, "gptj-tiny")?;
    let predictor = PredictorRuntime::load(&client, &meta)?;
    let batch = model.meta.batch;
    let max_seq = model.meta.max_seq;

    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.memory_budget = Tokens((batch * max_seq) as u64);
    cfg.max_batch = batch;
    cfg.block_size = 16;

    let mut engine = Engine::new(cfg, Box::new(PjrtBackend::new(model)),
                                 Box::new(PjrtPredictor::new(predictor)),
                                 Clock::wall_clock());

    let prompts = [
        ("call the weather api with a brief answer scale n2 today", 60),
        ("call the code api with a verbose answer scale n40 please", 15),
        ("call the search api with a medium answer scale n20 now", 120),
    ];
    for (i, (prompt, api_ms)) in prompts.iter().enumerate() {
        engine.submit(RequestSpec {
            id: RequestId(i as u64),
            arrival: engine.now(),
            prompt: prompt.to_string(),
            prompt_tokens: Tokens(
                lamps::util::tokenizer::valid_len(prompt, 64) as u64),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(6),
                api_type: ApiType::Tool(0),
                duration: Micros(api_ms * 1000),
                response_tokens: Tokens(3),
            }],
            final_decode: Tokens(8),
        });
    }
    engine.run_until_idle(None);

    let backend = engine
        .backend_any()
        .unwrap()
        .downcast_ref::<PjrtBackend>()
        .unwrap();
    for (i, (prompt, _)) in prompts.iter().enumerate() {
        let id = RequestId(i as u64);
        let r = engine.request(id).unwrap();
        println!("\nr{i}: \"{}\"", &prompt[..34.min(prompt.len())]);
        println!("  handling: {:?} | latency {:.1} ms | ttft {:.1} ms",
                 r.handling.first().map(|h| h.label()),
                 (r.finished_at.unwrap() - r.spec.arrival).0 as f64
                     / 1e3,
                 r.first_token_at
                     .map(|t| (t - r.spec.arrival).0 as f64 / 1e3)
                     .unwrap_or(0.0));
        println!("  generated tokens: {:?}",
                 backend.generated_tokens(id).unwrap());
    }
    let report = engine.metrics.report();
    println!("\ncompleted {}/{} | decoded {} real tokens",
             report.completed, report.submitted, report.tokens_decoded);
    Ok(())
}
