# Make `compile.*` importable when pytest runs from the repo root
# (python/tests expect cwd=python/; CI and the capture command run from /).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
