"""AOT compile path: lower the L2 graphs to HLO **text** artifacts.

Run once via ``make artifacts``; the Rust runtime
(``rust/src/runtime/``) loads these with ``HloModuleProto::from_text_file``
and executes them on the PJRT CPU client. Python never runs on the request
path.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts (per model preset):
  artifacts/<preset>_prefill.hlo.txt   (tokens, lengths) -> (next, k, v)
  artifacts/<preset>_decode.hlo.txt    (token, pos, k, v) -> (next, k, v)
  artifacts/predictor.hlo.txt          (tokens) -> (bin,)
  artifacts/meta.json                  shapes + config for the Rust side
  artifacts/predictor_stats.json       Table 3 accuracy metrics
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as model_mod
from compile import predictor as predictor_mod
from compile import tokenizer as tok


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big constants as ``{...}``, which the Rust-side text parser
    silently reads back as zeros — i.e. the baked model weights vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(print_large_constants=True)


def export_model(preset: str, out_dir: str, seed: int = 0) -> dict:
    """Bake weights and lower prefill/decode for one model preset."""
    cfg = model_mod.PRESETS[preset]
    params = model_mod.init_params(jax.random.PRNGKey(seed), cfg)
    B, S = cfg.batch, cfg.max_seq
    L, H, D = cfg.n_layers, cfg.n_heads, cfg.head_dim

    # Weights are closed over -> baked into the HLO as constants; only
    # dynamic state crosses the Rust boundary.
    def prefill_fn(tokens, lengths):
        return model_mod.prefill_greedy(params, cfg, tokens, lengths)

    def decode_fn(token, pos, k_cache, v_cache):
        return model_mod.decode_step_greedy(params, cfg, token, pos,
                                            k_cache, v_cache)

    tokens_spec = jax.ShapeDtypeStruct((B, S), jnp.int32)
    vec_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    kv_spec = jax.ShapeDtypeStruct((L, B, S, H, D), jnp.float32)

    t0 = time.time()
    prefill_hlo = to_hlo_text(jax.jit(prefill_fn).lower(tokens_spec,
                                                        vec_spec))
    decode_hlo = to_hlo_text(jax.jit(decode_fn).lower(vec_spec, vec_spec,
                                                      kv_spec, kv_spec))
    elapsed = time.time() - t0

    pf = os.path.join(out_dir, f"{preset}_prefill.hlo.txt")
    df = os.path.join(out_dir, f"{preset}_decode.hlo.txt")
    with open(pf, "w") as f:
        f.write(prefill_hlo)
    with open(df, "w") as f:
        f.write(decode_hlo)
    print(f"[aot] {preset}: prefill {len(prefill_hlo)//1024} KiB, "
          f"decode {len(decode_hlo)//1024} KiB (lowered in {elapsed:.1f}s)")

    return {
        "name": cfg.name,
        "vocab_size": cfg.vocab_size,
        "n_layers": L,
        "n_heads": H,
        "head_dim": D,
        "d_model": cfg.d_model,
        "max_seq": S,
        "batch": B,
        "kv_bytes_per_token": cfg.kv_bytes_per_token,
        "prefill_hlo": os.path.basename(pf),
        "decode_hlo": os.path.basename(df),
        "eos_id": tok.EOS_ID,
    }


def export_predictor(out_dir: str, seed: int = 0, *, steps: int = 3000
                     ) -> dict:
    cfg = predictor_mod.PredictorConfig()
    t0 = time.time()
    params, stats = predictor_mod.train(cfg, steps=steps, seed=seed)
    print(f"[aot] predictor trained in {time.time() - t0:.1f}s: "
          f"acc5={stats['acc5']:.3f} acc15={stats['acc15']:.3f} "
          f"mae={stats['mae_words']:.2f} words")

    def predict_fn(tokens):
        return (predictor_mod.predict_bin(params, tokens),)

    spec = jax.ShapeDtypeStruct((1, cfg.max_prompt), jnp.int32)
    hlo = to_hlo_text(jax.jit(predict_fn).lower(spec))
    path = os.path.join(out_dir, "predictor.hlo.txt")
    with open(path, "w") as f:
        f.write(hlo)

    with open(os.path.join(out_dir, "predictor_stats.json"), "w") as f:
        json.dump(stats, f, indent=2)

    return {
        "predictor_hlo": os.path.basename(path),
        "max_prompt": cfg.max_prompt,
        "num_bins": cfg.num_bins,
        "bin_width": cfg.bin_width,
        "vocab_size": cfg.vocab_size,
        "acc5": stats["acc5"],
        "acc15": stats["acc15"],
        "mae_words": stats["mae_words"],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/meta.json",
                    help="path of the meta.json to write; artifacts land "
                         "in its directory")
    ap.add_argument("--presets", default="gptj-tiny,vicuna-tiny")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--predictor-steps", type=int, default=3000)
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    meta = {"format": "hlo-text", "models": {}, "tokenizer": {
        "vocab_size": tok.VOCAB_SIZE, "pad_id": tok.PAD_ID,
        "bos_id": tok.BOS_ID, "eos_id": tok.EOS_ID,
        "reserved": tok.RESERVED, "scheme": "fnv1a64-word-hash",
    }}
    for preset in args.presets.split(","):
        meta["models"][preset] = export_model(preset, out_dir,
                                              seed=args.seed)
    meta["predictor"] = export_predictor(out_dir, seed=args.seed,
                                         steps=args.predictor_steps)

    with open(args.out, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] wrote {args.out}")


if __name__ == "__main__":
    main()
