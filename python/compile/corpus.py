"""Synthetic ToolBench-like corpus for training/evaluating the length
predictor (DESIGN.md §2: the real ToolBench dataset is substituted by a
generator matching its published statistics).

Each sample is a natural-language-ish tool-use prompt whose *true* pre-API
output length is a learnable function of prompt content (API category +
detail level) plus noise that grows with length — reproducing Table 3's
shape: accurate small bins, degrading accuracy for longer outputs.

`rust/src/workload/toolbench.rs` mirrors the category/detail tables so the
Rust workload generator produces in-distribution prompts for the exported
predictor.
"""

from __future__ import annotations

import dataclasses
import random
from typing import List, Tuple

# Mirrored in rust/src/workload/toolbench.rs — keep in sync.
CATEGORIES = [
    ("weather", 20.0),
    ("finance", 60.0),
    ("translate", 35.0),
    ("search", 90.0),
    ("media", 140.0),
    ("sports", 50.0),
    ("travel", 110.0),
    ("code", 180.0),
]

DETAILS = [
    ("brief", 0.0),
    ("short", 25.0),
    ("plain", 50.0),
    ("medium", 90.0),
    ("long", 150.0),
    ("verbose", 220.0),
    ("exhaustive", 300.0),
]

FILLER = (
    "please fetch the current value for my account and report it back "
    "with any relevant context from the service response today"
).split()

BIN_WIDTH = 10
NUM_BINS = 50


@dataclasses.dataclass
class Sample:
    prompt: str
    length: int  # true pre-API output length in tokens

    @property
    def bin(self) -> int:
        return min(self.length // BIN_WIDTH, NUM_BINS - 1)


def gen_sample(rng: random.Random) -> Sample:
    cat, base = rng.choice(CATEGORIES)
    det, extra = rng.choice(DETAILS)
    mean = base + extra
    noise = rng.gauss(0.0, 2.0 + 0.06 * mean)
    length = max(1, min(int(mean + noise), NUM_BINS * BIN_WIDTH - 1))
    # Real tool-use prompts carry length cues beyond the category (requested
    # item counts, field lists, ...). Model that with a quantized size-hint
    # word whose error grows with length -> reproduces Table 3's per-bin
    # accuracy decay (accurate small bins, degrading large bins).
    hint_noise = rng.gauss(0.0, 1.0 + 0.02 * length)
    hint = max(0, int((length + hint_noise) / 8))
    n_fill = rng.randint(3, 10)
    fill = " ".join(rng.choice(FILLER) for _ in range(n_fill))
    prompt = (f"call the {cat} api with a {det} answer scale n{hint} {fill}")
    return Sample(prompt=prompt, length=length)


def gen_corpus(n: int, seed: int = 0) -> List[Sample]:
    rng = random.Random(seed)
    return [gen_sample(rng) for _ in range(n)]


def train_val_split(samples: List[Sample], frac: float = 0.8
                    ) -> Tuple[List[Sample], List[Sample]]:
    cut = int(len(samples) * frac)
    return samples[:cut], samples[cut:]
