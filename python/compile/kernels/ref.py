"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These deliberately use the naive O(S^2) formulation so any blocking /
online-softmax bug in the kernels shows up as a numeric mismatch.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1.0e30


def decode_attention_ref(q, k, v, lengths):
    """Naive single-query attention.

    q: (B, H, D); k, v: (B, S, H, D); lengths: (B,) -> (B, H, D)
    """
    seq_len = k.shape[1]
    head_dim = q.shape[-1]
    scale = 1.0 / (head_dim ** 0.5)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # (B, H, S)
    s = jnp.einsum("bhd,bshd->bhs", qf, kf) * scale
    idx = jnp.arange(seq_len)[None, None, :]
    mask = idx < lengths[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhs,bshd->bhd", p, vf)
    # Fully-masked rows (lengths == 0) -> zeros, matching the kernel.
    any_valid = (lengths > 0)[:, None, None]
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)


def prefill_attention_ref(q, k, v, lengths):
    """Naive causal self-attention.

    q, k, v: (B, S, H, D); lengths: (B,) -> (B, S, H, D)

    Positions >= lengths[b] produce zeros (the kernel emits garbage there;
    callers must not read them — tests compare only valid positions).
    """
    seq_len = q.shape[1]
    head_dim = q.shape[-1]
    scale = 1.0 / (head_dim ** 0.5)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
    q_idx = jnp.arange(seq_len)
    k_idx = jnp.arange(seq_len)
    causal = k_idx[None, :] <= q_idx[:, None]  # (S, S)
    valid = k_idx[None, None, :] < lengths[:, None, None]  # (B, 1, S)
    mask = causal[None, None, :, :] & valid[:, :, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    row_valid = (q_idx[None, :] < lengths[:, None])[:, :, None, None]
    return jnp.where(row_valid, out, 0.0).astype(q.dtype)
