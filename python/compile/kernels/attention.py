"""Layer-1 Pallas attention kernels for the LAMPS serving stack.

Two kernels cover the serving hot path:

* :func:`decode_attention` — single-query ("flash-decoding") attention of one
  new token against the KV cache. This is the per-iteration hot spot of the
  decode phase the paper's scheduler optimizes around.
* :func:`prefill_attention` — blocked causal self-attention used once per
  request at admission (prefill phase).

Hardware-adaptation notes (GPU paper -> TPU kernel), per DESIGN.md
§Hardware-Adaptation:

- The CUDA PagedAttention structure (warps gathering KV pages into shared
  memory) becomes a ``BlockSpec``-driven HBM->VMEM schedule: the grid walks
  ``(batch, head)`` and the kernel streams the sequence axis through VMEM in
  ``block_k``-sized tiles with an *online softmax* (running max / denominator
  / weighted-value accumulator), never materializing the full attention row.
- The q.K^T and p.V contractions are plain dot products so Mosaic can place
  them on the MXU when compiled for real TPUs.
- ``interpret=True`` is mandatory on this CPU image: real TPU lowering emits
  a Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are
  validated against ``ref.py`` through the interpret path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Decode attention (single query token vs. KV cache)
# ---------------------------------------------------------------------------


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, block_k: int,
                        scale: float):
    """One (batch, head) cell: online-softmax over sequence tiles.

    Ref shapes (leading blocked dims of size 1 dropped by indexing):
      q_ref:   (1, 1, D)        the query for this (b, h)
      k_ref:   (1, S, 1, D)     keys for this (b, h)
      v_ref:   (1, S, 1, D)     values
      len_ref: (1, 1)           valid KV length for this b (int32)
      o_ref:   (1, 1, D)        output
    """
    seq_len = k_ref.shape[1]
    head_dim = q_ref.shape[-1]
    num_blocks = seq_len // block_k

    q = q_ref[0, 0, :].astype(jnp.float32)  # (D,)
    valid = len_ref[0, 0]

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        start = i * block_k
        k_blk = k_ref[0, pl.dslice(start, block_k), 0, :].astype(jnp.float32)
        v_blk = v_ref[0, pl.dslice(start, block_k), 0, :].astype(jnp.float32)
        # scores for this tile: (block_k,)
        s = jnp.dot(k_blk, q) * scale
        idx = start + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(idx < valid, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p)
        acc_new = acc_prev * alpha + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    init = (jnp.float32(NEG_INF), jnp.float32(0.0),
            jnp.zeros((head_dim,), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, num_blocks, body, init)
    # Fully-masked rows (valid == 0): exp(NEG_INF - NEG_INF) == 1 would make
    # the row an unweighted mean of V; masking is prefix-valid so this is
    # the only degenerate case — emit zeros to match the oracle.
    l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.where(valid > 0, acc / l, 0.0)
    o_ref[0, 0, :] = out.astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, block_k: int = 64,
                     interpret: bool = True) -> jax.Array:
    """Single-token decode attention against a (padded) KV cache.

    Args:
      q:       (B, H, D)    query vectors for the new token.
      k, v:    (B, S, H, D) padded KV cache; entries at position >= lengths[b]
               are ignored.
      lengths: (B,) int32   valid cache length per sequence.
      block_k: sequence tile size streamed through VMEM.

    Returns:
      (B, H, D) attention output.
    """
    batch, n_heads, head_dim = q.shape
    seq_len = k.shape[1]
    if seq_len % block_k != 0:
        raise ValueError(f"seq_len {seq_len} must be a multiple of "
                         f"block_k {block_k}")
    scale = 1.0 / (head_dim ** 0.5)
    lengths2 = lengths.astype(jnp.int32).reshape(batch, 1)

    kernel = functools.partial(_decode_attn_kernel, block_k=block_k,
                               scale=scale)
    grid = (batch, n_heads)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, seq_len, 1, head_dim), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, seq_len, 1, head_dim), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_heads, head_dim), q.dtype),
        interpret=interpret,
    )(q, k, v, lengths2)


# ---------------------------------------------------------------------------
# Prefill attention (blocked causal self-attention)
# ---------------------------------------------------------------------------


def _prefill_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *,
                         block_q: int, block_k: int, scale: float):
    """One (batch, head, q-tile) cell: causal online-softmax over KV tiles.

    Ref shapes:
      q_ref:   (1, block_q, 1, D)
      k_ref:   (1, S, 1, D)
      v_ref:   (1, S, 1, D)
      len_ref: (1, 1)
      o_ref:   (1, block_q, 1, D)
    """
    qt = pl.program_id(2)
    seq_len = k_ref.shape[1]
    head_dim = q_ref.shape[-1]
    valid = len_ref[0, 0]

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (block_q, D)
    q_idx = qt * block_q + jax.lax.iota(jnp.int32, block_q)  # (block_q,)

    # Causality: a q-tile only attends to KV tiles with start <= tile end.
    num_k_blocks = (qt * block_q + block_q + block_k - 1) // block_k
    num_k_blocks = jnp.minimum(num_k_blocks, seq_len // block_k)

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        start = i * block_k
        k_blk = k_ref[0, pl.dslice(start, block_k), 0, :].astype(jnp.float32)
        v_blk = v_ref[0, pl.dslice(start, block_k), 0, :].astype(jnp.float32)
        s = jnp.dot(q, k_blk.T)  # (block_q, block_k)
        k_idx = start + jax.lax.iota(jnp.int32, block_k)
        mask = (k_idx[None, :] <= q_idx[:, None]) & (k_idx[None, :] < valid)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    init = (jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, head_dim), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, init)
    # Rows at q_idx >= valid are fully masked (see decode kernel note):
    # zero them explicitly so padded positions hold zeros, not garbage.
    l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.where((q_idx < valid)[:, None], acc / l[:, None], 0.0)
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      lengths: jax.Array, *, block_q: int = 64,
                      block_k: int = 64, interpret: bool = True) -> jax.Array:
    """Blocked causal self-attention for the prefill phase.

    Args:
      q, k, v: (B, S, H, D) padded token projections.
      lengths: (B,) int32 valid prompt length per sequence.

    Returns:
      (B, S, H, D) attention output (garbage at positions >= lengths[b]).
    """
    batch, seq_len, n_heads, head_dim = q.shape
    if seq_len % block_q != 0 or seq_len % block_k != 0:
        raise ValueError(f"seq_len {seq_len} must be a multiple of block_q "
                         f"{block_q} and block_k {block_k}")
    scale = 1.0 / (head_dim ** 0.5)
    lengths2 = lengths.astype(jnp.int32).reshape(batch, 1)

    kernel = functools.partial(_prefill_attn_kernel, block_q=block_q,
                               block_k=block_k, scale=scale)
    grid = (batch, n_heads, seq_len // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, head_dim),
                         lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, seq_len, 1, head_dim),
                         lambda b, h, t: (b, 0, h, 0)),
            pl.BlockSpec((1, seq_len, 1, head_dim),
                         lambda b, h, t: (b, 0, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, head_dim),
                               lambda b, h, t: (b, t, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, lengths2)
