"""Toy word-hash tokenizer shared between the Python compile path and the
Rust runtime (`rust/src/workload/tokenizer.rs` mirrors this byte-for-byte).

A real deployment would ship a BPE vocabulary; for this reproduction the
scheduler and predictor only need a *stable* prompt -> token-id mapping that
both languages compute identically, so we use FNV-1a 64-bit word hashing into
a small vocabulary. Ids 0..RESERVED are special.
"""

from __future__ import annotations

from typing import List

VOCAB_SIZE = 512
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
RESERVED = 8

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def hash_word(word: str) -> int:
    """FNV-1a 64-bit over the UTF-8 bytes of ``word``."""
    h = _FNV_OFFSET
    for b in word.encode("utf-8"):
        h ^= b
        h = (h * _FNV_PRIME) & _MASK64
    return h


def word_id(word: str) -> int:
    return RESERVED + hash_word(word) % (VOCAB_SIZE - RESERVED)


def encode(text: str, max_len: int) -> List[int]:
    """BOS + hashed words, truncated/padded to ``max_len``."""
    ids = [BOS_ID]
    for w in text.split():
        if len(ids) >= max_len:
            break
        ids.append(word_id(w))
    while len(ids) < max_len:
        ids.append(PAD_ID)
    return ids[:max_len]


def valid_len(text: str, max_len: int) -> int:
    return min(1 + len(text.split()), max_len)
