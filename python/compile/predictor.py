"""Length-predictor model (the paper's OPT-125M stand-in, DESIGN.md §2).

The paper extracts OPT-125M's final-token embedding and feeds it to a linear
classifier over 50 bins of 10 tokens, trained with cross-entropy (§5). Here
the backbone is a small learned embedding + mean-pool + MLP — the same
mechanism (prompt -> embedding -> bin logits) at a size trainable at
artifact-build time on CPU. Training data is the synthetic ToolBench corpus
(:mod:`compile.corpus`); the trained network is baked into
``artifacts/predictor.hlo.txt`` and evaluated for Table 3.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus as corpus_mod
from compile import tokenizer as tok

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    vocab_size: int = tok.VOCAB_SIZE
    max_prompt: int = 64
    embed_dim: int = 32
    hidden_dim: int = 64
    num_bins: int = corpus_mod.NUM_BINS
    bin_width: int = corpus_mod.BIN_WIDTH


def init_params(rng: jax.Array, cfg: PredictorConfig) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "embed": jax.random.normal(k1, (cfg.vocab_size, cfg.embed_dim)) * 0.1,
        "w1": jax.random.normal(
            k2, (cfg.embed_dim, cfg.hidden_dim)) / math.sqrt(cfg.embed_dim),
        "b1": jnp.zeros((cfg.hidden_dim,)),
        "w2": jax.random.normal(
            k3, (cfg.hidden_dim, cfg.num_bins)) / math.sqrt(cfg.hidden_dim),
        "b2": jnp.zeros((cfg.num_bins,)),
    }


def forward(params: Params, tokens: jax.Array) -> jax.Array:
    """tokens: (B, max_prompt) int32 -> bin logits (B, num_bins)."""
    emb = params["embed"][tokens]  # (B, T, E)
    mask = (tokens != tok.PAD_ID).astype(jnp.float32)[..., None]
    pooled = jnp.sum(emb * mask, axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1), 1.0)
    h = jax.nn.relu(pooled @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def predict_bin(params: Params, tokens: jax.Array) -> jax.Array:
    """The graph exported to HLO: argmax bin, (B,) int32."""
    return jnp.argmax(forward(params, tokens), axis=-1).astype(jnp.int32)


def _loss(params: Params, tokens: jax.Array, bins: jax.Array) -> jax.Array:
    logits = forward(params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, bins[:, None], axis=1))


def encode_samples(samples: List[corpus_mod.Sample], cfg: PredictorConfig
                   ) -> Tuple[np.ndarray, np.ndarray]:
    xs = np.asarray([tok.encode(s.prompt, cfg.max_prompt) for s in samples],
                    dtype=np.int32)
    ys = np.asarray([s.bin for s in samples], dtype=np.int32)
    return xs, ys


def train(cfg: PredictorConfig, *, corpus_size: int = 6000,
          steps: int = 2000, batch: int = 128, lr: float = 3e-3,
          seed: int = 0) -> Tuple[Params, dict]:
    """Train on the synthetic ToolBench corpus; returns (params, table3 stats).

    Hand-rolled Adam keeps the compile path dependency-free (no optax on
    this image); plain SGD stalls here — pooled-embedding gradients are tiny
    at init and Adam's per-parameter normalization is what moves them.
    """
    samples = corpus_mod.gen_corpus(corpus_size, seed=seed)
    train_s, val_s = corpus_mod.train_val_split(samples, 0.8)
    xs, ys = encode_samples(train_s, cfg)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    m_state = jax.tree_util.tree_map(jnp.zeros_like, params)
    v_state = jax.tree_util.tree_map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def adam_update(params, m_state, v_state, step, xb, yb):
        loss, grads = jax.value_and_grad(_loss)(params, xb, yb)
        m_state = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, m_state, grads)
        v_state = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * g * g, v_state, grads)
        t = step + 1.0
        mhat_scale = 1.0 / (1.0 - b1 ** t)
        vhat_scale = 1.0 / (1.0 - b2 ** t)
        params = jax.tree_util.tree_map(
            lambda p, m, v: p - lr * (m * mhat_scale) /
            (jnp.sqrt(v * vhat_scale) + eps),
            params, m_state, v_state)
        return params, m_state, v_state, loss

    rng = np.random.default_rng(seed)
    losses = []
    for step in range(steps):
        idx = rng.integers(0, len(xs), size=batch)
        params, m_state, v_state, loss = adam_update(
            params, m_state, v_state, float(step), xs[idx], ys[idx])
        losses.append(float(loss))

    stats = evaluate(params, cfg, val_s)
    stats["final_train_loss"] = float(np.mean(losses[-20:]))
    return params, stats


def evaluate(params: Params, cfg: PredictorConfig,
             samples: List[corpus_mod.Sample]) -> dict:
    """Table 3 metrics: Acc-5 / Acc-15 overall + per-bin, MAE (in words)."""
    xs, ys = encode_samples(samples, cfg)
    pred_bins = np.asarray(jax.jit(predict_bin)(params, jnp.asarray(xs)))
    true_len = np.asarray([s.length for s in samples], dtype=np.float64)
    pred_len = pred_bins * cfg.bin_width + cfg.bin_width / 2.0
    err = np.abs(pred_len - true_len)

    per_bin = {}
    for b in range(cfg.num_bins):
        sel = ys == b
        if not np.any(sel):
            continue
        per_bin[int(b)] = {
            "n": int(sel.sum()),
            "acc5": float(np.mean(err[sel] <= 5.0)),
            "acc15": float(np.mean(err[sel] <= 15.0)),
        }

    first20 = ys < 20
    return {
        "n_val": len(samples),
        "acc5": float(np.mean(err <= 5.0)),
        "acc15": float(np.mean(err <= 15.0)),
        "mae_bins": float(np.mean(np.abs(pred_bins - ys))),
        "mae_words": float(np.mean(err)),
        "mae_words_first20": float(np.mean(err[first20]))
        if np.any(first20) else None,
        "per_bin": per_bin,
    }
