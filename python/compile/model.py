"""Layer-2 JAX model: TinyGPT, the serving target for the LAMPS stack.

The paper serves GPT-J 6B and Vicuna 13B on A100s; that hardware/weights
combination is unavailable here (DESIGN.md §2), so the served model is a
small GPT-style decoder with two presets mirroring the paper's two model
sizes ("gptj-tiny", "vicuna-tiny"). The *system* code paths are identical to
serving a large model: prefill builds a KV cache, decode consumes and extends
it one token per iteration, and the scheduler manages the cache's memory.

Both entry points call the Layer-1 Pallas kernels
(:mod:`compile.kernels.attention`), so the kernels lower into the same HLO
modules exported by :mod:`compile.aot`.

Shapes are static (PJRT executables are fixed-shape): the batch is padded to
``B`` slots and caches to ``max_seq``; per-slot validity is carried in
``lengths`` / ``pos`` vectors. Weights are baked into the HLO as constants at
lowering time, so the Rust runtime passes only dynamic state.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import decode_attention, prefill_attention

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """TinyGPT hyper-parameters.

    ``kv_bytes_per_token`` is the quantity M in the paper's waste equations
    (1)-(3): 2 (K and V) * n_layers * n_heads * head_dim * 4 bytes (f32).
    """

    name: str = "gptj-tiny"
    vocab_size: int = 512
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    ffn_mult: int = 4
    max_seq: int = 128
    batch: int = 4

    @property
    def d_model(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_bytes_per_token(self) -> int:
        return 2 * self.n_layers * self.n_heads * self.head_dim * 4


PRESETS = {
    # Stand-ins for the paper's two evaluation models (DESIGN.md §2).
    "gptj-tiny": ModelConfig(name="gptj-tiny", n_layers=4, n_heads=4,
                             head_dim=32),
    "vicuna-tiny": ModelConfig(name="vicuna-tiny", n_layers=6, n_heads=5,
                               head_dim=32),
}


def init_params(rng: jax.Array, cfg: ModelConfig) -> Params:
    """Random (but well-scaled) weights; the repo serves, it does not train."""
    d = cfg.d_model
    keys = jax.random.split(rng, 2 + cfg.n_layers)

    def dense(key, shape):
        fan_in = shape[0]
        return jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)

    layers = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + i], 6)
        layers.append({
            "wq": dense(ks[0], (d, d)),
            "wk": dense(ks[1], (d, d)),
            "wv": dense(ks[2], (d, d)),
            "wo": dense(ks[3], (d, d)),
            "w_up": dense(ks[4], (d, cfg.ffn_mult * d)),
            "w_down": dense(ks[5], (cfg.ffn_mult * d, d)),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
        })
    return {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, d),
                                   jnp.float32) * 0.02,
        "pos_embed": jax.random.normal(keys[1], (cfg.max_seq, d),
                                       jnp.float32) * 0.02,
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": layers,
    }


def _rmsnorm(x: jax.Array, g: jax.Array) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def _split_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(..., d_model) -> (..., H, D)."""
    return x.reshape(x.shape[:-1] + (cfg.n_heads, cfg.head_dim))


def _merge_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    return x.reshape(x.shape[:-2] + (cfg.d_model,))


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            lengths: jax.Array, *, interpret: bool = True
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Run the prompt through the model, building the KV cache.

    Args:
      tokens:  (B, S) int32 padded prompt tokens.
      lengths: (B,)   int32 valid prompt length per slot.

    Returns:
      logits:   (B, vocab) next-token logits at each slot's last valid pos.
      k_cache:  (L, B, S, H, D)
      v_cache:  (L, B, S, H, D)
    """
    batch, seq = tokens.shape
    h = params["embed"][tokens] + params["pos_embed"][None, :seq, :]
    k_all, v_all = [], []
    for layer in params["layers"]:
        xn = _rmsnorm(h, layer["ln1"])
        q = _split_heads(xn @ layer["wq"], cfg)  # (B, S, H, D)
        k = _split_heads(xn @ layer["wk"], cfg)
        v = _split_heads(xn @ layer["wv"], cfg)
        attn = prefill_attention(q, k, v, lengths, interpret=interpret)
        h = h + _merge_heads(attn, cfg) @ layer["wo"]
        xn = _rmsnorm(h, layer["ln2"])
        h = h + jax.nn.gelu(xn @ layer["w_up"]) @ layer["w_down"]
        k_all.append(k)
        v_all.append(v)
    h = _rmsnorm(h, params["ln_f"])
    # Gather each slot's last valid hidden state.
    last = jnp.clip(lengths - 1, 0, seq - 1)
    h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0, :]
    logits = h_last @ params["embed"].T
    return logits, jnp.stack(k_all), jnp.stack(v_all)


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                pos: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                *, interpret: bool = True
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One iteration of decode: extend the KV cache and emit logits.

    Args:
      token:   (B,)  int32 the most recent token per slot.
      pos:     (B,)  int32 the position this token occupies (== current
               sequence length - 1); inactive slots can pass 0.
      k_cache: (L, B, S, H, D) current cache (updated functionally).
      v_cache: (L, B, S, H, D)

    Returns:
      logits (B, vocab), new k_cache, new v_cache.
    """
    batch = token.shape[0]
    h = params["embed"][token] + params["pos_embed"][pos]  # (B, d)
    new_k, new_v = [], []
    lengths = pos + 1  # tokens visible to attention after the cache write
    batch_idx = jnp.arange(batch)
    for li, layer in enumerate(params["layers"]):
        xn = _rmsnorm(h, layer["ln1"])
        q = _split_heads(xn @ layer["wq"], cfg)  # (B, H, D)
        k = _split_heads(xn @ layer["wk"], cfg)
        v = _split_heads(xn @ layer["wv"], cfg)
        kc = k_cache[li].at[batch_idx, pos].set(k)  # (B, S, H, D)
        vc = v_cache[li].at[batch_idx, pos].set(v)
        attn = decode_attention(q, kc, vc, lengths, interpret=interpret)
        h = h + _merge_heads(attn, cfg) @ layer["wo"]
        xn = _rmsnorm(h, layer["ln2"])
        h = h + jax.nn.gelu(xn @ layer["w_up"]) @ layer["w_down"]
        new_k.append(kc)
        new_v.append(vc)
    h = _rmsnorm(h, params["ln_f"])
    logits = h @ params["embed"].T
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def decode_step_greedy(params: Params, cfg: ModelConfig, token: jax.Array,
                       pos: jax.Array, k_cache: jax.Array,
                       v_cache: jax.Array, *, interpret: bool = True
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """decode_step + argmax, the exact graph exported for the Rust hot path."""
    logits, kc, vc = decode_step(params, cfg, token, pos, k_cache, v_cache,
                                 interpret=interpret)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), kc, vc


def prefill_greedy(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   lengths: jax.Array, *, interpret: bool = True
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """prefill + argmax, the exact graph exported for the Rust hot path."""
    logits, kc, vc = prefill(params, cfg, tokens, lengths,
                             interpret=interpret)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), kc, vc
