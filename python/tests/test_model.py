"""Layer-2 model invariants: shapes, KV-cache consistency, padding hygiene.

The serving engine's correctness rests on one identity: running a prompt
through ``prefill`` and then extending token-by-token with ``decode_step``
must produce the same logits as prefilling the longer prompt directly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(name="test-tiny", vocab_size=64, n_layers=2, n_heads=2,
                    head_dim=16, max_seq=64, batch=2)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(42), CFG)


def _pad_tokens(rows):
    t = np.zeros((CFG.batch, CFG.max_seq), np.int32)
    lens = np.zeros((CFG.batch,), np.int32)
    for i, row in enumerate(rows):
        t[i, :len(row)] = row
        lens[i] = len(row)
    return jnp.asarray(t), jnp.asarray(lens)


def test_prefill_shapes(params):
    tokens, lens = _pad_tokens([[3, 4, 5], [6, 7, 8, 9]])
    logits, kc, vc = M.prefill(params, CFG, tokens, lens)
    assert logits.shape == (CFG.batch, CFG.vocab_size)
    assert kc.shape == (CFG.n_layers, CFG.batch, CFG.max_seq, CFG.n_heads,
                        CFG.head_dim)
    assert vc.shape == kc.shape
    assert np.all(np.isfinite(np.asarray(logits)))


def test_decode_step_shapes(params):
    tokens, lens = _pad_tokens([[3, 4, 5], [6, 7]])
    _, kc, vc = M.prefill(params, CFG, tokens, lens)
    logits, kc2, vc2 = M.decode_step(params, CFG,
                                     jnp.asarray([10, 11], jnp.int32),
                                     lens, kc, vc)
    assert logits.shape == (CFG.batch, CFG.vocab_size)
    assert kc2.shape == kc.shape


def test_prefill_then_decode_matches_longer_prefill(params):
    """prefill(p) + decode(t) logits == prefill(p + [t]) logits."""
    prompt = [5, 9, 13, 21, 2, 33]
    nxt = 17
    tokens, lens = _pad_tokens([prompt, prompt])
    _, kc, vc = M.prefill(params, CFG, tokens, lens)
    step_logits, _, _ = M.decode_step(
        params, CFG, jnp.asarray([nxt, nxt], jnp.int32), lens, kc, vc)

    tokens2, lens2 = _pad_tokens([prompt + [nxt], prompt + [nxt]])
    full_logits, _, _ = M.prefill(params, CFG, tokens2, lens2)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=2e-4, atol=2e-4)


def test_multi_step_decode_matches_prefill(params):
    """Three decode steps == one prefill of the concatenated sequence."""
    prompt = [7, 8, 9]
    extra = [11, 12, 13]
    tokens, lens = _pad_tokens([prompt, prompt])
    _, kc, vc = M.prefill(params, CFG, tokens, lens)
    pos = np.asarray(lens)
    logits = None
    for t in extra:
        logits, kc, vc = M.decode_step(
            params, CFG, jnp.asarray([t, t], jnp.int32),
            jnp.asarray(pos, jnp.int32), kc, vc)
        pos = pos + 1
    tokens2, lens2 = _pad_tokens([prompt + extra, prompt + extra])
    full_logits, _, _ = M.prefill(params, CFG, tokens2, lens2)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=5e-4, atol=5e-4)


def test_batch_slots_are_independent(params):
    """Changing slot 1's prompt must not change slot 0's logits — the
    engine packs unrelated requests into one fixed-shape batch."""
    tokens_a, lens = _pad_tokens([[3, 4, 5, 6], [7, 8, 9]])
    tokens_b, _ = _pad_tokens([[3, 4, 5, 6], [50, 51, 52]])
    la, _, _ = M.prefill(params, CFG, tokens_a, lens)
    lb, _, _ = M.prefill(params, CFG, tokens_b, lens)
    np.testing.assert_allclose(np.asarray(la)[0], np.asarray(lb)[0],
                               rtol=1e-6, atol=1e-6)
    assert not np.allclose(np.asarray(la)[1], np.asarray(lb)[1])


def test_padding_tokens_do_not_leak(params):
    """Same prompt with different garbage in the padded tail -> same logits."""
    prompt = [9, 10, 11]
    t1 = np.zeros((CFG.batch, CFG.max_seq), np.int32)
    t2 = np.full((CFG.batch, CFG.max_seq), 63, np.int32)
    for t in (t1, t2):
        t[0, :3] = prompt
        t[1, :3] = prompt
    lens = jnp.asarray([3, 3], jnp.int32)
    l1, _, _ = M.prefill(params, CFG, jnp.asarray(t1), lens)
    l2, _, _ = M.prefill(params, CFG, jnp.asarray(t2), lens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6,
                               atol=1e-6)


def test_greedy_variants_match(params):
    tokens, lens = _pad_tokens([[3, 4, 5], [6, 7, 8]])
    logits, kc, vc = M.prefill(params, CFG, tokens, lens)
    nxt, kc_g, vc_g = M.prefill_greedy(params, CFG, tokens, lens)
    assert np.array_equal(np.asarray(nxt),
                          np.argmax(np.asarray(logits), axis=-1))
    np.testing.assert_allclose(np.asarray(kc), np.asarray(kc_g))

    dl, _, _ = M.decode_step(params, CFG, nxt, lens, kc, vc)
    dn, _, _ = M.decode_step_greedy(params, CFG, nxt, lens, kc, vc)
    assert np.array_equal(np.asarray(dn), np.argmax(np.asarray(dl), axis=-1))


def test_kv_bytes_per_token():
    assert CFG.kv_bytes_per_token == 2 * 2 * 2 * 16 * 4
    gptj = M.PRESETS["gptj-tiny"]
    assert gptj.kv_bytes_per_token == 2 * 4 * 4 * 32 * 4


def test_presets_are_distinct_sizes():
    a, b = M.PRESETS["gptj-tiny"], M.PRESETS["vicuna-tiny"]
    assert (b.n_layers, b.d_model) > (a.n_layers, a.d_model)
