"""Predictor (OPT-125M stand-in) training + evaluation sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus as corpus_mod
from compile import predictor as P
from compile import tokenizer as tok

CFG = P.PredictorConfig()


def test_corpus_lengths_in_bin_range():
    samples = corpus_mod.gen_corpus(500, seed=3)
    for s in samples:
        assert 1 <= s.length < corpus_mod.NUM_BINS * corpus_mod.BIN_WIDTH
        assert 0 <= s.bin < corpus_mod.NUM_BINS
        assert s.bin == s.length // corpus_mod.BIN_WIDTH


def test_corpus_deterministic():
    a = corpus_mod.gen_corpus(50, seed=9)
    b = corpus_mod.gen_corpus(50, seed=9)
    assert [(s.prompt, s.length) for s in a] == \
        [(s.prompt, s.length) for s in b]


def test_corpus_category_correlation():
    """'code' prompts must be longer than 'weather' prompts on average —
    this is the signal the predictor learns."""
    samples = corpus_mod.gen_corpus(2000, seed=1)
    by_cat = {}
    for s in samples:
        cat = s.prompt.split()[2]  # "call the <cat> api ..."
        by_cat.setdefault(cat, []).append(s.length)
    assert np.mean(by_cat["code"]) > np.mean(by_cat["weather"])


def test_forward_shapes():
    params = P.init_params(jax.random.PRNGKey(0), CFG)
    toks = jnp.zeros((5, CFG.max_prompt), jnp.int32)
    logits = P.forward(params, toks)
    assert logits.shape == (5, CFG.num_bins)
    bins = P.predict_bin(params, toks)
    assert bins.shape == (5,)
    assert bins.dtype == jnp.int32


def test_padding_ignored_by_pooling():
    params = P.init_params(jax.random.PRNGKey(0), CFG)
    ids = tok.encode("call the weather api", CFG.max_prompt)
    a = jnp.asarray([ids], jnp.int32)
    # Same prompt but as if max_prompt were shorter: identical non-pad
    # prefix, so pooled embedding must match.
    logits_a = P.forward(params, a)
    # Double-check mask: replacing PAD positions' ids with PAD again is a
    # no-op, but replacing them with a real token must change the output.
    ids_mod = list(ids)
    ids_mod[-1] = 17
    logits_b = P.forward(params, jnp.asarray([ids_mod], jnp.int32))
    assert not np.allclose(np.asarray(logits_a), np.asarray(logits_b))


@pytest.mark.slow
def test_training_beats_chance():
    params, stats = P.train(CFG, corpus_size=2000, steps=200, seed=0)
    # 50-bin chance for acc15 (+/- 1.5 bins ~ 3 bins wide) is ~6%; the
    # trained model must be far above it.
    assert stats["acc15"] > 0.4, stats
    assert stats["mae_bins"] < 5.0, stats


@pytest.mark.slow
def test_accuracy_degrades_with_bin():
    """Table 3 shape: early bins more accurate than late bins."""
    params, stats = P.train(CFG, corpus_size=3000, steps=300, seed=0)
    per_bin = stats["per_bin"]
    early = [per_bin[b]["acc15"] for b in per_bin if b < 10]
    late = [per_bin[b]["acc15"] for b in per_bin if b >= 20]
    assert early and late
    assert np.mean(early) > np.mean(late)
