"""AOT artifact smoke tests: lowering emits parseable HLO text with the
expected entry signature (the contract the Rust runtime depends on)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrip_simple():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4]" in text


def test_model_export_tiny(tmp_path):
    """Export a scaled-down preset end-to-end and check the artifacts."""
    M.PRESETS["unit-tiny"] = M.ModelConfig(
        name="unit-tiny", vocab_size=64, n_layers=1, n_heads=2, head_dim=8,
        max_seq=64, batch=2)
    try:
        meta = aot.export_model("unit-tiny", str(tmp_path))
    finally:
        del M.PRESETS["unit-tiny"]

    for key in ("prefill_hlo", "decode_hlo"):
        path = tmp_path / meta[key]
        assert path.exists()
        text = path.read_text()
        assert "ENTRY" in text
    assert meta["kv_bytes_per_token"] == 2 * 1 * 2 * 8 * 4

    decode_text = (tmp_path / meta["decode_hlo"]).read_text()
    # Decode entry takes (token, pos, k, v): two s32[B] and two KV f32s.
    assert decode_text.count("s32[2]") >= 2
    assert "f32[1,2,64,2,8]" in decode_text


@pytest.mark.slow
def test_predictor_export(tmp_path):
    meta = aot.export_predictor(str(tmp_path), steps=60)
    assert (tmp_path / meta["predictor_hlo"]).exists()
    stats = json.loads((tmp_path / "predictor_stats.json").read_text())
    assert stats["n_val"] > 0
    assert 0.0 <= stats["acc15"] <= 1.0
    text = (tmp_path / meta["predictor_hlo"]).read_text()
    assert "ENTRY" in text
    assert f"s32[1,{meta['max_prompt']}]" in text
