"""Tokenizer cross-language contract.

The golden FNV-1a values here are duplicated in
``rust/src/workload/tokenizer.rs`` tests — if either side drifts, the
predictor would silently see out-of-distribution token ids at serving time.
"""

from hypothesis import given, settings, strategies as st

from compile import tokenizer as tok

# (word, fnv1a64, token id) — mirrored in rust/src/workload/tokenizer.rs.
GOLDEN = [
    ("weather", 4051237610556911699, 331),
    ("finance", 1035045675406308941, 61),
    ("code", 843606417163895828, 52),
    ("api", 16667751959619087879, 287),
    ("exhaustive", 9052355608359096841, 249),
    ("the", 6266135566914540924, 20),
]


def test_golden_hashes():
    for word, h, wid in GOLDEN:
        assert tok.hash_word(word) == h, word
        assert tok.word_id(word) == wid, word


def test_encode_golden():
    assert tok.encode("call the weather api", 8) == \
        [1, 369, 20, 331, 287, 0, 0, 0]


def test_encode_shape_and_padding():
    ids = tok.encode("a b c", 10)
    assert len(ids) == 10
    assert ids[0] == tok.BOS_ID
    assert ids[4:] == [tok.PAD_ID] * 6


def test_encode_truncates():
    ids = tok.encode(" ".join(["w"] * 100), 8)
    assert len(ids) == 8
    assert tok.PAD_ID not in ids


@settings(deadline=None, max_examples=100)
@given(st.text(alphabet=st.characters(codec="utf-8"), min_size=0,
               max_size=30))
def test_word_id_in_range(word):
    wid = tok.word_id(word)
    assert tok.RESERVED <= wid < tok.VOCAB_SIZE


@settings(deadline=None, max_examples=50)
@given(st.lists(st.sampled_from("alpha beta gamma delta".split()),
                min_size=0, max_size=20), st.integers(2, 32))
def test_encode_deterministic_and_bounded(words, max_len):
    text = " ".join(words)
    a, b = tok.encode(text, max_len), tok.encode(text, max_len)
    assert a == b
    assert len(a) == max_len
    assert all(0 <= t < tok.VOCAB_SIZE for t in a)
    assert tok.valid_len(text, max_len) == min(1 + len(words), max_len)
