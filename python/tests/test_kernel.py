"""Kernel-vs-oracle correctness: the CORE numeric signal for Layer 1.

Hypothesis sweeps shapes / dtypes / valid-length patterns; every case
asserts allclose against the pure-jnp oracle in ``compile.kernels.ref``.
Interpret-mode Pallas is slow, so example counts are kept moderate and
dimensions small — coverage comes from the *structure* of the sweep
(block-boundary lengths, degenerate rows, dtype mix), not raw volume.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention, prefill_attention

SETTINGS = dict(deadline=None, max_examples=25, derandomize=True)


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else dict(
        rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    batch=st.integers(1, 4),
    n_heads=st.sampled_from([1, 2, 4]),
    head_dim=st.sampled_from([8, 16, 32]),
    seq_blocks=st.integers(1, 4),
    block_k=st.sampled_from([16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_decode_matches_ref(batch, n_heads, head_dim, seq_blocks, block_k,
                            dtype, seed):
    seq = seq_blocks * block_k
    rng = np.random.default_rng(seed)
    q = _rand(rng, (batch, n_heads, head_dim), dtype)
    k = _rand(rng, (batch, seq, n_heads, head_dim), dtype)
    v = _rand(rng, (batch, seq, n_heads, head_dim), dtype)
    lengths = jnp.asarray(rng.integers(0, seq + 1, size=batch), jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=block_k)
    exp = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("length", [0, 1, 31, 32, 33, 64])
def test_decode_block_boundary_lengths(length):
    """Valid lengths straddling tile boundaries — the masking hot spots."""
    rng = np.random.default_rng(7)
    B, S, H, D = 2, 64, 2, 16
    q = _rand(rng, (B, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32)
    v = _rand(rng, (B, S, H, D), jnp.float32)
    lengths = jnp.asarray([length, S], jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=32)
    exp = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_decode_zero_length_is_zero():
    rng = np.random.default_rng(1)
    B, S, H, D = 1, 32, 2, 8
    out = decode_attention(
        _rand(rng, (B, H, D), jnp.float32),
        _rand(rng, (B, S, H, D), jnp.float32),
        _rand(rng, (B, S, H, D), jnp.float32),
        jnp.zeros((B,), jnp.int32), block_k=16)
    assert np.all(np.asarray(out) == 0.0)


def test_decode_ignores_padding_values():
    """Garbage beyond `lengths` must not leak into the output."""
    rng = np.random.default_rng(3)
    B, S, H, D = 2, 64, 2, 16
    q = _rand(rng, (B, H, D), jnp.float32)
    k = np.asarray(_rand(rng, (B, S, H, D), jnp.float32))
    v = np.asarray(_rand(rng, (B, S, H, D), jnp.float32))
    lengths = jnp.asarray([10, 40], jnp.int32)
    base = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            lengths, block_k=16)
    k2, v2 = k.copy(), v.copy()
    k2[0, 10:] = 1e6
    v2[0, 10:] = -1e6
    k2[1, 40:] = 1e6
    v2[1, 40:] = -1e6
    poisoned = decode_attention(q, jnp.asarray(k2), jnp.asarray(v2),
                                lengths, block_k=16)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                               rtol=1e-6, atol=1e-6)


def test_decode_rejects_nondivisible_block():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        decode_attention(
            _rand(rng, (1, 1, 8), jnp.float32),
            _rand(rng, (1, 48, 1, 8), jnp.float32),
            _rand(rng, (1, 48, 1, 8), jnp.float32),
            jnp.asarray([48], jnp.int32), block_k=32)


def test_decode_softmax_weights_sum_to_one():
    """With V = all-ones, output must be exactly 1 (softmax normalizes)."""
    rng = np.random.default_rng(5)
    B, S, H, D = 2, 64, 2, 8
    q = _rand(rng, (B, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32)
    v = jnp.ones((B, S, H, D), jnp.float32)
    out = decode_attention(q, k, v, jnp.asarray([17, 64], jnp.int32),
                           block_k=16)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# prefill_attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    batch=st.integers(1, 3),
    n_heads=st.sampled_from([1, 2]),
    head_dim=st.sampled_from([8, 16]),
    seq_blocks=st.integers(1, 3),
    block=st.sampled_from([16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_prefill_matches_ref(batch, n_heads, head_dim, seq_blocks, block,
                             dtype, seed):
    seq = seq_blocks * block
    rng = np.random.default_rng(seed)
    q = _rand(rng, (batch, seq, n_heads, head_dim), dtype)
    k = _rand(rng, (batch, seq, n_heads, head_dim), dtype)
    v = _rand(rng, (batch, seq, n_heads, head_dim), dtype)
    lengths = jnp.asarray(rng.integers(0, seq + 1, size=batch), jnp.int32)
    out = prefill_attention(q, k, v, lengths, block_q=block, block_k=block)
    exp = ref.prefill_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_prefill_is_causal():
    """Changing future tokens must not change past outputs."""
    rng = np.random.default_rng(11)
    B, S, H, D = 1, 64, 2, 16
    q = np.asarray(_rand(rng, (B, S, H, D), jnp.float32))
    k = np.asarray(_rand(rng, (B, S, H, D), jnp.float32))
    v = np.asarray(_rand(rng, (B, S, H, D), jnp.float32))
    lengths = jnp.asarray([S], jnp.int32)
    base = np.asarray(prefill_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), lengths))
    k2, v2 = k.copy(), v.copy()
    k2[0, 40:] += 3.0
    v2[0, 40:] -= 3.0
    mod = np.asarray(prefill_attention(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), lengths))
    np.testing.assert_allclose(base[0, :40], mod[0, :40], rtol=1e-6,
                               atol=1e-6)
    assert not np.allclose(base[0, 41:], mod[0, 41:])


def test_prefill_matches_decode_last_row():
    """The prefill row at position L-1 equals a decode call with the same
    cache — the exact invariant the serving engine relies on when switching
    from prefill to decode."""
    rng = np.random.default_rng(13)
    B, S, H, D = 2, 64, 2, 16
    q = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32)
    v = _rand(rng, (B, S, H, D), jnp.float32)
    lengths = jnp.asarray([23, 64], jnp.int32)
    pre = np.asarray(prefill_attention(q, k, v, lengths))
    last_q = np.stack([np.asarray(q)[b, int(lengths[b]) - 1]
                       for b in range(B)])
    dec = np.asarray(decode_attention(jnp.asarray(last_q), k, v, lengths,
                                      block_k=16))
    for b in range(B):
        np.testing.assert_allclose(pre[b, int(lengths[b]) - 1], dec[b],
                                   rtol=2e-5, atol=2e-5)
