//! Before/after numbers for refcounted KV prefix caching
//! (`SystemConfig::prefix_cache`): a shared-system-prompt workload where
//! every request carries the same 512-char prefix plus a unique tail,
//! and half the requests hit a QA-style API under forced Discard (so
//! the post-API recompute path is hot).
//!
//! Acceptance (asserted, not just printed): with the cache on, the run
//! materializes strictly fewer physical KV blocks and prefills strictly
//! fewer tokens than the uncached run, completes the same requests no
//! slower on average, and a bounded-retention run reports evictions.

use lamps::config::{HandlingPolicy, PrefixCacheConfig, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                           RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::Engine;
use lamps::metrics::RunReport;
use lamps::workload::Trace;

const SHARED_PREFIX_CHARS: usize = 512;
const N_REQUESTS: u64 = 24;

/// One request every 250 ms sharing a 512-char prompt prefix; even ids
/// call a 2 s API whose handling is forced to Discard.
fn workload() -> Vec<RequestSpec> {
    let shared: String = "The quick brown fox jumps over the lazy dog. "
        .chars()
        .cycle()
        .take(SHARED_PREFIX_CHARS)
        .collect();
    (0..N_REQUESTS)
        .map(|i| {
            let prompt = format!("{shared}user-{i:04}");
            let prompt_tokens = Tokens(prompt.len() as u64);
            let api_calls = if i % 2 == 0 {
                vec![ApiCallSpec {
                    decode_before: Tokens(8),
                    api_type: ApiType::Qa,
                    duration: Micros(2_000_000),
                    response_tokens: Tokens(4),
                }]
            } else {
                vec![]
            };
            RequestSpec {
                id: RequestId(i),
                arrival: Micros(i * 250_000),
                prompt,
                prompt_tokens,
                api_calls,
                final_decode: Tokens(16),
            }
        })
        .collect()
}

fn run(prefix: PrefixCacheConfig) -> RunReport {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.handling = HandlingPolicy::Forced(HandlingStrategy::Discard);
    cfg.memory_budget = Tokens(40_000);
    cfg.prefix_cache = prefix;
    let mut engine = Engine::simulated(cfg);
    let trace = Trace::new("shared-prefix", 4.0, workload());
    engine.run_trace(&trace)
}

fn main() {
    let off = run(PrefixCacheConfig::default());
    let on = run(PrefixCacheConfig::on());
    let bounded = run(PrefixCacheConfig {
        enabled: true,
        cache_blocks: Some(8),
    });

    println!("== micro_prefix_cache: {N_REQUESTS} requests sharing a \
              {SHARED_PREFIX_CHARS}-token prompt prefix ==");
    let row = |name: &str, r: &RunReport| {
        println!("{name:<18} blocks {:>5}  prefilled {:>6}  hits {:>6}  \
                  evictions {:>4}  mean latency {:>7.3}s  done {}",
                 r.blocks_allocated, r.tokens_prefilled,
                 r.prefix_hit_tokens, r.prefix_evictions,
                 r.latency.mean_secs(), r.completed);
    };
    row("cache off", &off);
    row("cache on", &on);
    row("cache on (cap 8)", &bounded);

    assert_eq!(off.completed, on.completed,
               "caching must not change completions");
    assert_eq!(off.prefix_hit_tokens, 0);
    assert!(on.prefix_hit_tokens > 0, "shared prefixes must hit");
    assert!(on.blocks_allocated < off.blocks_allocated,
            "cache on must materialize strictly fewer physical blocks \
             ({} vs {})",
            on.blocks_allocated, off.blocks_allocated);
    assert!(on.tokens_prefilled < off.tokens_prefilled,
            "cache on must prefill strictly fewer tokens ({} vs {})",
            on.tokens_prefilled, off.tokens_prefilled);
    assert!(on.latency.mean_us <= off.latency.mean_us,
            "cache on must not regress mean latency ({} vs {})",
            on.latency.mean_us, off.latency.mean_us);
    assert!(bounded.prefix_evictions > 0,
            "bounded retention must evict");
    assert!(bounded.prefix_cached_blocks <= 8,
            "retention cap exceeded");
}
