//! Fig 9: starvation-prevention threshold sweep (multi-API dataset,
//! GPT-J 6B): throughput and P99 latency per threshold. The paper finds
//! 100 a good balance.
use lamps::bench::{Dataset, ModelPreset};
use lamps::config::SystemConfig;
use lamps::core::types::Tokens;
use lamps::engine::Engine;

fn main() {
    let trace = Dataset::MultiApi.generate(300, 6.0, 42);
    println!("{:>10} {:>12} {:>12} {:>12} {:>10}", "threshold",
             "lat_mean(s)", "lat_p99(s)", "ttft_p99(s)", "thr(r/s)");
    let thresholds: [(&str, Option<u32>); 7] =
        [("1", Some(1)), ("10", Some(10)), ("50", Some(50)),
         ("100", Some(100)), ("200", Some(200)), ("500", Some(500)),
         ("none", None)];
    for (label, threshold) in thresholds {
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        cfg.cost = ModelPreset::GptJ6b.cost();
        cfg.memory_budget = Tokens(12_000);
        cfg.starvation_threshold = threshold;
        let report = Engine::simulated(cfg).run_trace(&trace);
        println!("{:>10} {:>12.3} {:>12.3} {:>12.3} {:>10.3}", label,
                 report.latency.mean_secs(), report.latency.p99_secs(),
                 report.ttft.p99_us / 1e6, report.throughput_rps);
    }
}
