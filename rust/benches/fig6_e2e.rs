//! Fig 6: mean + P99 end-to-end latency and TTFT vs request arrival rate,
//! for {vLLM, INFERCEPT, LAMPS} x {single-api, multi-api, toolbench} x
//! {GPT-J 6B, Vicuna 13B} — the paper's headline grid. Also prints the
//! §6.2 headline improvement percentages.
//!
//! Runs with the chunked batch composer enabled (512-token prefill
//! chunks + async swap) for every system; set `LAMPS_CHUNK=off` to
//! reproduce the legacy whole-prompt, synchronous-swap grid. Set
//! `LAMPS_REPLICAS=N` (and optionally `LAMPS_PLACEMENT`) to run every
//! cell across an N-replica `ReplicaSet`; `LAMPS_REPLICAS=1` (the
//! default) is byte-identical to the single-engine grid. Set
//! `LAMPS_PREFIX_CACHE=on` for per-replica prefix caching and
//! `LAMPS_SHARED_PREFIX=on` for the cross-replica shared prefix index
//! (pair the latter with `LAMPS_PLACEMENT=prefix-affinity`).
//!
//! Set `LAMPS_BENCH_JSON=/path/BENCH_fig6.json` to also write the grid
//! as a stable perf-trajectory snapshot (per-cell simulated latency /
//! TTFT percentiles plus measured wall-clock engine-steps/sec — see
//! `lamps::bench::cell_json`).
use lamps::bench::{cell_json, print_cells, print_headline,
                   run_cell_fleet_shared, write_bench_json, Cell,
                   Dataset, ModelPreset, SYSTEMS};
use lamps::config::{ComposeConfig, PlacementKind, PrefixCacheConfig};
use lamps::util::json;

fn env_on(name: &str) -> bool {
    matches!(std::env::var(name).as_deref(),
             Ok("1") | Ok("on") | Ok("true"))
}

fn main() {
    let compose = match std::env::var("LAMPS_CHUNK").as_deref() {
        Ok("off") | Ok("0") => ComposeConfig::default(),
        _ => ComposeConfig::chunked(),
    };
    let replicas: usize = std::env::var("LAMPS_REPLICAS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
        .max(1);
    let placement = std::env::var("LAMPS_PLACEMENT")
        .ok()
        .and_then(|v| PlacementKind::parse(&v))
        .unwrap_or(PlacementKind::MemoryOverTime);
    let prefix = if env_on("LAMPS_PREFIX_CACHE") {
        PrefixCacheConfig::on()
    } else {
        PrefixCacheConfig::default()
    };
    let shared_prefix = env_on("LAMPS_SHARED_PREFIX");
    println!("batch composer: prefill chunk {:?}, async swap {} | \
              replicas {replicas} ({} placement) | prefix cache {} | \
              shared prefix index {}",
             compose.prefill_chunk, compose.async_swap,
             placement.label(), prefix.enabled, shared_prefix);
    let rates = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
    // `LAMPS_REQUESTS` shrinks the grid for CI smoke runs (the full
    // 250-request grid is the paper-fidelity default).
    let n = std::env::var("LAMPS_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250);
    let mut snapshot: Vec<json::Value> = Vec::new();
    for model in [ModelPreset::GptJ6b, ModelPreset::Vicuna13b] {
        for dataset in Dataset::ALL {
            let mut cells: Vec<Cell> = Vec::new();
            for &rate in &rates {
                for system in SYSTEMS {
                    let t0 = std::time::Instant::now();
                    let cell = run_cell_fleet_shared(
                        system, dataset, model, rate, n, 42, None,
                        compose, replicas, placement, prefix,
                        shared_prefix);
                    let wall_us = t0.elapsed().as_micros() as u64;
                    snapshot.push(cell_json(&cell, wall_us));
                    cells.push(cell);
                }
            }
            print_cells(&format!("Fig 6 — {} / {}", dataset.label(),
                                 model.label()),
                        &cells);
            print_headline(&cells);
        }
    }
    if let Ok(path) = std::env::var("LAMPS_BENCH_JSON") {
        let body = vec![
            ("requests_per_cell", json::num(n as f64)),
            ("replicas", json::num(replicas as f64)),
            ("cells", json::Value::Arr(snapshot)),
        ];
        match write_bench_json(&path, "fig6", body) {
            Ok(()) => eprintln!("bench json written to {path}"),
            Err(e) => {
                eprintln!("failed to write bench json {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}
