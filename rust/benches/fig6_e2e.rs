//! Fig 6: mean + P99 end-to-end latency and TTFT vs request arrival rate,
//! for {vLLM, INFERCEPT, LAMPS} x {single-api, multi-api, toolbench} x
//! {GPT-J 6B, Vicuna 13B} — the paper's headline grid. Also prints the
//! §6.2 headline improvement percentages.
//!
//! Runs with the chunked batch composer enabled (512-token prefill
//! chunks + async swap) for every system; set `LAMPS_CHUNK=off` to
//! reproduce the legacy whole-prompt, synchronous-swap grid.
use lamps::bench::{print_cells, print_headline, run_cell_with, Cell,
                   Dataset, ModelPreset, SYSTEMS};
use lamps::config::ComposeConfig;

fn main() {
    let compose = match std::env::var("LAMPS_CHUNK").as_deref() {
        Ok("off") | Ok("0") => ComposeConfig::default(),
        _ => ComposeConfig::chunked(),
    };
    println!("batch composer: prefill chunk {:?}, async swap {}",
             compose.prefill_chunk, compose.async_swap);
    let rates = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
    // `LAMPS_REQUESTS` shrinks the grid for CI smoke runs (the full
    // 250-request grid is the paper-fidelity default).
    let n = std::env::var("LAMPS_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250);
    for model in [ModelPreset::GptJ6b, ModelPreset::Vicuna13b] {
        for dataset in Dataset::ALL {
            let mut cells: Vec<Cell> = Vec::new();
            for &rate in &rates {
                for system in SYSTEMS {
                    cells.push(run_cell_with(system, dataset, model,
                                             rate, n, 42, None,
                                             compose));
                }
            }
            print_cells(&format!("Fig 6 — {} / {}", dataset.label(),
                                 model.label()),
                        &cells);
            print_headline(&cells);
        }
    }
}
