//! Fig 6: mean + P99 end-to-end latency and TTFT vs request arrival rate,
//! for {vLLM, INFERCEPT, LAMPS} x {single-api, multi-api, toolbench} x
//! {GPT-J 6B, Vicuna 13B} — the paper's headline grid. Also prints the
//! §6.2 headline improvement percentages.
use lamps::bench::{print_cells, print_headline, run_cell, Cell, Dataset,
                   ModelPreset, SYSTEMS};

fn main() {
    let rates = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
    let n = 250;
    for model in [ModelPreset::GptJ6b, ModelPreset::Vicuna13b] {
        for dataset in Dataset::ALL {
            let mut cells: Vec<Cell> = Vec::new();
            for &rate in &rates {
                for system in SYSTEMS {
                    cells.push(run_cell(system, dataset, model, rate, n,
                                        42, None));
                }
            }
            print_cells(&format!("Fig 6 — {} / {}", dataset.label(),
                                 model.label()),
                        &cells);
            print_headline(&cells);
        }
    }
}
