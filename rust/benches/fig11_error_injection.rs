//! Fig 11: controlled Gaussian error injection into the predictions
//! (error ~ N(0, p x measured)) on the multi-API dataset with GPT-J 6B:
//! latency and throughput vs rate for p in {0, 5, 10, 30, 50}%.
use lamps::bench::{Dataset, ModelPreset};
use lamps::config::{PredictorKind, SystemConfig};
use lamps::core::types::Tokens;
use lamps::engine::Engine;

fn main() {
    println!("{:>6} {:>5} {:>12} {:>12} {:>10}", "err%", "rate",
             "lat_mean(s)", "lat_p50(s)", "thr(r/s)");
    for error_pct in [0.0, 0.05, 0.10, 0.30, 0.50] {
        for rate in [4.0, 6.0, 8.0, 10.0] {
            let trace = Dataset::MultiApi.generate(250, rate, 42);
            let mut cfg = SystemConfig::preset("lamps").unwrap();
            cfg.cost = ModelPreset::GptJ6b.cost();
            cfg.memory_budget = Tokens(12_000);
            cfg.predictor = if error_pct == 0.0 {
                PredictorKind::Oracle
            } else {
                PredictorKind::NoisyOracle { error_pct }
            };
            let report = Engine::simulated(cfg).run_trace(&trace);
            println!("{:>6.0} {:>5.1} {:>12.3} {:>12.3} {:>10.3}",
                     error_pct * 100.0, rate,
                     report.latency.mean_secs(),
                     report.latency.p50_us / 1e6,
                     report.throughput_rps);
        }
    }
}
