//! Fig 11: controlled Gaussian error injection into the predictions
//! (error ~ N(0, p x measured)) on the multi-API dataset with GPT-J 6B —
//! now doubling as the learned-duration-seam robustness yardstick.
//!
//! Modes, driven by `LAMPS_API_PRED`:
//! - `static` or `learned`: the classic Fig 11 table (latency and
//!   throughput vs rate for p in {0, 5, 10, 30, 50}%) under that seam
//!   mode only. The CI smoke runs both values back to back.
//! - unset: the comparison grid — every error level runs under both
//!   seam modes on the same trace and the improvement of learned over
//!   static mean completion time is printed per cell. At p in
//!   {30, 50}% the learned seam must be *strictly* better (averaged
//!   over the rate axis) or the bench exits non-zero: the estimators
//!   exist precisely to degrade less than static predictions as
//!   injected error grows.
//!
//! Comparison mode also honors the perf-trajectory conventions of
//! `micro_wire`/`micro_placement`: `--json PATH` (or
//! `LAMPS_BENCH_JSON`) writes the stable `BENCH_fig11.json` snapshot;
//! `--gate PATH` (or `LAMPS_BENCH_GATE`) reads the checked-in
//! conservative floor and fails if the learned-vs-static improvement at
//! a gated error level fell below it.
//!
//! ```sh
//! cargo bench --bench fig11_error_injection -- \
//!     --gate "$PWD/../BENCH_fig11.json" \
//!     --json "$PWD/../BENCH_fig11.fresh.json"
//! ```
//!
//! `LAMPS_REQUESTS` shrinks the trace for CI smoke runs (250 is the
//! paper-fidelity default).

use lamps::bench::{improvement_pct, write_bench_json, Dataset,
                   ModelPreset};
use lamps::config::{ApiPredKind, PredictorKind, SystemConfig};
use lamps::core::types::Tokens;
use lamps::engine::Engine;
use lamps::util::json::{self, Value};

const ERROR_LEVELS: [f64; 5] = [0.0, 0.05, 0.10, 0.30, 0.50];
/// Error levels where learned must strictly beat static (the PR's
/// acceptance criterion, kept honest on every comparison run).
const GATED_LEVELS: [f64; 2] = [0.30, 0.50];
/// Rate axis of the comparison grid (mid/high load, where duration
/// mispredictions actually move strategy choices and queue order).
const COMPARE_RATES: [f64; 2] = [6.0, 8.0];
/// Rate axis of the classic single-mode table.
const TABLE_RATES: [f64; 4] = [4.0, 6.0, 8.0, 10.0];

fn run_cell(error_pct: f64, rate: f64, n: usize, pred: ApiPredKind)
            -> lamps::metrics::RunReport {
    let trace = Dataset::MultiApi.generate(n, rate, 42);
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = ModelPreset::GptJ6b.cost();
    cfg.memory_budget = Tokens(12_000);
    cfg.predictor = if error_pct == 0.0 {
        PredictorKind::Oracle
    } else {
        PredictorKind::NoisyOracle { error_pct }
    };
    cfg.api_pred = pred;
    Engine::simulated(cfg).run_trace(&trace)
}

fn requests() -> usize {
    std::env::var("LAMPS_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250)
}

/// The classic Fig 11 table under one seam mode.
fn table_mode(pred: ApiPredKind, n: usize) {
    println!("fig11 (api-pred {}): {n} requests", pred.label());
    println!("{:>6} {:>5} {:>12} {:>12} {:>10}", "err%", "rate",
             "lat_mean(s)", "lat_p50(s)", "thr(r/s)");
    for error_pct in ERROR_LEVELS {
        for rate in TABLE_RATES {
            let report = run_cell(error_pct, rate, n, pred);
            println!("{:>6.0} {:>5.1} {:>12.3} {:>12.3} {:>10.3}",
                     error_pct * 100.0, rate,
                     report.latency.mean_secs(),
                     report.latency.p50_us / 1e6,
                     report.throughput_rps);
        }
    }
}

fn arg_or_env(args: &[String], flag: &str, env: &str)
              -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var(env).ok())
}

/// `err_30`-style stable JSON key for an error level.
fn level_key(error_pct: f64) -> String {
    format!("err_{:02.0}", error_pct * 100.0)
}

fn gate_value(v: &Value, section: &str, key: &str) -> Option<f64> {
    v.get(section)?.get(key)?.as_f64()
}

/// Learned-vs-static comparison grid + asserts + gate/json plumbing.
fn compare_mode(n: usize) {
    let args: Vec<String> = std::env::args().collect();
    let mut failed = false;

    println!("fig11 learned-vs-static (rates {COMPARE_RATES:?}, \
              {n} requests)");
    println!("{:>6} {:>5} {:>14} {:>14} {:>9}", "err%", "rate",
             "static_mean(s)", "learned_mean(s)", "gain%");

    // (error level, static mean us, learned mean us) averaged over the
    // rate axis — one sample per rate keeps seed luck from deciding
    // the strict asserts below.
    let mut levels: Vec<(f64, f64, f64)> = Vec::new();
    for error_pct in ERROR_LEVELS {
        let (mut s_sum, mut l_sum) = (0.0f64, 0.0f64);
        for rate in COMPARE_RATES {
            let s = run_cell(error_pct, rate, n, ApiPredKind::Static);
            let l = run_cell(error_pct, rate, n, ApiPredKind::Learned);
            println!("{:>6.0} {:>5.1} {:>14.3} {:>14.3} {:>9.2}",
                     error_pct * 100.0, rate,
                     s.latency.mean_secs(), l.latency.mean_secs(),
                     improvement_pct(l.latency.mean_us,
                                     s.latency.mean_us));
            s_sum += s.latency.mean_us;
            l_sum += l.latency.mean_us;
        }
        let s_mean = s_sum / COMPARE_RATES.len() as f64;
        let l_mean = l_sum / COMPARE_RATES.len() as f64;
        println!("{:>6.0} {:>5} {:>14.3} {:>14.3} {:>9.2}",
                 error_pct * 100.0, "avg", s_mean / 1e6, l_mean / 1e6,
                 improvement_pct(l_mean, s_mean));
        levels.push((error_pct, s_mean, l_mean));
    }

    // -- Acceptance criteria ----------------------------------------
    for &(error_pct, s_mean, l_mean) in &levels {
        if error_pct == 0.0 && (l_mean - s_mean).abs() > f64::EPSILON {
            // The exact oracle's error is identically zero, so the
            // estimators never heat up and learned must sit exactly on
            // the static path.
            eprintln!("FAIL: at 0% error learned ({l_mean:.1}us) must \
                       match static ({s_mean:.1}us)");
            failed = true;
        }
        if GATED_LEVELS.contains(&error_pct) && l_mean >= s_mean {
            eprintln!("FAIL: at {:.0}% injected error learned mean \
                       completion ({:.1}us) must be strictly better \
                       than static ({:.1}us)",
                      error_pct * 100.0, l_mean, s_mean);
            failed = true;
        }
    }

    // -- Regression gate against the checked-in floor ---------------
    if let Some(path) = arg_or_env(&args, "--gate", "LAMPS_BENCH_GATE") {
        match std::fs::read_to_string(&path)
            .map_err(|e| e.to_string())
            .and_then(|text| {
                json::parse(&text).map_err(|e| e.to_string())
            }) {
            Ok(baseline) => {
                for error_pct in GATED_LEVELS {
                    let key = level_key(error_pct);
                    let Some(floor) =
                        gate_value(&baseline, &key, "improvement_pct")
                    else {
                        eprintln!("FAIL: baseline {path} is missing \
                                   {key}.improvement_pct");
                        failed = true;
                        continue;
                    };
                    let (_, s_mean, l_mean) = levels
                        .iter()
                        .copied()
                        .find(|&(e, _, _)| e == error_pct)
                        .expect("gated level was measured");
                    let gain = improvement_pct(l_mean, s_mean);
                    if gain < floor {
                        eprintln!(
                            "FAIL: {key} learned-vs-static gain \
                             {gain:.2}% fell below the checked-in \
                             floor {floor:.2}% from {path}");
                        failed = true;
                    } else {
                        println!("gate ok: {key} gain {gain:.2}% >= \
                                  floor {floor:.2}%");
                    }
                }
            }
            Err(e) => {
                eprintln!("FAIL: cannot read gate baseline {path}: {e}");
                failed = true;
            }
        }
    }

    // -- Perf-trajectory snapshot -----------------------------------
    if let Some(path) = arg_or_env(&args, "--json", "LAMPS_BENCH_JSON") {
        let mut body = vec![
            ("requests", json::num(n as f64)),
            ("rates", Value::Arr(
                COMPARE_RATES.iter().map(|&r| json::num(r)).collect())),
        ];
        let keys: Vec<String> = levels
            .iter()
            .map(|&(e, _, _)| level_key(e))
            .collect();
        for (key, &(_, s_mean, l_mean)) in keys.iter().zip(&levels) {
            body.push((key.as_str(), json::obj(vec![
                ("static_mean_us", json::num(s_mean)),
                ("learned_mean_us", json::num(l_mean)),
                ("improvement_pct",
                 json::num(improvement_pct(l_mean, s_mean))),
            ])));
        }
        match write_bench_json(&path, "fig11_error_injection", body) {
            Ok(()) => eprintln!("bench json written to {path}"),
            Err(e) => {
                eprintln!("FAIL: cannot write bench json {path}: {e}");
                failed = true;
            }
        }
    }

    if failed {
        std::process::exit(1);
    }
}

fn main() {
    let n = requests();
    match std::env::var("LAMPS_API_PRED").as_deref() {
        Ok("static") => table_mode(ApiPredKind::Static, n),
        Ok("learned") => table_mode(ApiPredKind::Learned, n),
        _ => compare_mode(n),
    }
}
