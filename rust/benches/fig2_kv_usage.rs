//! Fig 2: impact of API calls. (a) KV-cache usage over time with all
//! calls handled by Preserve, with-API vs without-API variants of the
//! single-API dataset; (b)/(c) completed requests over time under
//! Preserve vs Discard.
use lamps::bench::{Dataset, ModelPreset};
use lamps::config::{HandlingPolicy, SystemConfig};
use lamps::core::request::HandlingStrategy;
use lamps::core::types::Tokens;
use lamps::engine::Engine;
use lamps::metrics::RunReport;
use lamps::workload::infercept;

fn run(trace: &lamps::workload::Trace,
       handling: HandlingPolicy) -> RunReport {
    let mut cfg = SystemConfig::preset("lamps-no-sched").unwrap();
    cfg.cost = ModelPreset::GptJ6b.cost();
    cfg.memory_budget = Tokens(12_000);
    cfg.handling = handling;
    let mut engine = Engine::simulated(cfg);
    engine.record_timeline = true;
    engine.run_trace(trace)
}

fn series(label: &str, report: &RunReport) {
    println!("\n-- {label}: time(s)  kv%  completed --");
    let step = (report.timeline.len() / 24).max(1);
    for point in report.timeline.iter().step_by(step) {
        println!("{:>8.1} {:>6.1} {:>6}", point.at.as_secs_f64(),
                 point.kv_occupancy * 100.0, point.completed);
    }
}

fn main() {
    let with_api = Dataset::SingleApi.generate(150, 4.0, 42);
    let without_api = infercept::strip_api_calls(&with_api);
    let preserve = HandlingPolicy::Forced(HandlingStrategy::Preserve);
    let discard = HandlingPolicy::Forced(HandlingStrategy::Discard);

    let rep_with = run(&with_api, preserve);
    let rep_without = run(&without_api, preserve);
    let rep_discard = run(&with_api, discard);

    println!("== Fig 2a: KV usage, Preserve handling ==");
    series("with API calls", &rep_with);
    series("without API calls", &rep_without);
    println!("\n== Fig 2b/2c: completions, Preserve vs Discard ==");
    series("with API, Preserve", &rep_with);
    series("with API, Discard", &rep_discard);
    println!("\nsummary: preserve mean lat {:.1}s vs discard {:.1}s; \
              discard recomputed {} tokens",
             rep_with.latency.mean_secs(),
             rep_discard.latency.mean_secs(),
             rep_discard.tokens_recomputed);
}
