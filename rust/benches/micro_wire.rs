//! micro_wire: wire-layer hot-path microbench — inbound frame parsing
//! and outbound event encoding, typed zero-copy (`lamps::wire`) versus
//! the allocating `util::json` tree path it replaced.
//!
//! Three jobs in one binary:
//!
//! 1. **Correctness cross-check** (always): every corpus frame must
//!    encode byte-identically through both paths before anything is
//!    timed — a perf win that changes bytes is a protocol break.
//! 2. **Measurement**: frames/sec + allocations/frame for both paths,
//!    both directions, via a counting global allocator. The typed path
//!    must allocate strictly less and parse/encode strictly faster, or
//!    the bench exits non-zero (the PR's acceptance criterion, kept
//!    honest forever).
//! 3. **Perf trajectory**: `--json PATH` (or `LAMPS_BENCH_JSON`)
//!    writes the stable `BENCH_micro_wire.json` snapshot; `--gate
//!    PATH` (or `LAMPS_BENCH_GATE`) reads a checked-in snapshot and
//!    fails if typed frames/sec regressed more than 20% against it.
//!
//! ```sh
//! cargo bench --bench micro_wire -- \
//!     --gate "$PWD/../BENCH_micro_wire.json" \
//!     --json "$PWD/../BENCH_micro_wire.fresh.json"
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use lamps::util::json::{self, Value};
use lamps::wire::{CompletionFrame, Encoder, EventFrame, Frame};

/// System allocator with an allocation counter — `alloc`/`realloc`
/// calls are the "allocations" the zero-copy claim is about.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout,
                      new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

// -------------------------------------------------------------------
// Inbound: typed zero-copy parse vs Value-tree parse + field walk
// -------------------------------------------------------------------

/// The connection-realistic inbound mix: v2 requests (single- and
/// multi-call), tool results (the per-call hot frame), a v1 one-shot,
/// and an escape-heavy request (the lexer's owned slow path).
fn inbound_corpus() -> Vec<String> {
    vec![
        "{\"type\":\"request\",\"prompt\":\"what is 6 times 7?\",\
         \"output_tokens\":4,\"api_calls\":[{\"decode_before\":2,\
         \"api_type\":\"math\",\"response_tokens\":2}]}"
            .to_string(),
        "{\"type\":\"request\",\"prompt\":\"plan my trip through three \
         connecting flights and check the weather at each stop\",\
         \"output_tokens\":40,\"api_calls\":[\
         {\"decode_before\":5,\"api_type\":\"qa\",\"api_ms\":700,\
         \"response_tokens\":32},\
         {\"decode_before\":9,\"api_type\":\"image\"},\
         {\"decode_before\":14,\"api_type\":\"tool\",\
         \"response_tokens\":8}]}"
            .to_string(),
        "{\"type\":\"tool_result\",\"id\":3,\"index\":0,\
         \"response_tokens\":2}"
            .to_string(),
        "{\"type\":\"tool_result\",\"id\":12345,\"index\":2,\
         \"response_tokens\":64}"
            .to_string(),
        "{\"prompt\":\"legacy one-shot\",\"output_tokens\":5,\
         \"pre_api_tokens\":2,\"api_ms\":30}"
            .to_string(),
        "{\"type\":\"request\",\"prompt\":\"escape \\\"heavy\\\" \
         \\\\ prompt\\nwith\\ttabs and \\u20ac signs\",\
         \"output_tokens\":6,\"api_calls\":[]}"
            .to_string(),
    ]
}

/// The pre-wire inbound path: `json::parse` into the `Value` tree,
/// then the field walk `server/mod.rs` used to run (prompt/
/// output_tokens/api_calls for requests, id/index/response_tokens for
/// tool results). Returns a checksum so the work can't be optimized
/// out.
fn old_parse(line: &str) -> u64 {
    let v = json::parse(line).expect("corpus lines are valid");
    match v.get("type").and_then(|t| t.as_str()) {
        Some("tool_result") => {
            v.u64_field("id").expect("id")
                + v.u64_field("index").expect("index")
                + v.u64_field("response_tokens").expect("tokens")
        }
        _ => {
            let prompt = v.str_field("prompt").expect("prompt");
            let output = v.u64_field("output_tokens").expect("tokens");
            let calls: u64 = match v.get("api_calls") {
                Some(calls) => calls
                    .as_arr()
                    .expect("array")
                    .iter()
                    .map(|c| {
                        c.u64_field("decode_before").expect("before")
                            + c.get("api_ms")
                                .and_then(|x| x.as_u64())
                                .unwrap_or(0)
                            + c.get("response_tokens")
                                .and_then(|x| x.as_u64())
                                .unwrap_or(4)
                    })
                    .sum(),
                None => {
                    // Legacy v1 synthesis: one implicit call.
                    let pre = v
                        .get("pre_api_tokens")
                        .and_then(|x| x.as_u64())
                        .unwrap_or(0);
                    if pre > 0 {
                        pre + v
                            .get("api_ms")
                            .and_then(|x| x.as_u64())
                            .unwrap_or(0)
                            + 4
                    } else {
                        0
                    }
                }
            };
            prompt.len() as u64 + output + calls
        }
    }
}

/// The typed zero-copy path, reduced to the same checksum.
fn new_parse(line: &str) -> u64 {
    match Frame::parse(line).expect("corpus lines are valid") {
        Frame::Request(r) | Frame::V1Request(r) => {
            r.prompt.len() as u64
                + r.output_tokens
                + r.api_calls
                    .iter()
                    .map(|c| {
                        c.decode_before
                            + c.api_ms.unwrap_or(0)
                            + c.response_tokens
                    })
                    .sum::<u64>()
        }
        Frame::ToolResult(t) => t.id + t.index + t.response_tokens,
        Frame::Cancel(c) => c.id,
    }
}

// -------------------------------------------------------------------
// Outbound: typed encoder vs Value-tree build + json::write
// -------------------------------------------------------------------

const GENERATED: [i32; 4] = [11, 7, -3, 42];

/// The streaming-heavy outbound mix (tokens frames dominate a real
/// session, so they dominate here too).
fn outbound_corpus() -> Vec<EventFrame<'static>> {
    let finished = CompletionFrame {
        id: 7,
        latency_us: 27_384,
        ttft_us: Some(812),
        tokens_decoded: 6,
        generated: Some(&GENERATED),
        dropped: None,
    };
    vec![
        EventFrame::Queued { id: 7 },
        EventFrame::Placed { id: 7, replica: 2 },
        EventFrame::FirstToken { id: 7 },
        EventFrame::Tokens { id: 7, chunk: 1 },
        EventFrame::Tokens { id: 7, chunk: 1 },
        EventFrame::Tokens { id: 7, chunk: 2 },
        EventFrame::Tokens { id: 7, chunk: 4 },
        EventFrame::ApiCallStarted {
            id: 7,
            index: 0,
            strategy: "preserve",
            predicted_us: 90,
            external: true,
        },
        EventFrame::ApiCallCompleted {
            id: 7,
            index: 0,
            actual_us: 25_310,
        },
        EventFrame::Finished(finished),
    ]
}

/// Rebuild one outbound frame the pre-wire way: a fresh `Value` tree
/// (BTreeMap per frame) serialized by `json::write` — exactly what
/// `RequestEvent::to_json` did before the typed encoder.
fn old_encode(frame: &EventFrame<'_>) -> String {
    let v = match frame {
        EventFrame::Queued { id } => json::obj(vec![
            ("type", json::s("queued")),
            ("id", json::num(*id as f64)),
        ]),
        EventFrame::Placed { id, replica } => json::obj(vec![
            ("type", json::s("placed")),
            ("id", json::num(*id as f64)),
            ("replica", json::num(*replica as f64)),
        ]),
        EventFrame::FirstToken { id } => json::obj(vec![
            ("type", json::s("first_token")),
            ("id", json::num(*id as f64)),
        ]),
        EventFrame::Tokens { id, chunk } => json::obj(vec![
            ("type", json::s("tokens")),
            ("id", json::num(*id as f64)),
            ("chunk", json::num(*chunk as f64)),
        ]),
        EventFrame::ApiCallStarted {
            id,
            index,
            strategy,
            predicted_us,
            external,
        } => json::obj(vec![
            ("type", json::s("api_call_started")),
            ("id", json::num(*id as f64)),
            ("index", json::num(*index as f64)),
            ("strategy", json::s(strategy)),
            ("predicted_us", json::num(*predicted_us as f64)),
            ("external", Value::Bool(*external)),
        ]),
        EventFrame::ApiCallCompleted { id, index, actual_us } => {
            json::obj(vec![
                ("type", json::s("api_call_completed")),
                ("id", json::num(*id as f64)),
                ("index", json::num(*index as f64)),
                ("actual_us", json::num(*actual_us as f64)),
            ])
        }
        EventFrame::Finished(c) => {
            let mut v = json::obj(vec![
                ("id", json::num(c.id as f64)),
                ("latency_us", json::num(c.latency_us as f64)),
                ("tokens_decoded", json::num(c.tokens_decoded as f64)),
                ("ttft_us", match c.ttft_us {
                    Some(t) => json::num(t as f64),
                    None => Value::Null,
                }),
                ("generated", match c.generated {
                    Some(toks) => Value::Arr(
                        toks.iter()
                            .map(|t| json::num(*t as f64))
                            .collect()),
                    None => Value::Null,
                }),
            ]);
            if let Value::Obj(map) = &mut v {
                map.insert("type".to_string(), json::s("finished"));
            }
            v
        }
        other => panic!("corpus has no old-path shape for {other:?}"),
    };
    json::write(&v)
}

// -------------------------------------------------------------------
// Harness
// -------------------------------------------------------------------

struct Measured {
    per_sec: f64,
    allocs_per_frame: f64,
}

/// Time `iters` passes of `work` over a `corpus_len`-frame corpus,
/// returning frames/sec and allocations/frame.
fn measure<F: FnMut() -> u64>(iters: u64, corpus_len: usize,
                              mut work: F) -> Measured {
    // Warmup pass (fills allocator caches, faults in code).
    let mut sink = work();
    let a0 = allocs();
    let t0 = Instant::now();
    for _ in 0..iters {
        sink = sink.wrapping_add(work());
    }
    let elapsed = t0.elapsed();
    let da = allocs() - a0;
    std::hint::black_box(sink);
    let frames = iters * corpus_len as u64;
    let secs = elapsed.as_secs_f64().max(1e-9);
    Measured {
        per_sec: frames as f64 / secs,
        allocs_per_frame: da as f64 / frames as f64,
    }
}

fn arg_or_env(args: &[String], flag: &str, env: &str)
              -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var(env).ok())
}

fn gate_value(v: &Value, section: &str, key: &str) -> Option<f64> {
    v.get(section)?.get(key)?.as_f64()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let iters: u64 = std::env::var("LAMPS_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);

    let inbound = inbound_corpus();
    let outbound = outbound_corpus();

    // -- Correctness before speed -----------------------------------
    // Typed parse must accept every corpus line the tree parser
    // accepts (checksums agree)...
    for line in &inbound {
        assert_eq!(new_parse(line), old_parse(line),
                   "parse divergence on {line}");
    }
    // ...and the typed encoder must be byte-identical to the old
    // writer on every outbound frame.
    for frame in &outbound {
        assert_eq!(Encoder::frame_to_string(frame), old_encode(frame),
                   "encode divergence on {frame:?}");
    }

    // -- Inbound ----------------------------------------------------
    let old_in = measure(iters, inbound.len(), || {
        inbound.iter().map(|l| old_parse(l)).sum()
    });
    let new_in = measure(iters, inbound.len(), || {
        inbound.iter().map(|l| new_parse(l)).sum()
    });

    // -- Outbound ---------------------------------------------------
    let old_out = measure(iters, outbound.len(), || {
        outbound
            .iter()
            .map(|f| old_encode(f).len() as u64)
            .sum()
    });
    let mut enc = Encoder::with_capacity(4096);
    let new_out = measure(iters, outbound.len(), || {
        for f in &outbound {
            enc.push(f);
        }
        let n = enc.len() as u64;
        enc.clear();
        n
    });

    println!("== micro_wire ({} frames/pass, {iters} passes) ==",
             inbound.len() + outbound.len());
    println!("{:<26} {:>14} {:>14}", "path", "frames/s", "allocs/frame");
    println!("{:<26} {:>14.0} {:>14.3}", "inbound  util::json",
             old_in.per_sec, old_in.allocs_per_frame);
    println!("{:<26} {:>14.0} {:>14.3}", "inbound  wire (typed)",
             new_in.per_sec, new_in.allocs_per_frame);
    println!("{:<26} {:>14.0} {:>14.3}", "outbound util::json",
             old_out.per_sec, old_out.allocs_per_frame);
    println!("{:<26} {:>14.0} {:>14.3}", "outbound wire (typed)",
             new_out.per_sec, new_out.allocs_per_frame);

    // -- Acceptance criteria, kept honest on every run --------------
    let mut failed = false;
    if new_in.allocs_per_frame >= old_in.allocs_per_frame {
        eprintln!("FAIL: typed inbound parse must allocate strictly \
                   less ({:.3} vs {:.3})",
                  new_in.allocs_per_frame, old_in.allocs_per_frame);
        failed = true;
    }
    if new_out.allocs_per_frame >= old_out.allocs_per_frame {
        eprintln!("FAIL: typed outbound encode must allocate strictly \
                   less ({:.3} vs {:.3})",
                  new_out.allocs_per_frame, old_out.allocs_per_frame);
        failed = true;
    }
    if new_in.per_sec <= old_in.per_sec {
        eprintln!("FAIL: typed inbound parse must be faster \
                   ({:.0} vs {:.0} frames/s)",
                  new_in.per_sec, old_in.per_sec);
        failed = true;
    }
    if new_out.per_sec <= old_out.per_sec {
        eprintln!("FAIL: typed outbound encode must be faster \
                   ({:.0} vs {:.0} events/s)",
                  new_out.per_sec, old_out.per_sec);
        failed = true;
    }

    // -- Regression gate against the checked-in baseline ------------
    if let Some(path) = arg_or_env(&args, "--gate", "LAMPS_BENCH_GATE") {
        match std::fs::read_to_string(&path)
            .map_err(|e| e.to_string())
            .and_then(|text| {
                json::parse(&text).map_err(|e| e.to_string())
            }) {
            Ok(baseline) => {
                let checks = [
                    ("inbound", "frames_per_sec", new_in.per_sec),
                    ("outbound", "events_per_sec", new_out.per_sec),
                ];
                for (section, key, measured) in checks {
                    let Some(base) =
                        gate_value(&baseline, section, key)
                    else {
                        eprintln!("FAIL: baseline {path} is missing \
                                   {section}.{key}");
                        failed = true;
                        continue;
                    };
                    let floor = base * 0.8;
                    if measured < floor {
                        eprintln!(
                            "FAIL: {section} {key} {measured:.0} \
                             regressed >20% vs baseline {base:.0} \
                             (floor {floor:.0}) from {path}");
                        failed = true;
                    } else {
                        println!(
                            "gate ok: {section} {key} {measured:.0} \
                             >= floor {floor:.0}");
                    }
                }
            }
            Err(e) => {
                eprintln!("FAIL: cannot read gate baseline {path}: {e}");
                failed = true;
            }
        }
    }

    // -- Perf-trajectory snapshot -----------------------------------
    if let Some(path) = arg_or_env(&args, "--json", "LAMPS_BENCH_JSON") {
        let body = vec![
            ("iters", json::num(iters as f64)),
            ("inbound", json::obj(vec![
                ("frames_per_sec", json::num(new_in.per_sec)),
                ("frames_per_sec_baseline", json::num(old_in.per_sec)),
                ("allocs_per_frame", json::num(new_in.allocs_per_frame)),
                ("allocs_per_frame_baseline",
                 json::num(old_in.allocs_per_frame)),
            ])),
            ("outbound", json::obj(vec![
                ("events_per_sec", json::num(new_out.per_sec)),
                ("events_per_sec_baseline", json::num(old_out.per_sec)),
                ("allocs_per_event", json::num(new_out.allocs_per_frame)),
                ("allocs_per_event_baseline",
                 json::num(old_out.allocs_per_frame)),
            ])),
        ];
        match lamps::bench::write_bench_json(&path, "micro_wire", body) {
            Ok(()) => eprintln!("bench json written to {path}"),
            Err(e) => {
                eprintln!("FAIL: cannot write bench json {path}: {e}");
                failed = true;
            }
        }
    }

    if failed {
        std::process::exit(1);
    }
}
