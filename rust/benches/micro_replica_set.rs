//! Before/after numbers for multi-replica dispatch
//! (`cluster::ReplicaSet`): a skewed augmented-LLM trace — every fourth
//! request is a heavy long-prompt, long-API job, the rest are light
//! chat turns — served by 4 replicas under each placement policy.
//!
//! The skew period matches the round-robin rotation, so round-robin
//! lands every heavy request on replica 0 (the classic failure mode of
//! oblivious placement under periodic traffic); memory-over-time
//! placement sees the heavy requests' rank integrals and spreads them.
//!
//! Acceptance (asserted, not just printed): memory-over-time placement
//! beats round-robin on mean completion time, completes the same
//! requests, and actually spreads the heavy jobs across replicas.

use lamps::cluster::{FleetReport, ReplicaSet};
use lamps::config::{PlacementKind, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::workload::Trace;

const N_REQUESTS: u64 = 48;
const REPLICAS: usize = 4;
/// Per-replica KV budget in token slots (one modeled GPU each).
const BUDGET: u64 = 6_000;

/// One request every 150 ms; ids divisible by 4 are heavy (2500-token
/// prompt, 200 decodes into a 20 s API, 100 more after), the rest light
/// (64-token prompt, 32 decodes, no API).
fn workload() -> Trace {
    let specs = (0..N_REQUESTS)
        .map(|i| {
            let heavy = i % 4 == 0;
            let (prompt_tokens, api_calls, final_decode) = if heavy {
                (Tokens(2_500),
                 vec![ApiCallSpec {
                     decode_before: Tokens(200),
                     api_type: ApiType::Image,
                     duration: Micros(20_000_000),
                     response_tokens: Tokens(8),
                 }],
                 Tokens(100))
            } else {
                (Tokens(64), vec![], Tokens(32))
            };
            RequestSpec {
                id: RequestId(i),
                arrival: Micros(i * 150_000),
                prompt: String::new(),
                prompt_tokens,
                api_calls,
                final_decode,
            }
        })
        .collect();
    Trace::new("skewed-augmented", 1.0 / 0.15, specs)
}

/// Run the fleet under one placement policy; returns the report plus
/// how many heavy requests each replica received.
fn run(placement: PlacementKind) -> (FleetReport, Vec<usize>) {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.memory_budget = Tokens(BUDGET);
    cfg.replicas = REPLICAS;
    cfg.placement = placement;
    // This bench isolates what each *placement policy* does with the
    // skewed trace; the admission re-queue would quietly fix
    // round-robin's pile-up after the fact and blur the comparison.
    cfg.admission_requeue = false;
    let mut set = ReplicaSet::simulated(cfg);
    let report = set.run_trace(&workload());
    let mut heavy = vec![0usize; REPLICAS];
    for (id, r) in set.assignments() {
        if id.0 % 4 == 0 {
            heavy[*r] += 1;
        }
    }
    (report, heavy)
}

fn main() {
    println!("== micro_replica_set: {N_REQUESTS} requests (1 in 4 \
              heavy) on {REPLICAS} replicas of {BUDGET} token slots ==");
    let (rr, rr_heavy) = run(PlacementKind::RoundRobin);
    let (ll, ll_heavy) = run(PlacementKind::LeastLoaded);
    let (mot, mot_heavy) = run(PlacementKind::MemoryOverTime);

    let row = |name: &str, r: &FleetReport, heavy: &[usize]| {
        let per: Vec<usize> =
            r.per_replica.iter().map(|p| p.completed).collect();
        println!("{name:<18} mean latency {:>8.3}s  p99 {:>8.3}s  \
                  done {:>2}  per-replica {per:?}  heavy {heavy:?}",
                 r.fleet.latency.mean_secs(), r.fleet.latency.p99_secs(),
                 r.fleet.completed);
    };
    row("round-robin", &rr, &rr_heavy);
    row("least-loaded", &ll, &ll_heavy);
    row("memory-over-time", &mot, &mot_heavy);

    for (name, r) in [("round-robin", &rr), ("least-loaded", &ll),
                      ("memory-over-time", &mot)] {
        assert_eq!(r.fleet.completed, N_REQUESTS as usize,
                   "{name} must complete every request");
    }
    // The skew period matches the rotation: round-robin stacks every
    // heavy request on replica 0.
    assert_eq!(rr_heavy, vec![12, 0, 0, 0],
               "round-robin heavy placement {rr_heavy:?}");
    // Memory-over-time placement must actually spread the heavy jobs...
    assert!(*mot_heavy.iter().max().unwrap() < 12,
            "memory-over-time heavy placement {mot_heavy:?}");
    assert!(mot_heavy.iter().filter(|&&c| c > 0).count() >= 2,
            "memory-over-time heavy placement {mot_heavy:?}");
    // ...and beat round-robin on mean completion time (the acceptance
    // criterion of the multi-replica dispatch PR).
    assert!(mot.fleet.latency.mean_us < rr.fleet.latency.mean_us,
            "memory-over-time mean {} must beat round-robin mean {}",
            mot.fleet.latency.mean_us, rr.fleet.latency.mean_us);
}
