//! Fig 10: component breakdown on the multi-API dataset with Vicuna 13B:
//! vLLM -> vLLM + predicted handling (FCFS; "LAMPS w/o scheduling") ->
//! full LAMPS, vs INFERCEPT. The paper: handling alone lands close to
//! INFERCEPT; the scheduling policy delivers the main gains.
use lamps::bench::{print_cells, run_cell, Cell, Dataset, ModelPreset,
                   BREAKDOWN_SYSTEMS};

fn main() {
    let mut cells: Vec<Cell> = Vec::new();
    for system in BREAKDOWN_SYSTEMS {
        cells.push(run_cell(system, Dataset::MultiApi,
                            ModelPreset::Vicuna13b, 5.0, 300, 42, None));
    }
    print_cells("Fig 10 — breakdown of LAMPS components (multi-API, \
                 Vicuna 13B)", &cells);
}
