//! Before/after numbers for cross-replica prefix sharing
//! (`--shared-prefix` + `--placement prefix-affinity`): four request
//! families, each sharing a long system-prompt prefix, arrive in
//! pseudo-random order at a 4-replica fleet with per-replica prefix
//! caches on.
//!
//! Memory-over-time placement is blind to where a family's prefix
//! lives: members scatter across replicas and every (family, replica)
//! first encounter re-prefills the whole prompt. Prefix-affinity
//! placement probes the fleet's shared hash→replica index and discounts
//! the prefill leg of the rank integral on replicas that already hold
//! the prefix, so families converge onto their prefix's home replicas.
//!
//! Acceptance (asserted, not just printed): at 4 replicas on this
//! shared-prefix trace, prefix-affinity placement prefills **strictly
//! fewer** tokens than memory-over-time placement, completes the same
//! requests, and reports non-zero steered tokens (while the index under
//! memory-over-time placement steers nothing).

use lamps::cluster::{FleetReport, ReplicaSet};
use lamps::config::{PlacementKind, PrefixCacheConfig, SystemConfig};
use lamps::core::request::RequestSpec;
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::util::Rng;
use lamps::workload::Trace;

const N_REQUESTS: u64 = 64;
const REPLICAS: usize = 4;
const SHARED_PREFIX_CHARS: usize = 3072;

/// Four distinct shared prompt prefixes (system prompts / few-shot
/// templates), cycled to length.
fn family_prefix(family: usize) -> String {
    const SEEDS: [&str; 4] = [
        "You are a terse assistant for database migrations. ",
        "Translate the user's request into SQL, then explain. ",
        "Summarize the following support ticket for triage. ",
        "Act as a code reviewer; list defects then nitpicks. ",
    ];
    SEEDS[family % 4]
        .chars()
        .cycle()
        .take(SHARED_PREFIX_CHARS)
        .collect()
}

/// Pseudo-random family choice and 40-90 ms spacing (fixed seed): the
/// arrival order carries no periodic pattern a placement policy could
/// exploit by accident — only the prompt *content* identifies a family.
fn workload() -> Trace {
    let mut rng = Rng::new(0x5AFE_CAFE);
    let mut t = 0u64;
    let specs = (0..N_REQUESTS)
        .map(|i| {
            t += rng.int_range(40_000, 90_000);
            let family = rng.int_range(0, 3) as usize;
            let prompt = format!("{}user-{i:04}", family_prefix(family));
            let prompt_tokens = Tokens(prompt.len() as u64);
            RequestSpec {
                id: RequestId(i),
                arrival: Micros(t),
                prompt,
                prompt_tokens,
                api_calls: vec![],
                final_decode: Tokens(6),
            }
        })
        .collect();
    Trace::new("shared-prefix-fleet", 1.0 / 0.065, specs)
}

fn run(placement: PlacementKind) -> FleetReport {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.replicas = REPLICAS;
    cfg.placement = placement;
    cfg.prefix_cache = PrefixCacheConfig::on();
    cfg.shared_prefix = true;
    let mut set = ReplicaSet::simulated(cfg);
    set.run_trace(&workload())
}

fn main() {
    println!("== micro_shared_prefix: {N_REQUESTS} requests in 4 \
              families sharing {SHARED_PREFIX_CHARS}-token prompt \
              prefixes, {REPLICAS} replicas, shared index on ==");
    let mot = run(PlacementKind::MemoryOverTime);
    let aff = run(PlacementKind::PrefixAffinity);

    let row = |name: &str, r: &FleetReport| {
        let hits: Vec<u64> =
            r.per_replica.iter().map(|p| p.prefix_hit_tokens).collect();
        let steered = r
            .shared_prefix
            .as_ref()
            .map(|s| s.steered_tokens)
            .unwrap_or(0);
        println!("{name:<18} prefilled {:>7}  hit {:>7}  steered {:>7}  \
                  mean latency {:>7.3}s  done {:>2}  per-replica hits \
                  {hits:?}",
                 r.fleet.tokens_prefilled, r.fleet.prefix_hit_tokens,
                 steered, r.fleet.latency.mean_secs(),
                 r.fleet.completed);
    };
    row("memory-over-time", &mot);
    row("prefix-affinity", &aff);

    assert_eq!(mot.fleet.completed, N_REQUESTS as usize);
    assert_eq!(aff.fleet.completed, N_REQUESTS as usize,
               "placement must not change completions");
    // The acceptance criterion: steering by the shared index must save
    // real prefill work, not just shuffle it.
    assert!(aff.fleet.tokens_prefilled < mot.fleet.tokens_prefilled,
            "prefix-affinity must prefill strictly fewer tokens than \
             memory-over-time ({} vs {})",
            aff.fleet.tokens_prefilled, mot.fleet.tokens_prefilled);
    assert!(aff.fleet.prefix_hit_tokens > mot.fleet.prefix_hit_tokens,
            "the saved prefill must show up as cross-request hits \
             ({} vs {})",
            aff.fleet.prefix_hit_tokens, mot.fleet.prefix_hit_tokens);
    let steered = aff
        .shared_prefix
        .as_ref()
        .expect("shared index active")
        .steered_tokens;
    assert!(steered > 0, "affinity placement must report steering");
    assert_eq!(mot.shared_prefix.as_ref().unwrap().steered_tokens, 0,
               "memory-over-time placement never consults the index");
}
