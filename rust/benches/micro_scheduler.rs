//! Micro-benchmarks of the scheduler hot paths (criterion is not in the
//! offline vendor set; timing is hand-rolled over many iterations).
//! §Perf target: a full LAMPS ranking pass over 10k waiting requests
//! must stay well under one decode iteration (~10 ms).
use std::time::Instant;

use lamps::config::{CostModel, SchedulerKind};
use lamps::coordinator::handling::{select_strategy, WasteInputs};
use lamps::coordinator::ranking::{memory_over_time, RankInputs};
use lamps::coordinator::scheduler::{make_scheduler, ScheduleContext,
                                    Score};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::kv::BlockManager;
use lamps::predictor::oracle::OraclePredictor;
use lamps::predictor::Predictor;
use lamps::workload::infercept;

fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<44} {:>12.0} ns/iter", per);
}

fn main() {
    let trace = infercept::multi_api_dataset(10_000, 3.0, 42);
    let mut oracle = OraclePredictor;
    let requests: Vec<_> = trace
        .requests
        .iter()
        .map(|spec| {
            let preds = oracle.predict(spec);
            let handling =
                vec![lamps::core::request::HandlingStrategy::Preserve;
                     spec.api_calls.len()];
            lamps::core::request::Request::new(spec.clone(), preds,
                                               handling)
        })
        .collect();
    let cost = CostModel::paper_scale();
    let ctx = ScheduleContext {
        cost,
        t_iter_est: Micros(12_000),
        c_other_est: Tokens(6_000),
        iteration: 0,
        account_prefill: false,
        prefix_cached_block: None,
    };

    let lamps_sched = make_scheduler(SchedulerKind::Lamps);
    bench("lamps score: one request", 100_000, || {
        std::hint::black_box(lamps_sched.score(&requests[0], &ctx));
    });
    bench("lamps ranking pass: 10k requests", 100, || {
        let mut scores: Vec<(Score, RequestId)> = requests
            .iter()
            .map(|r| (lamps_sched.score(r, &ctx), r.spec.id))
            .collect();
        scores.sort_by(|a, b| a.0.cmp(&b.0));
        std::hint::black_box(scores.len());
    });
    bench("memory_over_time integral", 100_000, || {
        std::hint::black_box(memory_over_time(
            &requests[1], &cost,
            &RankInputs { t_iter: Micros(12_000),
                          c_other_est: Tokens(6_000),
                          account_prefill: false,
                          prefix_cached_block: None }));
    });
    bench("waste equations: select_strategy", 1_000_000, || {
        std::hint::black_box(select_strategy(
            &WasteInputs {
                ctx: Tokens(300),
                api_duration: Micros(700_000),
                c_other: Tokens(6_000),
                cached: Tokens::ZERO,
            },
            &cost));
    });
    bench("kv: alloc+append x16+free", 100_000, || {
        let mut m = BlockManager::new(Tokens(1024), 16);
        m.allocate(RequestId(1), Tokens(100)).unwrap();
        for _ in 0..16 {
            m.append_token(RequestId(1)).unwrap();
        }
        std::hint::black_box(m.free(RequestId(1)).unwrap());
    });
}
