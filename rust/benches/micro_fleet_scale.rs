//! micro_fleet_scale: fleet-scale sweep of the modeled network —
//! replicas ∈ {4, 16, 64, 256} × staleness (exact, gossip-lagged LAN,
//! LAN with a deliberately tight staleness budget) on a shared-prefix
//! burst workload sized per replica, so the work grows with the fleet
//! while per-replica pressure stays flat (PR 10).
//!
//! Three jobs in one binary, mirroring `micro_placement`:
//!
//! 1. **Correctness cross-check** (always): every run — exact and
//!    armed — must drain completely. Staleness may cost re-prefill,
//!    never a lost or stuck request.
//! 2. **Graceful degradation + O(k) probes** (always): at every fleet
//!    size the armed runs' mean completion time must stay within
//!    `DEGRADE_FACTOR`× the exact run plus `DEGRADE_SLACK_S` (the
//!    256-replica case is the PR's acceptance criterion), and the
//!    live placement probes issued under bounded staleness must stay
//!    under a constant per arrival — independent of the replica
//!    count — or the bench exits non-zero. A small autoscale smoke
//!    rides along: a diurnally retimed trace on a 1:16 elastic fleet
//!    must scale up at the crest and still drain.
//! 3. **Perf trajectory**: `--json PATH` (or `LAMPS_BENCH_JSON`)
//!    writes the stable `BENCH_micro_fleet.json` snapshot; `--gate
//!    PATH` (or `LAMPS_BENCH_GATE`) reads the checked-in snapshot —
//!    a conservative floor, not a measurement — and fails if armed
//!    steps/sec at 256 replicas falls below half of it.
//!
//! ```sh
//! cargo bench --bench micro_fleet_scale -- \
//!     --gate "$PWD/../BENCH_micro_fleet.json" \
//!     --json "$PWD/../BENCH_micro_fleet.fresh.json"
//! ```

use std::time::Instant;

use lamps::cluster::ReplicaSet;
use lamps::config::{AutoscaleConfig, NetModelKind, PlacementKind,
                    PrefixCacheConfig, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::metrics::NetStats;
use lamps::util::json::{self, Value};
use lamps::workload::{self, ArrivalProcess, Trace};

const REPLICA_COUNTS: [usize; 4] = [4, 16, 64, 256];
/// Requests per replica in the sweep trace (`LAMPS_FLEET_REQS`
/// overrides): the burst scales with the fleet, per-replica load
/// does not.
const REQS_PER_REPLICA: u64 = 4;
/// Shortlist size pinned explicitly so the probe bound below is
/// self-contained rather than inherited from a default.
const TOPK: usize = 4;
/// Per-replica KV budget in token slots — roomy enough that the sweep
/// measures gossip/placement overhead, not preemption storms.
const BUDGET: u64 = 2_000;
/// Graceful degradation: armed mean completion must stay within
/// `factor × exact + slack`. The additive slack keeps tiny absolute
/// latencies from blowing up the ratio.
const DEGRADE_FACTOR: f64 = 2.0;
const DEGRADE_SLACK_S: f64 = 0.25;
/// Live probes per placement are capped at O(topk); requeues and
/// rescue re-validations add a bounded number of extra placements per
/// request, so 3 placements × topk probes is a generous constant
/// ceiling — the point is that it does not scale with the replica
/// count.
const PROBE_PLACEMENTS_PER_REQ: u64 = 3;

/// Shared-prefix burst: `n × per_replica` requests over ~2 virtual
/// seconds regardless of fleet size, drawing prompts from a small
/// prefix pool (so gossip carries real `PrefixDelta` traffic) with a
/// sprinkling of short API calls (so replicas park and resume).
fn fleet_trace(n: usize, per_replica: u64) -> Trace {
    const PREFIXES: [&str; 4] = [
        "System: answer in one short paragraph and cite sources for \
         any external facts referenced in the reply body here. ",
        "System: you are a strict JSON transformer; never add prose \
         or commentary around the emitted document body at all. ",
        "System: translate the user's message to French, preserving \
         code spans and inline markup fragments fully verbatim. ",
        "System: summarize the thread in three bullets, keeping the \
         participants' own terminology wherever it is unambiguous. ",
    ];
    let m = (n as u64 * per_replica).max(1);
    let gap = (2_000_000 / m).max(1);
    let specs = (0..m)
        .map(|i| {
            let prefix = PREFIXES[(i % 4) as usize];
            let prompt = format!("{prefix}tail-{i:06}");
            let prompt_tokens = Tokens(prompt.len() as u64);
            let api_calls = if i % 5 == 0 {
                vec![ApiCallSpec {
                    decode_before: Tokens(4),
                    api_type: ApiType::Qa,
                    duration: Micros(40_000 + 10_000 * (i % 3)),
                    response_tokens: Tokens(2),
                }]
            } else {
                vec![]
            };
            RequestSpec {
                id: RequestId(i),
                arrival: Micros(i * gap),
                prompt,
                prompt_tokens,
                api_calls,
                final_decode: Tokens(8 + (i % 9)),
            }
        })
        .collect();
    Trace::new("fleet-scale", 1.0, specs)
}

struct RunOut {
    steps: u64,
    steps_per_sec: f64,
    mean_latency_s: f64,
    completed: usize,
    /// Live placement probes issued under bounded staleness (armed
    /// runs only).
    probes: Option<u64>,
    net: Option<NetStats>,
}

/// Drive one fleet over `trace` to quiesce, timing the step loop.
fn run_fleet(trace: &Trace, n: usize, model: NetModelKind,
             staleness: Option<Micros>,
             autoscale: Option<AutoscaleConfig>) -> RunOut {
    let mut cfg = SystemConfig::preset("lamps")
        .expect("lamps preset exists");
    cfg.replicas = n;
    cfg.placement = PlacementKind::LeastLoaded;
    cfg.memory_budget = Tokens(BUDGET);
    cfg.prefix_cache = PrefixCacheConfig::on();
    cfg.shared_prefix = true;
    cfg.net.model = model;
    cfg.net.topk = TOPK;
    if let Some(b) = staleness {
        cfg.net.staleness_budget = b;
    }
    cfg.net.autoscale = autoscale;
    let mut set = ReplicaSet::simulated(cfg);
    for spec in &trace.requests {
        set.enqueue(spec.clone());
    }
    let t0 = Instant::now();
    let mut steps = 0u64;
    while set.step() {
        steps += 1;
        assert!(steps < 50_000_000,
                "fleet-scale run failed to drain ({n} replicas, \
                 {model:?})");
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    let probes = set.net_state().map(|ns| ns.probes_issued());
    let report = set.fleet_report();
    RunOut {
        steps,
        steps_per_sec: steps as f64 / secs,
        mean_latency_s: report.fleet.latency.mean_secs(),
        completed: report.fleet.completed,
        probes,
        net: report.net,
    }
}

fn arg_or_env(args: &[String], flag: &str, env: &str)
              -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var(env).ok())
}

fn gate_value(v: &Value, section: &str, key: &str) -> Option<f64> {
    v.get(section)?.get(key)?.as_f64()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let per_replica: u64 = std::env::var("LAMPS_FLEET_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(REQS_PER_REPLICA);

    let mut failed = false;
    let mut sections: Vec<(String, Value)> = Vec::new();
    let mut armed_256: Option<f64> = None;

    for n in REPLICA_COUNTS {
        let trace = fleet_trace(n, per_replica);
        let m = trace.len();
        let exact =
            run_fleet(&trace, n, NetModelKind::Off, None, None);
        let lan = run_fleet(&trace, n, NetModelKind::Lan, None, None);
        // A 1ms budget expires digests well inside the 5ms gossip
        // cadence: placement runs mostly on "assume idle" optimism,
        // the worst case the degradation bound must absorb.
        let tight = run_fleet(&trace, n, NetModelKind::Lan,
                              Some(Micros(1_000)), None);

        // -- Correctness before speed -------------------------------
        for (label, r) in
            [("exact", &exact), ("lan", &lan), ("lan_tight", &tight)]
        {
            if r.completed != m {
                eprintln!("FAIL: {label} at {n} replicas completed \
                           {}/{m} — staleness may never lose a \
                           request", r.completed);
                failed = true;
            }
        }

        // -- Graceful degradation -----------------------------------
        let bound =
            exact.mean_latency_s * DEGRADE_FACTOR + DEGRADE_SLACK_S;
        for (label, r) in [("lan", &lan), ("lan_tight", &tight)] {
            if r.mean_latency_s > bound {
                eprintln!("FAIL: {label} at {n} replicas degraded \
                           non-gracefully: mean {:.4}s > bound \
                           {bound:.4}s (exact {:.4}s)",
                          r.mean_latency_s, exact.mean_latency_s);
                failed = true;
            }
        }

        // -- O(k) placement probes ----------------------------------
        let probe_cap =
            m as u64 * PROBE_PLACEMENTS_PER_REQ * TOPK as u64;
        for (label, r) in [("lan", &lan), ("lan_tight", &tight)] {
            let probes = r.probes.unwrap_or(0);
            if probes > probe_cap {
                eprintln!("FAIL: {label} at {n} replicas issued \
                           {probes} live probes for {m} requests \
                           (cap {probe_cap}) — per-arrival placement \
                           must stay O(topk), not O(replicas)");
                failed = true;
            }
        }

        let stale = lan.net.as_ref().map_or(0, |s| {
            s.stale_steer_requests
        });
        let gossip =
            lan.net.as_ref().map_or(0, |s| s.gossip_messages);
        println!("== micro_fleet_scale: {n} replicas x \
                  {per_replica} reqs/replica ({m} requests) ==");
        println!("{:<22} {:>10} {:>12} {:>12}", "mode", "steps",
                 "steps/s", "mean lat s");
        for (label, r) in
            [("exact (net off)", &exact), ("lan", &lan),
             ("lan tight budget", &tight)]
        {
            println!("{label:<22} {:>10} {:>12.0} {:>12.4}", r.steps,
                     r.steps_per_sec, r.mean_latency_s);
        }
        println!("lan: {} gossip msgs, {} stale steers, {} probes \
                  (cap {probe_cap})",
                 gossip, stale, lan.probes.unwrap_or(0));

        sections.push((format!("replicas_{n}"), json::obj(vec![
            ("requests", json::num(m as f64)),
            ("exact_steps_per_sec", json::num(exact.steps_per_sec)),
            ("lan_steps_per_sec", json::num(lan.steps_per_sec)),
            ("lan_tight_steps_per_sec",
             json::num(tight.steps_per_sec)),
            ("exact_mean_latency_s",
             json::num(exact.mean_latency_s)),
            ("lan_mean_latency_s", json::num(lan.mean_latency_s)),
            ("lan_tight_mean_latency_s",
             json::num(tight.mean_latency_s)),
            ("lan_probes_per_arrival",
             json::num(lan.probes.unwrap_or(0) as f64
                       / m.max(1) as f64)),
            ("lan_stale_steer_requests", json::num(stale as f64)),
            ("lan_gossip_messages", json::num(gossip as f64)),
        ])));
        if n == 256 {
            armed_256 = Some(lan.steps_per_sec);
        }
    }

    // -- Autoscale smoke: diurnal load on an elastic 1:16 fleet -----
    // Retime a 16-replica trace onto a sharp diurnal curve: the crest
    // must wake parked replicas (scale-ups), and the fleet must still
    // drain every request.
    let base = fleet_trace(16, 10);
    let diurnal = workload::retime(&base, ArrivalProcess::Diurnal {
        base_rate: 0.5,
        peak_rate: 200.0,
        period_secs: 10.0,
    }, 0xF1EE7);
    let auto_run = run_fleet(&diurnal, 16, NetModelKind::Lan, None,
                             Some(AutoscaleConfig { min: 1, max: 16 }));
    let (ups, downs) = auto_run.net.as_ref().map_or((0, 0), |s| {
        (s.scale_ups, s.scale_downs)
    });
    if auto_run.completed != diurnal.len() {
        eprintln!("FAIL: autoscale run completed {}/{} — elastic \
                   scaling may never lose a request",
                  auto_run.completed, diurnal.len());
        failed = true;
    }
    if ups == 0 {
        eprintln!("FAIL: diurnal crest on a min-1 fleet produced no \
                   scale-ups — the elastic path is dead");
        failed = true;
    }
    println!("== micro_fleet_scale: autoscale 1:16 diurnal ==");
    println!("{} requests, {} steps, {:.0} steps/s, {ups} ups / \
              {downs} downs, mean lat {:.4}s",
             diurnal.len(), auto_run.steps, auto_run.steps_per_sec,
             auto_run.mean_latency_s);
    sections.push(("autoscale_diurnal".to_string(), json::obj(vec![
        ("requests", json::num(diurnal.len() as f64)),
        ("steps_per_sec", json::num(auto_run.steps_per_sec)),
        ("mean_latency_s", json::num(auto_run.mean_latency_s)),
        ("scale_ups", json::num(ups as f64)),
        ("scale_downs", json::num(downs as f64)),
    ])));

    // -- Regression gate against the checked-in floor ---------------
    // The baseline is a conservative floor, not a measurement, so the
    // gate trips at 0.5× — a real collapse, not scheduler jitter.
    let lan_256 = armed_256.expect("256-replica sweep ran");
    if let Some(path) = arg_or_env(&args, "--gate", "LAMPS_BENCH_GATE")
    {
        match std::fs::read_to_string(&path)
            .map_err(|e| e.to_string())
            .and_then(|text| {
                json::parse(&text).map_err(|e| e.to_string())
            }) {
            Ok(baseline) => {
                let key = "lan_steps_per_sec";
                match gate_value(&baseline, "replicas_256", key) {
                    Some(base_v) => {
                        let floor = base_v * 0.5;
                        if lan_256 < floor {
                            eprintln!(
                                "FAIL: replicas_256 {key} {lan_256:.0} \
                                 fell below floor {floor:.0} (0.5x \
                                 baseline {base_v:.0}) from {path}");
                            failed = true;
                        } else {
                            println!(
                                "gate ok: replicas_256 {key} \
                                 {lan_256:.0} >= floor {floor:.0}");
                        }
                    }
                    None => {
                        eprintln!("FAIL: baseline {path} is missing \
                                   replicas_256.{key}");
                        failed = true;
                    }
                }
            }
            Err(e) => {
                eprintln!("FAIL: cannot read gate baseline {path}: \
                           {e}");
                failed = true;
            }
        }
    }

    // -- Perf-trajectory snapshot -----------------------------------
    if let Some(path) = arg_or_env(&args, "--json", "LAMPS_BENCH_JSON")
    {
        let mut body = vec![
            ("reqs_per_replica", json::num(per_replica as f64)),
            ("topk", json::num(TOPK as f64)),
            ("degrade_factor", json::num(DEGRADE_FACTOR)),
            ("degrade_slack_s", json::num(DEGRADE_SLACK_S)),
        ];
        for (name, v) in &sections {
            body.push((name.as_str(), v.clone()));
        }
        match lamps::bench::write_bench_json(&path,
                                             "micro_fleet_scale",
                                             body) {
            Ok(()) => eprintln!("bench json written to {path}"),
            Err(e) => {
                eprintln!("FAIL: cannot write bench json {path}: {e}");
                failed = true;
            }
        }
    }

    if failed {
        std::process::exit(1);
    }
}
