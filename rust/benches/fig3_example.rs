//! Fig 3 / Table 1: the paper's worked example, regenerated. Expected
//! averages: FCFS 11.66, SJF 10.33, SJF-total 11, LAMPS 10.
use lamps::config::{CostModel, SchedulerKind, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                           RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::Engine;

fn spec(id: u64, pre: u64, api: u64, post: u64) -> RequestSpec {
    RequestSpec {
        id: RequestId(id),
        arrival: Micros::ZERO,
        prompt: String::new(),
        prompt_tokens: Tokens(0),
        api_calls: vec![ApiCallSpec {
            decode_before: Tokens(pre),
            api_type: ApiType::Qa,
            duration: Micros(api * 1_000_000),
            response_tokens: Tokens(0),
        }],
        final_decode: Tokens(post),
    }
}

fn main() {
    println!("{:<10} {:>6} {:>6} {:>6} {:>8} {:>8}", "policy", "R1",
             "R2", "R3", "avg", "paper");
    for (kind, paper) in [(SchedulerKind::Fcfs, 11.66),
                          (SchedulerKind::Sjf, 10.33),
                          (SchedulerKind::SjfTotal, 11.0),
                          (SchedulerKind::Lamps, 10.0)] {
        let cfg = SystemConfig {
            scheduler: kind,
            memory_budget: Tokens(6),
            max_batch: 1,
            block_size: 1,
            starvation_threshold: None,
            cost: CostModel::unit(),
            ..SystemConfig::default()
        };
        let mut engine = Engine::simulated(cfg);
        engine.submit_with_handling(spec(1, 5, 2, 1),
                                    vec![HandlingStrategy::Preserve]);
        engine.submit_with_handling(spec(2, 1, 7, 1),
                                    vec![HandlingStrategy::Discard]);
        engine.submit_with_handling(spec(3, 2, 1, 1),
                                    vec![HandlingStrategy::Swap]);
        engine.run_until_idle(None);
        let f = |id| engine.request(RequestId(id)).unwrap()
            .finished_at.unwrap().as_secs_f64();
        let avg = (f(1) + f(2) + f(3)) / 3.0;
        println!("{:<10} {:>6.1} {:>6.1} {:>6.1} {:>8.2} {:>8.2}",
                 kind.label(), f(1), f(2), f(3), avg, paper);
    }
}
