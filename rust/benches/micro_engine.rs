//! End-to-end simulator throughput: virtual-seconds simulated per
//! wall-second, and engine iterations per second — the §Perf L3 numbers.
use std::time::Instant;

use lamps::bench::{Dataset, ModelPreset};
use lamps::config::SystemConfig;
use lamps::core::types::Tokens;
use lamps::engine::Engine;

fn main() {
    for (name, dataset, n, rate) in [
        ("single-api 500 @ 4/s", Dataset::SingleApi, 500, 4.0),
        ("multi-api 300 @ 6/s", Dataset::MultiApi, 300, 6.0),
        ("toolbench 300 @ 4/s", Dataset::ToolBench, 300, 4.0),
    ] {
        let trace = dataset.generate(n, rate, 42);
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        cfg.cost = ModelPreset::GptJ6b.cost();
        cfg.memory_budget = Tokens(12_000);
        let mut engine = Engine::simulated(cfg);
        let start = Instant::now();
        let report = engine.run_trace(&trace);
        let wall = start.elapsed().as_secs_f64();
        println!("{name:<24} wall {wall:>6.2}s  virtual {:>8.1}s  \
                  speedup {:>7.0}x  {:>7} iters ({:>6.0} iters/s)",
                 report.duration.as_secs_f64(),
                 report.duration.as_secs_f64() / wall,
                 report.iterations,
                 report.iterations as f64 / wall);
    }
}
