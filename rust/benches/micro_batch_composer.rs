//! Before/after numbers for the token-budgeted batch composer:
//!
//! 1. **Iteration-time microbench** — a 4096-token discard-recompute
//!    (8x the 512-token chunk) co-batched with plain decoders. Legacy
//!    composition charges the whole recompute to one iteration, stalling
//!    every co-batched decode for ~410 ms (paper-scale prefill); chunked
//!    composition bounds each iteration to one chunk's forward time.
//! 2. **End-to-end latency** — the Fig 6 LAMPS single-api cell with and
//!    without chunking+async swap: mean latency must be no worse with
//!    the composer enabled.
use lamps::bench::{run_cell_with, Dataset, ModelPreset};
use lamps::config::{ComposeConfig, HandlingPolicy, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                           RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::Engine;

const CHUNK: u64 = 512;
const RECOMPUTE_CTX: u64 = 4_096; // 8x the chunk size

/// Worst single-iteration clock advance while serving 4 decoders
/// alongside one request whose context is discard-recomputed.
fn worst_iteration(compose: ComposeConfig) -> Micros {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.handling = HandlingPolicy::Forced(HandlingStrategy::Discard);
    cfg.memory_budget = Tokens(40_000);
    cfg.max_batch = 8;
    cfg.compose = compose;
    let mut engine = Engine::simulated(cfg);

    // Co-batched decoders: enough tokens to still be decoding when the
    // recompute lands.
    for i in 0..4u64 {
        engine.submit(RequestSpec {
            id: RequestId(i),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(64),
            api_calls: vec![],
            final_decode: Tokens(2_000),
        });
    }
    // The recompute victim: big context, short API under forced
    // Discard -> the return owes a RECOMPUTE_CTX-token recompute.
    engine.submit(RequestSpec {
        id: RequestId(100),
        arrival: Micros::ZERO,
        prompt: String::new(),
        prompt_tokens: Tokens(RECOMPUTE_CTX - 8),
        api_calls: vec![ApiCallSpec {
            decode_before: Tokens(8),
            api_type: ApiType::Qa,
            duration: Micros(2_000_000),
            response_tokens: Tokens(0),
        }],
        final_decode: Tokens(8),
    });

    let mut worst = Micros::ZERO;
    loop {
        let before = engine.now();
        if !engine.step() {
            break;
        }
        let delta = engine.now() - before;
        if delta > worst {
            worst = delta;
        }
    }
    assert!(engine.request(RequestId(100)).unwrap().is_finished());
    worst
}

fn main() {
    let legacy = worst_iteration(ComposeConfig::default());
    let chunked = worst_iteration(ComposeConfig {
        prefill_chunk: Some(CHUNK),
        ..ComposeConfig::default()
    });
    println!("== micro_batch_composer: iteration stall under a \
              {RECOMPUTE_CTX}-token recompute ==");
    println!("legacy (whole-context)  worst iteration: {:>9.1} ms",
             legacy.0 as f64 / 1e3);
    println!("chunked ({CHUNK} tokens)      worst iteration: \
              {:>9.1} ms", chunked.0 as f64 / 1e3);
    // Acceptance: one chunk's forward time (51.2 ms at 100 us/token)
    // plus a generous decode-iteration allowance.
    let chunk_forward_us = 100 * CHUNK; // paper-scale prefill cost
    let decode_allowance_us = 50_000;
    assert!(legacy.0 >= 100 * RECOMPUTE_CTX,
            "legacy must charge the whole recompute in one iteration");
    assert!(chunked.0 <= chunk_forward_us + decode_allowance_us,
            "chunked iteration {} us exceeds one chunk + decode",
            chunked.0);

    println!("\n== fig6 single-api LAMPS cell: composer off vs on ==");
    let off = run_cell_with("lamps", Dataset::SingleApi,
                            ModelPreset::GptJ6b, 3.0, 150, 42, None,
                            ComposeConfig::default());
    let on = run_cell_with("lamps", Dataset::SingleApi,
                           ModelPreset::GptJ6b, 3.0, 150, 42, None,
                           ComposeConfig::chunked());
    println!("composer off: mean {:>8.3}s  p99 {:>8.3}s  ttft \
              {:>7.3}s  done {}",
             off.report.latency.mean_secs(), off.report.latency.p99_secs(),
             off.report.ttft.mean_secs(), off.report.completed);
    println!("composer on : mean {:>8.3}s  p99 {:>8.3}s  ttft \
              {:>7.3}s  done {}  (overlapped swap {:.1} ms)",
             on.report.latency.mean_secs(), on.report.latency.p99_secs(),
             on.report.ttft.mean_secs(), on.report.completed,
             on.report.swap_overlap_us as f64 / 1e3);
    assert_eq!(off.report.completed, on.report.completed);
    assert!(on.report.latency.mean_us
                <= off.report.latency.mean_us * 1.05,
            "chunked mean latency regressed: {} vs {}",
            on.report.latency.mean_us, off.report.latency.mean_us);
}
