//! Fig 7: mean latency and TTFT across datasets at a fixed arrival rate
//! of 5 req/s, for both model presets.
use lamps::bench::{print_cells, run_cell, Cell, Dataset, ModelPreset,
                   SYSTEMS};

fn main() {
    let mut cells: Vec<Cell> = Vec::new();
    for model in [ModelPreset::GptJ6b, ModelPreset::Vicuna13b] {
        for dataset in Dataset::ALL {
            for system in SYSTEMS {
                cells.push(run_cell(system, dataset, model, 5.0, 250, 42,
                                    None));
            }
        }
    }
    print_cells("Fig 7 — all datasets at rate 5", &cells);
}
