//! micro_placement: placement-probe hot-path microbench — the
//! epoch-cached `Engine::load_memory_over_time` versus the from-scratch
//! O(live + pending) recompute it memoizes (PR 8).
//!
//! A placement decision probes every replica; between arrivals almost
//! no replica's state changes, so the stateless recompute redoes the
//! same rank integrals fleet-wide per arrival. The epoch cache makes a
//! probe O(1) when the replica is untouched. This bench builds two
//! identical fleets — score cache on and off (`placement_cache`) — and
//! measures probe sweeps with a realistic invalidation pattern: one
//! replica dirtied per pass (an arrival lands somewhere, everyone else
//! is unchanged).
//!
//! Three jobs in one binary, mirroring `micro_wire`:
//!
//! 1. **Correctness cross-check** (always): before anything is timed,
//!    every replica's cached score must be bit-identical to the
//!    uncached fleet's, to its own `load_memory_over_time_uncached`,
//!    and a memory-over-time pick sequence over both fleets must choose
//!    identical replicas — a perf win that moves placement is a
//!    scheduling break.
//! 2. **Measurement**: probes/sec + allocations/probe for both fleets
//!    at 4, 16, and 64 replicas, via a counting global allocator. At 64
//!    replicas the cached fleet must probe strictly faster and allocate
//!    strictly less per probe, or the bench exits non-zero (the PR's
//!    acceptance criterion, kept honest forever).
//! 3. **Perf trajectory**: `--json PATH` (or `LAMPS_BENCH_JSON`)
//!    writes the stable `BENCH_micro_placement.json` snapshot; `--gate
//!    PATH` (or `LAMPS_BENCH_GATE`) reads a checked-in snapshot and
//!    fails if cached probes/sec at 64 replicas regressed more than 20%
//!    against it.
//!
//! ```sh
//! cargo bench --bench micro_placement -- \
//!     --gate "$PWD/../BENCH_micro_placement.json" \
//!     --json "$PWD/../BENCH_micro_placement.fresh.json"
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use lamps::cluster::{self, ArrivalScratch};
use lamps::config::{PlacementKind, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::Engine;
use lamps::util::json::{self, Value};

/// System allocator with an allocation counter — `alloc`/`realloc`
/// calls are the "allocations" the amortized-probe claim is about.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout,
                      new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

// -------------------------------------------------------------------
// Fleet construction: identical live + pending load per replica
// -------------------------------------------------------------------

const REPLICA_COUNTS: [usize; 3] = [4, 16, 64];
/// Admitted, mid-decode requests per replica (the O(live) sweep).
const LIVE_PER_REPLICA: u64 = 6;
/// Arrival-queued specs per replica (each one costs the recompute an
/// oracle prediction + a handling assignment — the allocating part).
const PENDING_PER_REPLICA: u64 = 8;
/// Per-replica KV budget in token slots.
const BUDGET: u64 = 12_000;

/// Deterministic mixed spec: every third request is augmented (a long
/// prompt decoding into an API call), the rest plain chat turns. `salt`
/// staggers replicas so their loads — and therefore their scores —
/// differ, which is what makes the pick-sequence cross-check meaningful.
fn spec(id: u64, salt: u64) -> RequestSpec {
    let v = id + salt;
    let api_calls = if v % 3 == 0 {
        vec![ApiCallSpec {
            decode_before: Tokens(24 + 8 * (v % 5)),
            api_type: ApiType::Tool(0),
            duration: Micros(400_000 + 100_000 * (v % 4)),
            response_tokens: Tokens(8),
        }]
    } else {
        vec![]
    };
    RequestSpec {
        id: RequestId(id),
        arrival: Micros(0),
        prompt: String::new(),
        prompt_tokens: Tokens(128 + 96 * (v % 7)),
        api_calls,
        final_decode: Tokens(200 + 40 * (v % 6)),
    }
}

/// One replica carrying live and pending load, staggered by `salt`.
fn make_replica(salt: u64, cache: bool) -> Engine {
    let mut cfg = SystemConfig::preset("lamps")
        .expect("lamps preset exists");
    cfg.memory_budget = Tokens(BUDGET);
    cfg.placement_cache = cache;
    let mut e = Engine::simulated(cfg);
    for k in 0..LIVE_PER_REPLICA {
        e.submit(spec(k, salt));
    }
    // A few iterations admit the batch and start decoding; the decode
    // runways above are long enough that nothing finishes.
    for _ in 0..4 {
        e.step();
    }
    for k in 0..PENDING_PER_REPLICA {
        e.enqueue(spec(1_000 + k, salt));
    }
    e
}

fn make_fleet(n: usize, cache: bool) -> Vec<Engine> {
    (0..n).map(|r| make_replica(r as u64 * 17, cache)).collect()
}

/// A fresh arrival for the pick-sequence cross-check.
fn probe_spec(i: u64) -> RequestSpec {
    RequestSpec {
        id: RequestId(10_000 + i),
        arrival: Micros(0),
        prompt: String::new(),
        prompt_tokens: Tokens(64 + 32 * (i % 7)),
        api_calls: vec![],
        final_decode: Tokens(16 + 8 * (i % 5)),
    }
}

// -------------------------------------------------------------------
// Harness
// -------------------------------------------------------------------

struct Measured {
    per_sec: f64,
    allocs_per_probe: f64,
}

/// Time `passes` sweeps of `probes_per_pass` probes, returning
/// probes/sec and allocations/probe.
fn measure<F: FnMut() -> u64>(passes: u64, probes_per_pass: usize,
                              mut work: F) -> Measured {
    // Warmup pass (fills allocator caches, primes the score memos).
    let mut sink = work();
    let a0 = allocs();
    let t0 = Instant::now();
    for _ in 0..passes {
        sink = sink.wrapping_add(work());
    }
    let elapsed = t0.elapsed();
    let da = allocs() - a0;
    std::hint::black_box(sink);
    let probes = passes * probes_per_pass as u64;
    let secs = elapsed.as_secs_f64().max(1e-9);
    Measured {
        per_sec: probes as f64 / secs,
        allocs_per_probe: da as f64 / probes as f64,
    }
}

/// One probe sweep with the realistic invalidation pattern: dirty one
/// replica (round-robin), then score the whole fleet — exactly what a
/// placement decision does after an arrival lands somewhere.
fn sweep(fleet: &mut [Engine], cursor: &mut usize) -> u64 {
    *cursor = (*cursor + 1) % fleet.len();
    fleet[*cursor].invalidate_placement_cache();
    fleet
        .iter()
        .map(|e| e.load_memory_over_time().to_bits())
        .fold(0u64, u64::wrapping_add)
}

fn arg_or_env(args: &[String], flag: &str, env: &str)
              -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var(env).ok())
}

fn gate_value(v: &Value, section: &str, key: &str) -> Option<f64> {
    v.get(section)?.get(key)?.as_f64()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let iters: u64 = std::env::var("LAMPS_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);

    let mut failed = false;
    let mut sections: Vec<(String, Value)> = Vec::new();
    let mut at_64: Option<(Measured, Measured)> = None;

    for n in REPLICA_COUNTS {
        let mut cached = make_fleet(n, true);
        let mut uncached = make_fleet(n, false);

        // -- Correctness before speed -------------------------------
        // Identical fleets must score bit-identically, cache or no
        // cache, and the cached probe must agree with its own
        // from-scratch seam.
        for (c, u) in cached.iter().zip(&uncached) {
            let cv = c.load_memory_over_time();
            assert_eq!(cv.to_bits(),
                       u.load_memory_over_time().to_bits(),
                       "cached fleet diverged from uncached fleet");
            assert_eq!(cv.to_bits(),
                       c.load_memory_over_time_uncached().to_bits(),
                       "cache hit diverged from recompute");
        }
        // A memory-over-time pick sequence must be byte-identical.
        for i in 0..(2 * n as u64) {
            let spec = probe_spec(i);
            let arrival = ArrivalScratch::new(&spec, 16);
            let (mut rc, mut ru) = (0usize, 0usize);
            let (pc, _) = cluster::pick_replica(
                &cached, PlacementKind::MemoryOverTime, &mut rc,
                &arrival, None);
            let (pu, _) = cluster::pick_replica(
                &uncached, PlacementKind::MemoryOverTime, &mut ru,
                &arrival, None);
            assert_eq!(pc, pu,
                       "pick #{i} diverged: cached chose {pc}, \
                        uncached chose {pu}");
        }

        // -- Measurement --------------------------------------------
        // Normalize total probes across fleet sizes so runtime stays
        // flat as n grows.
        let passes = (iters / n as u64).max(200);
        let mut cur_c = 0usize;
        let m_cached = measure(passes, n, || {
            sweep(&mut cached, &mut cur_c)
        });
        let mut cur_u = 0usize;
        let m_uncached = measure(passes, n, || {
            sweep(&mut uncached, &mut cur_u)
        });

        println!("== micro_placement: {n} replicas x {} live + {} \
                  pending ({passes} passes) ==",
                 LIVE_PER_REPLICA, PENDING_PER_REPLICA);
        println!("{:<26} {:>14} {:>14}", "path", "probes/s",
                 "allocs/probe");
        println!("{:<26} {:>14.0} {:>14.3}", "recompute (cache off)",
                 m_uncached.per_sec, m_uncached.allocs_per_probe);
        println!("{:<26} {:>14.0} {:>14.3}", "epoch cache",
                 m_cached.per_sec, m_cached.allocs_per_probe);

        sections.push((format!("replicas_{n}"), json::obj(vec![
            ("cached_probes_per_sec", json::num(m_cached.per_sec)),
            ("cached_allocs_per_probe",
             json::num(m_cached.allocs_per_probe)),
            ("uncached_probes_per_sec",
             json::num(m_uncached.per_sec)),
            ("uncached_allocs_per_probe",
             json::num(m_uncached.allocs_per_probe)),
        ])));
        if n == 64 {
            at_64 = Some((m_cached, m_uncached));
        }
    }

    // -- Acceptance criteria, kept honest on every run --------------
    let (cached64, uncached64) = at_64.expect("64-replica sweep ran");
    if cached64.per_sec <= uncached64.per_sec {
        eprintln!("FAIL: cached probes must be strictly faster at 64 \
                   replicas ({:.0} vs {:.0} probes/s)",
                  cached64.per_sec, uncached64.per_sec);
        failed = true;
    }
    if cached64.allocs_per_probe >= uncached64.allocs_per_probe {
        eprintln!("FAIL: cached probes must allocate strictly less at \
                   64 replicas ({:.3} vs {:.3} allocs/probe)",
                  cached64.allocs_per_probe, uncached64.allocs_per_probe);
        failed = true;
    }

    // -- Regression gate against the checked-in baseline ------------
    if let Some(path) = arg_or_env(&args, "--gate", "LAMPS_BENCH_GATE") {
        match std::fs::read_to_string(&path)
            .map_err(|e| e.to_string())
            .and_then(|text| {
                json::parse(&text).map_err(|e| e.to_string())
            }) {
            Ok(baseline) => {
                let key = "cached_probes_per_sec";
                match gate_value(&baseline, "replicas_64", key) {
                    Some(base) => {
                        let floor = base * 0.8;
                        if cached64.per_sec < floor {
                            eprintln!(
                                "FAIL: replicas_64 {key} {:.0} \
                                 regressed >20% vs baseline {base:.0} \
                                 (floor {floor:.0}) from {path}",
                                cached64.per_sec);
                            failed = true;
                        } else {
                            println!(
                                "gate ok: replicas_64 {key} {:.0} >= \
                                 floor {floor:.0}", cached64.per_sec);
                        }
                    }
                    None => {
                        eprintln!("FAIL: baseline {path} is missing \
                                   replicas_64.{key}");
                        failed = true;
                    }
                }
            }
            Err(e) => {
                eprintln!("FAIL: cannot read gate baseline {path}: {e}");
                failed = true;
            }
        }
    }

    // -- Perf-trajectory snapshot -----------------------------------
    if let Some(path) = arg_or_env(&args, "--json", "LAMPS_BENCH_JSON") {
        let mut body = vec![
            ("iters", json::num(iters as f64)),
            ("live_per_replica", json::num(LIVE_PER_REPLICA as f64)),
            ("pending_per_replica",
             json::num(PENDING_PER_REPLICA as f64)),
        ];
        for (name, v) in &sections {
            body.push((name.as_str(), v.clone()));
        }
        match lamps::bench::write_bench_json(&path, "micro_placement",
                                             body) {
            Ok(()) => eprintln!("bench json written to {path}"),
            Err(e) => {
                eprintln!("FAIL: cannot write bench json {path}: {e}");
                failed = true;
            }
        }
    }

    if failed {
        std::process::exit(1);
    }
}
