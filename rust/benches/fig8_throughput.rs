//! Fig 8: throughput vs request arrival rate with Vicuna 13B across the
//! three datasets. Matches the paper's method: a 30-minute (virtual)
//! window, counting completed requests.
use lamps::bench::{run_cell, Dataset, ModelPreset, SYSTEMS};
use lamps::core::types::Micros;

fn main() {
    // A 10-minute window keeps the sweep tractable; the paper's 30-minute
    // method is identical modulo the horizon (set WINDOW_SECS to 1800 to
    // match exactly).
    const WINDOW_SECS: f64 = 600.0;
    let window = Micros::from_secs_f64(WINDOW_SECS);
    println!("{:<11} {:<10} {:>5} {:>12} {:>10}", "dataset", "system",
             "rate", "completed", "thr(r/s)");
    for dataset in Dataset::ALL {
        for rate in [1.0, 2.0, 4.0, 6.0] {
            for system in SYSTEMS {
                // Enough requests to saturate the window at this rate.
                let n = (rate * WINDOW_SECS * 1.2) as usize;
                let cell = run_cell(system, dataset,
                                    ModelPreset::Vicuna13b, rate,
                                    n.min(2500), 42, Some(window));
                println!("{:<11} {:<10} {:>5.1} {:>12} {:>10.3}",
                         dataset.label(), system, rate,
                         cell.report.completed,
                         cell.report.completed as f64 / WINDOW_SECS);
            }
        }
    }
}
