//! Table 3: length-predictor accuracy. Reads the Python-side training
//! stats (artifacts/predictor_stats.json) and re-evaluates the exported
//! HLO classifier from Rust on a fresh synthetic ToolBench eval split —
//! a full cross-language validation of tokenizer + artifact + runtime.
use lamps::runtime::{ArtifactMeta, PredictorRuntime, RuntimeClient};
use lamps::util::json;
use lamps::workload::toolbench;

fn main() {
    let Ok(meta) = ArtifactMeta::load_default() else {
        println!("run `make artifacts` first");
        return;
    };
    if let Ok(text) =
        std::fs::read_to_string(meta.dir.join("predictor_stats.json"))
    {
        let v = json::parse(&text).unwrap();
        println!("== python-side validation split ==");
        println!("acc5 {:.3}  acc15 {:.3}  MAE {:.2} words \
                  (paper: 0.685 / 0.783 / 3.06)",
                 v.f64_field("acc5").unwrap(),
                 v.f64_field("acc15").unwrap(),
                 v.f64_field("mae_words").unwrap());
    }

    let client = RuntimeClient::cpu().unwrap();
    let pred = PredictorRuntime::load(&client, &meta).unwrap();
    let samples = toolbench::eval_samples(1500, 777);
    let width = pred.meta.bin_width as u64;
    let mut err = Vec::new();
    let mut per_bin: Vec<Vec<f64>> = vec![Vec::new(); 50];
    let start = std::time::Instant::now();
    for s in &samples {
        let bin = pred.predict_bin(&s.prompt).unwrap();
        let predicted = bin as f64 * width as f64 + width as f64 / 2.0;
        let e = (predicted - s.length as f64).abs();
        err.push(e);
        per_bin[s.bin() as usize].push(e);
    }
    let n = err.len() as f64;
    let acc = |t: f64| err.iter().filter(|e| **e <= t).count() as f64 / n;
    println!("\n== rust-side (PJRT) eval, {} samples ==", samples.len());
    println!("acc5 {:.3}  acc15 {:.3}  MAE {:.2} words  \
              ({:.2} ms/prediction)",
             acc(5.0), acc(15.0), err.iter().sum::<f64>() / n,
             start.elapsed().as_millis() as f64 / n);
    println!("\nper-bin accuracy (first 11 bins; paper Table 3):");
    println!("{:>4} {:>6} {:>7} {:>7}", "bin", "n", "acc5", "acc15");
    for (b, errs) in per_bin.iter().enumerate().take(11) {
        if errs.is_empty() {
            continue;
        }
        let m = errs.len() as f64;
        println!("{:>4} {:>6} {:>7.3} {:>7.3}", b, errs.len(),
                 errs.iter().filter(|e| **e <= 5.0).count() as f64 / m,
                 errs.iter().filter(|e| **e <= 15.0).count() as f64 / m);
    }
}
