//! Table 2 self-check: measured API duration and calls-per-request
//! statistics of the synthetic datasets vs the published values.
use lamps::bench::Dataset;

fn main() {
    println!("{:<10} {:>12} {:>12} {:>10} {:>10}   {}", "class",
             "dur_mean(s)", "dur_std(s)", "calls_mu", "calls_sd",
             "published (dur / calls)");
    let published = [
        ("math", "(9e-5, 6e-5) / (3.75, 1.3)"),
        ("qa", "(0.69, 0.17) / (2.52, 1.73)"),
        ("ve", "(0.09, 0.014) / (28.18, 15.2)"),
        ("chatbot", "(28.6, 15.6) / (4.45, 1.96)"),
        ("image", "(20.03, 7.8) / (6.91, 3.93)"),
        ("tts", "(17.24, 7.6) / (6.91, 3.93)"),
        ("tool", "(1.72, 3.33) / (2.45, 1.81)"),
    ];
    let lookup = |label: &str| {
        published.iter().find(|(l, _)| *l == label).map(|(_, p)| *p)
            .unwrap_or("")
    };
    for (name, trace) in [
        ("multi-api", Dataset::MultiApi.generate(4000, 3.0, 42)),
        ("toolbench", Dataset::ToolBench.generate(4000, 3.0, 42)),
    ] {
        println!("== {name} ==");
        for (label, s) in trace.api_class_stats() {
            println!("{:<10} {:>12.5} {:>12.5} {:>10.2} {:>10.2}   {}",
                     label, s.duration_mean, s.duration_std,
                     s.calls_mean, s.calls_std, lookup(&label));
        }
    }
}
