//! Wire-protocol fuzz: seeded random frames — garbage bytes, bracket
//! bombs, structurally random JSON, mutated valid frames, valid
//! frames with hostile field values, escape-heavy strings, frames
//! delivered one byte at a time (splitting multi-byte UTF-8 across
//! reads), and oversized single frames — thrown at the v2 NDJSON TCP
//! listener. The server must never panic and never emit a
//! non-JSON byte in response: every reply line parses, and after the
//! barrage the same listener still serves a well-formed request
//! (proof the accept loop and engine thread survived).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use lamps::config::{CostModel, SystemConfig};
use lamps::core::types::Micros;
use lamps::server;
use lamps::util::json;

fn fast_cost() -> CostModel {
    CostModel {
        decode_base: Micros(200),
        decode_per_ctx_token_us: 0.0,
        prefill_per_token_us: 5.0,
        swap_base_us: 0.0,
        swap_per_token_us: 0.0,
        rank_overhead_per_request_us: 0.0,
    }
}

struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// Random printable ASCII, newline-free (a frame is one line).
fn garbage_line(rng: &mut XorShift) -> String {
    let len = rng.below(64) as usize;
    (0..len)
        .map(|_| (0x20 + rng.below(0x5f)) as u8 as char)
        .collect()
}

/// Runs of structural JSON characters — the recursive-descent
/// parser's worst diet (bounded length bounds its recursion).
fn bracket_bomb(rng: &mut XorShift) -> String {
    const CHARS: [char; 8] = ['{', '}', '[', ']', '"', '\\', ':', ','];
    let len = 1 + rng.below(60) as usize;
    (0..len)
        .map(|_| CHARS[rng.below(CHARS.len() as u64) as usize])
        .collect()
}

/// Structurally valid JSON of bounded depth with keys drawn from the
/// protocol vocabulary — close enough to real frames to reach the
/// field-validation paths, random enough to stress them.
fn random_json(rng: &mut XorShift, depth: u64) -> String {
    const KEYS: [&str; 8] = ["type", "prompt", "output_tokens", "id",
                             "index", "api_calls", "response_tokens",
                             "api_ms"];
    const STRS: [&str; 6] =
        ["request", "tool_result", "bogus", "", "qa", "math"];
    match rng.below(if depth == 0 { 3 } else { 5 }) {
        0 => format!("{}", rng.below(40)),
        1 => format!("\"{}\"", STRS[rng.below(6) as usize]),
        2 => ["true", "false", "null"][rng.below(3) as usize].to_string(),
        3 => {
            let items: Vec<String> = (0..rng.below(3))
                .map(|_| random_json(rng, depth - 1))
                .collect();
            format!("[{}]", items.join(","))
        }
        _ => {
            let pairs: Vec<String> = (0..rng.below(4))
                .map(|_| {
                    format!("\"{}\":{}", KEYS[rng.below(8) as usize],
                            random_json(rng, depth - 1))
                })
                .collect();
            format!("{{{}}}", pairs.join(","))
        }
    }
}

/// A well-formed frame, then one byte replaced or the tail cut —
/// near-misses that must die in the parser or field validation, never
/// in a panic. Length never grows, so a surviving `output_tokens`
/// stays single-digit (the blocking v1 path must terminate fast).
fn mutated_frame(rng: &mut XorShift) -> String {
    const TEMPLATES: [&str; 3] = [
        "{\"type\":\"request\",\"prompt\":\"fuzz\",\"output_tokens\":4,\
         \"api_calls\":[{\"decode_before\":2,\"api_type\":\"qa\",\
         \"api_ms\":3,\"response_tokens\":2}]}",
        "{\"type\":\"tool_result\",\"id\":3,\"index\":0,\
         \"response_tokens\":2}",
        "{\"prompt\":\"v1\",\"output_tokens\":5}",
    ];
    let mut b: Vec<u8> =
        TEMPLATES[rng.below(3) as usize].bytes().collect();
    if rng.below(2) == 0 {
        let i = rng.below(b.len() as u64) as usize;
        b[i] = (0x20 + rng.below(0x5f)) as u8;
    } else {
        b.truncate(rng.below(b.len() as u64) as usize);
    }
    String::from_utf8_lossy(&b).into_owned()
}

/// Escape-heavy strings: prompts stuffed with backslash escapes,
/// quotes, `\u` sequences (well-formed, short, and malformed), and
/// multi-byte UTF-8 — the zero-copy lexer's slow (owned) path, and
/// the exact place a borrow/copy boundary bug would corrupt or panic.
fn escape_heavy(rng: &mut XorShift) -> String {
    const PIECES: [&str; 12] = [
        "\\\"", "\\\\", "\\n", "\\t", "\\r", "\\b", "\\f", "\\/",
        "\\u0041", "\\u20ac", "\\u12", "é✓",
    ];
    let n = 1 + rng.below(12) as usize;
    let mut prompt = String::new();
    for _ in 0..n {
        prompt.push_str(PIECES[rng.below(12) as usize]);
    }
    // Half the time as a complete v1 request (so a well-formed escape
    // run must decode and serve), half as a bare string frame (must
    // die in field validation, not the lexer).
    if rng.below(2) == 0 {
        format!("{{\"prompt\":\"{prompt}\",\"output_tokens\":2}}")
    } else {
        format!("\"{prompt}\"")
    }
}

/// A valid frame with adversarial-but-bounded field values: requests
/// that may exceed the budget, tool results for ids that don't exist
/// (or aren't externally held — this server simulates durations).
fn hostile_valid(rng: &mut XorShift) -> String {
    if rng.below(2) == 0 {
        format!(
            "{{\"type\":\"request\",\"prompt\":\"f{}\",\
             \"output_tokens\":{},\"api_calls\":[{{\
             \"decode_before\":{},\"api_type\":\"tool\",\"api_ms\":{},\
             \"response_tokens\":{}}}]}}",
            rng.below(100), 1 + rng.below(8), rng.below(4),
            rng.below(20), rng.below(4))
    } else {
        format!("{{\"type\":\"tool_result\",\"id\":{},\"index\":{},\
                 \"response_tokens\":{}}}",
                rng.below(40), rng.below(4), rng.below(6))
    }
}

/// Read everything the server has to say right now; every complete
/// line must parse as JSON. Returns on timeout or EOF.
fn drain_assert_json(reader: &mut BufReader<TcpStream>) {
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => return, // clean close
            Ok(_) => {
                let t = line.trim();
                if !t.is_empty() {
                    json::parse(t).unwrap_or_else(|e| {
                        panic!("non-JSON reply {t:?}: {e}")
                    });
                }
            }
            Err(_) => return, // read timeout: drained for now
        }
    }
}

fn connect(addr: &str) -> TcpStream {
    for _ in 0..50 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("server did not come up on {addr}");
}

#[test]
fn fuzzed_frames_never_break_the_listener() {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    let (handle, _join) = server::spawn_sim(cfg);
    let addr = "127.0.0.1:17073";
    let server_handle = handle.clone();
    std::thread::spawn(move || {
        let _ = server::serve_tcp(server_handle, addr);
    });

    for seed in [0x5EED_0001u64, 0xF00D_CAFE ^ 0xDEAD_BEEF, 42] {
        let stream = connect(addr);
        stream
            .set_read_timeout(Some(Duration::from_millis(150)))
            .unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut rng = XorShift(seed);
        for i in 0..180u64 {
            let line = match rng.below(6) {
                0 => garbage_line(&mut rng),
                1 => bracket_bomb(&mut rng),
                2 => random_json(&mut rng, 3),
                3 => mutated_frame(&mut rng),
                4 => escape_heavy(&mut rng),
                _ => hostile_valid(&mut rng),
            };
            // A dead listener surfaces here as a broken pipe.
            writer
                .write_all(line.as_bytes())
                .and_then(|()| writer.write_all(b"\n"))
                .unwrap_or_else(|e| {
                    panic!("server hung up mid-fuzz (line {i}): {e}")
                });
            if i % 40 == 39 {
                writer.flush().unwrap();
                drain_assert_json(&mut reader);
            }
        }
        writer.flush().unwrap();
        drain_assert_json(&mut reader);
    }

    // The listener and engine thread must have survived the barrage:
    // a well-formed v1 one-shot on a fresh connection still completes.
    let stream = connect(addr);
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer
        .write_all(b"{\"prompt\": \"still alive\", \"output_tokens\": 3}\n")
        .unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).expect("completion is valid JSON");
    assert_eq!(v.u64_field("tokens_decoded").unwrap(), 3,
               "post-fuzz request must be served normally");
    handle.shutdown();
}

#[test]
fn byte_at_a_time_delivery_with_split_utf8() {
    // Frames trickled one byte per write + flush — every multi-byte
    // UTF-8 character in the prompt is split across read-buffer
    // boundaries. The line framer must reassemble them, the zero-copy
    // lexer must decode the escapes, and the request must complete.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    let (handle, _join) = server::spawn_sim(cfg);
    let addr = "127.0.0.1:17074";
    let server_handle = handle.clone();
    std::thread::spawn(move || {
        let _ = server::serve_tcp(server_handle, addr);
    });
    let stream = connect(addr);
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let line = "{\"prompt\":\"héllo ✓ \\u20ac wörld\",\
                \"output_tokens\":3}\n";
    for b in line.as_bytes() {
        writer.write_all(std::slice::from_ref(b)).unwrap();
        writer.flush().unwrap();
    }
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = json::parse(&reply).expect("completion is valid JSON");
    assert_eq!(v.u64_field("tokens_decoded").unwrap(), 3,
               "byte-at-a-time request must be served normally");
    // Same treatment for a malformed escape: a JSON error frame, not
    // a hangup.
    let bad = "{\"prompt\":\"\\q\",\"output_tokens\":1}\n";
    for b in bad.as_bytes() {
        writer.write_all(std::slice::from_ref(b)).unwrap();
    }
    writer.flush().unwrap();
    reply.clear();
    reader.read_line(&mut reply).unwrap();
    let v = json::parse(&reply).expect("error frame is valid JSON");
    assert!(v.str_field("error").unwrap().contains("bad escape"),
            "{reply}");
    handle.shutdown();
}

#[test]
fn oversized_frames_get_an_error_and_the_connection_survives() {
    // A single frame beyond the 1 MiB line cap is discarded while
    // reading; the reply must be a well-formed JSON error naming the
    // size, and the same connection must then serve a normal request.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    let (handle, _join) = server::spawn_sim(cfg);
    let addr = "127.0.0.1:17075";
    let server_handle = handle.clone();
    std::thread::spawn(move || {
        let _ = server::serve_tcp(server_handle, addr);
    });
    let stream = connect(addr);
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    // A syntactically valid giant request — size alone must reject it.
    let mut huge =
        String::from("{\"prompt\":\"");
    huge.push_str(&"x".repeat(lamps::wire::MAX_FRAME_BYTES));
    huge.push_str("\",\"output_tokens\":1}\n");
    writer.write_all(huge.as_bytes()).unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let v = json::parse(&reply).expect("oversize reply is valid JSON");
    let msg = v.str_field("error").unwrap();
    assert!(msg.contains("exceeds") && msg.contains("byte"), "{reply}");
    // Listener and connection both survive.
    writer
        .write_all(b"{\"prompt\": \"after the flood\", \
                      \"output_tokens\": 2}\n")
        .unwrap();
    writer.flush().unwrap();
    reply.clear();
    reader.read_line(&mut reply).unwrap();
    let v = json::parse(&reply).unwrap();
    assert_eq!(v.u64_field("tokens_decoded").unwrap(), 2,
               "connection must stay usable after an oversized frame");
    handle.shutdown();
}
