//! Randomized property tests of multi-replica dispatch
//! (`cluster::ReplicaSet`), driven by the crate's deterministic
//! `util::Rng` (fixed seeds — every failure is exactly reproducible):
//!
//! - every request is placed on exactly one replica, exists only there,
//!   and completes there (no migration, no loss, no duplication),
//! - per-replica KV conservation: every replica's block manager drains
//!   back to zero occupancy once its requests finish,
//! - a `replicas = 1` fleet reproduces the single-`Engine` run of the
//!   same trace/seed **byte-identically** (the refactor's safety rail),
//!   including with the chunked composer and the prefix cache enabled,
//! - round-robin placement is a pure rotation in arrival order.

use std::collections::{BTreeMap, HashSet};

use lamps::cluster::ReplicaSet;
use lamps::config::{CostModel, HandlingPolicy, PlacementKind,
                    PrefixCacheConfig, SchedulerKind, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                           RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::Engine;
use lamps::util::Rng;
use lamps::workload::{infercept, Trace};

/// Mixed augmented/plain trace with random arrivals, prompts, API
/// durations, and decode lengths.
fn random_trace(rng: &mut Rng, n: u64) -> Trace {
    let mut t = 0u64;
    let specs = (0..n)
        .map(|i| {
            t += rng.int_range(0, 400_000);
            let api_calls = if rng.f64() < 0.5 {
                vec![ApiCallSpec {
                    decode_before: Tokens(rng.int_range(1, 30)),
                    api_type: ApiType::Qa,
                    duration: Micros(rng.int_range(100_000, 5_000_000)),
                    response_tokens: Tokens(rng.int_range(0, 8)),
                }]
            } else {
                vec![]
            };
            RequestSpec {
                id: RequestId(i),
                arrival: Micros(t),
                prompt: String::new(),
                prompt_tokens: Tokens(rng.int_range(0, 200)),
                api_calls,
                final_decode: Tokens(rng.int_range(1, 40)),
            }
        })
        .collect();
    Trace::new("random", 1.0, specs)
}

/// Trace whose prompts draw from a small pool of shared prefixes plus a
/// unique tail, so the cross-replica shared prefix index has real
/// content-chain sharing to track (empty prompts hash per-request and
/// never cross-share).
fn random_shared_trace(rng: &mut Rng, n: u64) -> Trace {
    const PREFIXES: [&str; 3] = [
        "System: answer in one short paragraph and cite your sources \
         whenever external facts are referenced here. ",
        "System: you are a strict JSON transformer; never add prose or \
         commentary around the emitted document body. ",
        "System: translate the user's message to French, preserving \
         code spans and inline markup fragments verbatim. ",
    ];
    let mut t = 0u64;
    let specs = (0..n)
        .map(|i| {
            t += rng.int_range(0, 300_000);
            let prefix = PREFIXES[rng.int_range(0, 2) as usize];
            let prompt = format!("{prefix}tail-{i:05}");
            let prompt_tokens = Tokens(prompt.len() as u64);
            let api_calls = if rng.f64() < 0.4 {
                vec![ApiCallSpec {
                    decode_before: Tokens(rng.int_range(1, 10)),
                    api_type: ApiType::Qa,
                    duration: Micros(rng.int_range(100_000, 2_000_000)),
                    response_tokens: Tokens(rng.int_range(0, 6)),
                }]
            } else {
                vec![]
            };
            RequestSpec {
                id: RequestId(i),
                arrival: Micros(t),
                prompt,
                prompt_tokens,
                api_calls,
                final_decode: Tokens(rng.int_range(1, 20)),
            }
        })
        .collect();
    Trace::new("shared-random", 1.0, specs)
}

/// Every (hash, replica) entry of the fleet index must be backed by an
/// actually-resident block in that replica's local prefix cache — the
/// advisory index may *under*-promise, never point at purged state.
fn assert_index_subset_of_resident(set: &ReplicaSet) {
    let index = set.shared_index().expect("shared index active");
    let resident: Vec<HashSet<u64>> = (0..set.len())
        .map(|i| {
            set.replica(i)
                .resident_prefix_hashes()
                .into_iter()
                .collect()
        })
        .collect();
    for hash in index.hashes() {
        for r in index.replicas_of(hash) {
            assert!(resident[r].contains(&hash),
                    "index holds {hash:#x} for replica {r} but the \
                     block is gone — no entry may survive a \
                     replica-local purge/eviction");
        }
    }
}

#[test]
fn prop_shared_index_mirrors_resident_blocks_at_every_step() {
    let mut rng = Rng::new(0x5E7_0010);
    for (replicas, cache_blocks, placement) in [
        (2usize, None, PlacementKind::PrefixAffinity),
        (3, Some(8u64), PlacementKind::PrefixAffinity),
        (4, None, PlacementKind::MemoryOverTime),
    ] {
        let trace = random_shared_trace(&mut rng, 40);
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        // Small budget: memory pressure reclaims cached blocks, so the
        // Removed delta path is exercised, not just registration.
        cfg.memory_budget = Tokens(1_500);
        cfg.replicas = replicas;
        cfg.placement = placement;
        cfg.prefix_cache = PrefixCacheConfig {
            enabled: true,
            cache_blocks,
        };
        cfg.shared_prefix = true;
        let mut set = ReplicaSet::simulated(cfg);
        for spec in &trace.requests {
            set.enqueue(spec.clone());
        }
        let mut steps = 0u64;
        while set.step() {
            steps += 1;
            assert!(steps < 5_000_000, "fleet failed to drain");
            assert_index_subset_of_resident(&set);
        }
        // The sequential fleet drains the stepped replica's journal
        // every step, so by the end the mirror is exact — residency
        // missing from the index would mean a lost Registered delta.
        let index = set.shared_index().unwrap();
        assert!(!index.is_empty(),
                "shared prompts must populate the index");
        for i in 0..set.len() {
            for hash in set.replica(i).resident_prefix_hashes() {
                assert!(index.holds(hash, i),
                        "resident {hash:#x} on replica {i} missing from \
                         the index ({placement:?})");
            }
        }
        let report = set.fleet_report();
        assert_eq!(report.fleet.completed as u64, 40,
                   "{placement:?} fleet must drain");
    }
}

#[test]
fn shared_prefix_off_keeps_the_pr3_fleet_json_shape() {
    // `--shared-prefix` off must reproduce the PR 3 fleet JSON: the
    // exact top-level key set, with no shared_prefix block anywhere.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.memory_budget = Tokens(9_000);
    cfg.replicas = 3;
    cfg.prefix_cache = PrefixCacheConfig::on();
    let trace = infercept::single_api_dataset(30, 4.0, 9);
    let mut set = ReplicaSet::simulated(cfg);
    let json = set.run_trace(&trace).to_json(false);
    assert!(!json.contains("shared_prefix"),
            "index-off JSON must carry no trace of the feature");
    let v = lamps::util::json::parse(&json).unwrap();
    let keys: Vec<&str> = v
        .as_obj()
        .unwrap()
        .keys()
        .map(|k| k.as_str())
        .collect();
    assert_eq!(keys, ["fleet", "per_replica", "placement", "replicas"],
               "exactly the PR 3 top-level shape");
}

#[test]
fn prop_shared_index_is_purely_observational_under_pr3_placements() {
    // With a PR 3 placement policy the index is maintained but never
    // consulted: every per-replica report, the fleet aggregate, and the
    // dispatch log must be byte-identical to the run without it (the
    // executable form of "--shared-prefix off reproduces the PR 3
    // path" — the journals may not perturb replica behavior).
    for placement in [PlacementKind::MemoryOverTime,
                      PlacementKind::LeastLoaded,
                      PlacementKind::RoundRobin] {
        let mut rng = Rng::new(0x5E7_0020);
        let trace = random_shared_trace(&mut rng, 35);
        let run = |shared: bool| {
            let mut cfg = SystemConfig::preset("lamps").unwrap();
            cfg.memory_budget = Tokens(3_000);
            cfg.replicas = 3;
            cfg.placement = placement;
            cfg.prefix_cache = PrefixCacheConfig::on();
            cfg.shared_prefix = shared;
            let mut set = ReplicaSet::simulated(cfg);
            let report = set.run_trace(&trace);
            (report, set.assignments().to_vec())
        };
        let (off, assigned_off) = run(false);
        let (on, assigned_on) = run(true);
        assert_eq!(assigned_off, assigned_on, "{placement:?}");
        assert_eq!(off.fleet.to_json(true), on.fleet.to_json(true),
                   "{placement:?}: fleet aggregate diverged");
        for (i, (l, r)) in
            off.per_replica.iter().zip(&on.per_replica).enumerate()
        {
            assert_eq!(l.to_json(true), r.to_json(true),
                       "{placement:?}: replica {i} diverged");
        }
        assert!(off.shared_prefix.is_none());
        let stats = on.shared_prefix.expect("stats when index active");
        assert_eq!(stats.steered_tokens, 0,
                   "{placement:?} never consults the index");
    }
}

#[test]
fn fleet_promotion_survives_api_return_on_replica() {
    // §4.4 parity across the fleet: ids 0 and 2 land on replica 0 under
    // round-robin (1 goes to replica 1). Request 2 is promoted while
    // queued behind the hog, Discards at its API mid-fleet-run, and
    // must come back from the return still promoted — an API return
    // never demotes a starving request.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.scheduler = SchedulerKind::Fcfs;
    cfg.handling = HandlingPolicy::Forced(HandlingStrategy::Discard);
    cfg.memory_budget = Tokens(100);
    cfg.block_size = 1;
    cfg.max_batch = 1;
    cfg.starvation_threshold = Some(2);
    cfg.cost = CostModel::unit();
    cfg.replicas = 2;
    cfg.placement = PlacementKind::RoundRobin;
    let plain = |id: u64, decode: u64| RequestSpec {
        id: RequestId(id),
        arrival: Micros::ZERO,
        prompt: String::new(),
        prompt_tokens: Tokens(0),
        api_calls: vec![],
        final_decode: Tokens(decode),
    };
    let trace = Trace::new("t", 1.0, vec![
        plain(0, 8), // hog -> replica 0
        plain(1, 1), // filler -> replica 1
        RequestSpec {
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(2),
                api_type: ApiType::Qa,
                duration: Micros(3_000_000),
                response_tokens: Tokens(0),
            }],
            ..plain(2, 1) // -> replica 0, behind the hog
        },
    ]);
    let mut set = ReplicaSet::simulated(cfg);
    let report = set.run_trace(&trace);
    assert_eq!(report.fleet.completed, 3);
    let b = set.replica(0).request(RequestId(2)).unwrap();
    assert!(b.is_finished());
    assert!(b.starving,
            "promotion must survive the Discard re-admission on its \
             replica");
    assert_eq!(b.starvation_cnt, 0, "counter rests at the §4.4 reset");
}

#[test]
fn prop_each_request_lands_on_exactly_one_replica() {
    let mut rng = Rng::new(0x5E7_0001);
    let policies = [PlacementKind::MemoryOverTime,
                    PlacementKind::LeastLoaded,
                    PlacementKind::RoundRobin];
    for case in 0..6u64 {
        let n = 30 + case * 5;
        let trace = random_trace(&mut rng, n);
        let replicas = 2 + (case % 3) as usize;
        for policy in policies {
            let mut cfg = SystemConfig::preset("lamps").unwrap();
            cfg.memory_budget = Tokens(10_000);
            cfg.replicas = replicas;
            cfg.placement = policy;
            let mut set = ReplicaSet::simulated(cfg);
            let report = set.run_trace(&trace);

            // Exactly one placement per request, on a real replica.
            let mut owner: BTreeMap<RequestId, usize> = BTreeMap::new();
            for &(id, r) in set.assignments() {
                assert!(r < replicas, "replica index out of range");
                assert!(owner.insert(id, r).is_none(),
                        "{id} placed twice ({policy:?})");
            }
            assert_eq!(owner.len() as u64, n,
                       "every request must be placed ({policy:?})");

            // The request lives (and finished) on its owner — and on no
            // other replica.
            for (&id, &r) in &owner {
                for other in 0..replicas {
                    let found = set.replica(other).request(id);
                    if other == r {
                        let req = found.unwrap_or_else(|| {
                            panic!("{id} missing from its owner")
                        });
                        assert!(req.is_finished(),
                                "{id} unfinished on replica {r}");
                    } else {
                        assert!(found.is_none(),
                                "{id} leaked onto replica {other}");
                    }
                }
            }

            // Fan-in accounting: per-replica submissions/completions
            // partition the trace.
            let submitted: usize =
                report.per_replica.iter().map(|p| p.submitted).sum();
            let completed: usize =
                report.per_replica.iter().map(|p| p.completed).sum();
            assert_eq!(submitted as u64, n);
            assert_eq!(completed as u64, n);
            assert_eq!(report.fleet.completed as u64, n);

            // Per-replica KV conservation: every block manager drains.
            for i in 0..replicas {
                assert_eq!(set.replica(i).kv_occupancy(), 0.0,
                           "replica {i} leaked KV ({policy:?})");
            }
        }
    }
}

#[test]
fn prop_round_robin_is_pure_rotation() {
    let mut rng = Rng::new(0x5E7_0002);
    let trace = random_trace(&mut rng, 25);
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.memory_budget = Tokens(10_000);
    cfg.replicas = 4;
    cfg.placement = PlacementKind::RoundRobin;
    let mut set = ReplicaSet::simulated(cfg);
    set.run_trace(&trace);
    // Arrivals are strictly increasing in id here, so dispatch order is
    // id order and the rotation is exact.
    for (i, &(_, r)) in set.assignments().iter().enumerate() {
        assert_eq!(r, i % 4);
    }
}

/// `replicas = 1` must reproduce the single-engine run byte for byte —
/// same JSON report (all counters, timings, and summaries), across
/// schedulers and with the composer/prefix-cache features on.
#[test]
fn prop_single_replica_fleet_is_byte_identical_to_engine() {
    for (system, seed) in [("lamps", 42u64), ("vllm", 7), ("infercept", 3)]
    {
        for chunked in [false, true] {
            let mut cfg = SystemConfig::preset(system).unwrap();
            cfg.memory_budget = Tokens(9_000);
            cfg.seed = seed;
            if chunked {
                cfg.compose = lamps::config::ComposeConfig::chunked();
                cfg.prefix_cache =
                    lamps::config::PrefixCacheConfig::on();
                // With one replica the shared index and affinity
                // placement must leave the single-engine path
                // untouched too.
                cfg.shared_prefix = true;
                cfg.placement = PlacementKind::PrefixAffinity;
            }
            let trace = infercept::single_api_dataset(40, 4.0, seed);

            let mut engine = Engine::simulated(cfg.clone());
            let solo = engine.run_trace(&trace);

            cfg.replicas = 1;
            let mut set = ReplicaSet::simulated(cfg);
            let fleet = set.run_trace(&trace);

            assert_eq!(solo.to_json(true), fleet.fleet.to_json(true),
                       "{system} seed {seed} chunked {chunked}: \
                        replicas = 1 diverged from the single engine");
            assert_eq!(fleet.per_replica.len(), 1);
        }
    }
}

/// Same check on a multi-API dataset, both uncapped and through the
/// fleet driver's frontier-based time-cap semantics.
#[test]
fn prop_single_replica_fleet_matches_engine_multi_api() {
    for cap in [None, Some(Micros(20_000_000))] {
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        cfg.memory_budget = Tokens(9_000);
        let trace = infercept::multi_api_dataset(30, 3.0, 11);

        let mut engine = Engine::simulated(cfg.clone());
        let solo = engine.run_trace_limited(&trace, cap);

        let mut set = ReplicaSet::simulated(cfg);
        let fleet = set.run_trace_limited(&trace, cap);
        assert_eq!(solo.to_json(true), fleet.fleet.to_json(true),
                   "cap {cap:?}");
    }
}

/// Randomized gossip-staleness invariants (the modeled network of
/// `cluster::net`): with `--net-model lan|wan` armed the shared-prefix
/// mirror lags reality, and the only legal consequence is a measured
/// re-prefill (`stale_steer_*`) — never a lost request, never an audit
/// failure. The staleness-aware fleet auditor must hold at every step,
/// and the mirror must converge to exact (both directions) once the
/// fleet quiesces and the network flushes.
#[test]
fn prop_gossip_staleness_only_costs_reprefill() {
    use lamps::config::NetModelKind;
    let mut rng = Rng::new(0x5E7_0030);
    for (model, replicas, placement) in [
        (NetModelKind::Lan, 3usize, PlacementKind::PrefixAffinity),
        (NetModelKind::Wan, 4, PlacementKind::PrefixAffinity),
        (NetModelKind::Lan, 4, PlacementKind::MemoryOverTime),
    ] {
        let trace = random_shared_trace(&mut rng, 40);
        let n = trace.len() as u64;
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        cfg.memory_budget = Tokens(1_500);
        cfg.replicas = replicas;
        cfg.placement = placement;
        cfg.prefix_cache = PrefixCacheConfig::on();
        cfg.shared_prefix = true;
        cfg.net.model = model;
        let mut set = ReplicaSet::simulated(cfg);
        assert!(set.net_state().is_some(), "{model:?} must arm the net");
        for spec in &trace.requests {
            set.enqueue(spec.clone());
        }
        let mut steps = 0u64;
        while set.step() {
            steps += 1;
            assert!(steps < 5_000_000, "fleet failed to drain");
            // The bounded-staleness auditor must forgive exactly the
            // in-flight window and nothing else, at every step.
            if let Err(e) = lamps::audit::check_fleet(&set) {
                panic!("{model:?}/{placement:?}: staleness-aware fleet \
                        invariant violated: {e}");
            }
        }
        let report = set.fleet_report();
        assert_eq!(report.fleet.completed as u64, n,
                   "{model:?}/{placement:?}: staleness may slow, \
                    never lose");
        let stats = report.net.as_ref().expect("armed run reports net");
        assert!(stats.gossip_messages > 0,
                "deltas and digests must actually ride the network");

        // Quiesce: the final no-progress round flushes the network, so
        // the mirror is exact again — in both directions.
        let index = set.shared_index().expect("shared index active");
        assert_index_subset_of_resident(&set);
        for i in 0..set.len() {
            for hash in set.replica(i).resident_prefix_hashes() {
                assert!(index.holds(hash, i),
                        "{model:?}: resident {hash:#x} on replica {i} \
                         missing from the flushed mirror");
            }
        }
    }
}

/// `--net-model off` (the default) must keep the fleet byte-identical
/// to the network-less path: same report JSON, same dispatch log, no
/// "net" key — regardless of how the other (inert when off) network
/// knobs are set, across placements.
#[test]
fn prop_net_model_off_is_byte_identical() {
    for placement in [PlacementKind::PrefixAffinity,
                      PlacementKind::MemoryOverTime,
                      PlacementKind::LeastLoaded,
                      PlacementKind::RoundRobin] {
        let mut rng = Rng::new(0x5E7_0040);
        let trace = random_shared_trace(&mut rng, 35);
        let run = |touch_knobs: bool| {
            let mut cfg = SystemConfig::preset("lamps").unwrap();
            cfg.memory_budget = Tokens(2_000);
            cfg.replicas = 3;
            cfg.placement = placement;
            cfg.prefix_cache = PrefixCacheConfig::on();
            cfg.shared_prefix = true;
            if touch_knobs {
                // Everything but the model itself: all inert when off.
                cfg.net.gossip_interval = Micros(1_000);
                cfg.net.staleness_budget = Micros(7_000);
                cfg.net.topk = 2;
            }
            let mut set = ReplicaSet::simulated(cfg);
            let report = set.run_trace(&trace);
            (report.to_json(true), set.assignments().to_vec())
        };
        let (default_json, default_assigned) = run(false);
        let (knobs_json, knobs_assigned) = run(true);
        assert_eq!(default_assigned, knobs_assigned, "{placement:?}");
        assert_eq!(default_json, knobs_json,
                   "{placement:?}: off-path knobs must be inert");
        assert!(!default_json.contains("\"net\""),
                "no net block may appear with the model off");
    }
}

/// The always-on invariant auditor must be observationally pure: a
/// fig6-shaped fleet run with the auditor forced on yields a
/// byte-identical timeline report to the same run with it forced off —
/// where this test drives the promoted checker
/// ([`lamps::audit::check_fleet`]) by hand after every step instead.
#[test]
fn prop_audit_mode_is_byte_invisible_to_the_fleet_report() {
    use lamps::config::AuditMode;
    let mut rng = Rng::new(0xA0D1_7EA);
    let trace = random_trace(&mut rng, 60);
    let run = |audit: AuditMode, check_by_hand: bool| {
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        cfg.memory_budget = Tokens(3_000);
        cfg.replicas = 4;
        cfg.placement = PlacementKind::MemoryOverTime;
        cfg.audit = audit;
        let mut set = ReplicaSet::simulated(cfg);
        set.set_record_timeline(true);
        for spec in &trace.requests {
            set.enqueue(spec.clone());
        }
        let mut steps = 0u64;
        while set.step() {
            steps += 1;
            assert!(steps < 5_000_000, "fleet failed to drain");
            if check_by_hand {
                if let Err(e) = lamps::audit::check_fleet(&set) {
                    panic!("fleet invariant violated: {e}");
                }
            }
        }
        set.fleet_report().to_json(true)
    };
    let on = run(AuditMode::On, false);
    let off = run(AuditMode::Off, true);
    assert_eq!(on, off, "the auditor must not perturb the run");
}
