//! Randomized property tests of multi-replica dispatch
//! (`cluster::ReplicaSet`), driven by the crate's deterministic
//! `util::Rng` (fixed seeds — every failure is exactly reproducible):
//!
//! - every request is placed on exactly one replica, exists only there,
//!   and completes there (no migration, no loss, no duplication),
//! - per-replica KV conservation: every replica's block manager drains
//!   back to zero occupancy once its requests finish,
//! - a `replicas = 1` fleet reproduces the single-`Engine` run of the
//!   same trace/seed **byte-identically** (the refactor's safety rail),
//!   including with the chunked composer and the prefix cache enabled,
//! - round-robin placement is a pure rotation in arrival order.

use std::collections::BTreeMap;

use lamps::cluster::ReplicaSet;
use lamps::config::{PlacementKind, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::Engine;
use lamps::util::Rng;
use lamps::workload::{infercept, Trace};

/// Mixed augmented/plain trace with random arrivals, prompts, API
/// durations, and decode lengths.
fn random_trace(rng: &mut Rng, n: u64) -> Trace {
    let mut t = 0u64;
    let specs = (0..n)
        .map(|i| {
            t += rng.int_range(0, 400_000);
            let api_calls = if rng.f64() < 0.5 {
                vec![ApiCallSpec {
                    decode_before: Tokens(rng.int_range(1, 30)),
                    api_type: ApiType::Qa,
                    duration: Micros(rng.int_range(100_000, 5_000_000)),
                    response_tokens: Tokens(rng.int_range(0, 8)),
                }]
            } else {
                vec![]
            };
            RequestSpec {
                id: RequestId(i),
                arrival: Micros(t),
                prompt: String::new(),
                prompt_tokens: Tokens(rng.int_range(0, 200)),
                api_calls,
                final_decode: Tokens(rng.int_range(1, 40)),
            }
        })
        .collect();
    Trace::new("random", 1.0, specs)
}

#[test]
fn prop_each_request_lands_on_exactly_one_replica() {
    let mut rng = Rng::new(0x5E7_0001);
    let policies = [PlacementKind::MemoryOverTime,
                    PlacementKind::LeastLoaded,
                    PlacementKind::RoundRobin];
    for case in 0..6u64 {
        let n = 30 + case * 5;
        let trace = random_trace(&mut rng, n);
        let replicas = 2 + (case % 3) as usize;
        for policy in policies {
            let mut cfg = SystemConfig::preset("lamps").unwrap();
            cfg.memory_budget = Tokens(10_000);
            cfg.replicas = replicas;
            cfg.placement = policy;
            let mut set = ReplicaSet::simulated(cfg);
            let report = set.run_trace(&trace);

            // Exactly one placement per request, on a real replica.
            let mut owner: BTreeMap<RequestId, usize> = BTreeMap::new();
            for &(id, r) in set.assignments() {
                assert!(r < replicas, "replica index out of range");
                assert!(owner.insert(id, r).is_none(),
                        "{id} placed twice ({policy:?})");
            }
            assert_eq!(owner.len() as u64, n,
                       "every request must be placed ({policy:?})");

            // The request lives (and finished) on its owner — and on no
            // other replica.
            for (&id, &r) in &owner {
                for other in 0..replicas {
                    let found = set.replica(other).request(id);
                    if other == r {
                        let req = found.unwrap_or_else(|| {
                            panic!("{id} missing from its owner")
                        });
                        assert!(req.is_finished(),
                                "{id} unfinished on replica {r}");
                    } else {
                        assert!(found.is_none(),
                                "{id} leaked onto replica {other}");
                    }
                }
            }

            // Fan-in accounting: per-replica submissions/completions
            // partition the trace.
            let submitted: usize =
                report.per_replica.iter().map(|p| p.submitted).sum();
            let completed: usize =
                report.per_replica.iter().map(|p| p.completed).sum();
            assert_eq!(submitted as u64, n);
            assert_eq!(completed as u64, n);
            assert_eq!(report.fleet.completed as u64, n);

            // Per-replica KV conservation: every block manager drains.
            for i in 0..replicas {
                assert_eq!(set.replica(i).kv_occupancy(), 0.0,
                           "replica {i} leaked KV ({policy:?})");
            }
        }
    }
}

#[test]
fn prop_round_robin_is_pure_rotation() {
    let mut rng = Rng::new(0x5E7_0002);
    let trace = random_trace(&mut rng, 25);
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.memory_budget = Tokens(10_000);
    cfg.replicas = 4;
    cfg.placement = PlacementKind::RoundRobin;
    let mut set = ReplicaSet::simulated(cfg);
    set.run_trace(&trace);
    // Arrivals are strictly increasing in id here, so dispatch order is
    // id order and the rotation is exact.
    for (i, &(_, r)) in set.assignments().iter().enumerate() {
        assert_eq!(r, i % 4);
    }
}

/// `replicas = 1` must reproduce the single-engine run byte for byte —
/// same JSON report (all counters, timings, and summaries), across
/// schedulers and with the composer/prefix-cache features on.
#[test]
fn prop_single_replica_fleet_is_byte_identical_to_engine() {
    for (system, seed) in [("lamps", 42u64), ("vllm", 7), ("infercept", 3)]
    {
        for chunked in [false, true] {
            let mut cfg = SystemConfig::preset(system).unwrap();
            cfg.memory_budget = Tokens(9_000);
            cfg.seed = seed;
            if chunked {
                cfg.compose = lamps::config::ComposeConfig::chunked();
                cfg.prefix_cache =
                    lamps::config::PrefixCacheConfig::on();
            }
            let trace = infercept::single_api_dataset(40, 4.0, seed);

            let mut engine = Engine::simulated(cfg.clone());
            let solo = engine.run_trace(&trace);

            cfg.replicas = 1;
            let mut set = ReplicaSet::simulated(cfg);
            let fleet = set.run_trace(&trace);

            assert_eq!(solo.to_json(true), fleet.fleet.to_json(true),
                       "{system} seed {seed} chunked {chunked}: \
                        replicas = 1 diverged from the single engine");
            assert_eq!(fleet.per_replica.len(), 1);
        }
    }
}

/// Same check on a multi-API dataset, both uncapped and through the
/// fleet driver's frontier-based time-cap semantics.
#[test]
fn prop_single_replica_fleet_matches_engine_multi_api() {
    for cap in [None, Some(Micros(20_000_000))] {
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        cfg.memory_budget = Tokens(9_000);
        let trace = infercept::multi_api_dataset(30, 3.0, 11);

        let mut engine = Engine::simulated(cfg.clone());
        let solo = engine.run_trace_limited(&trace, cap);

        let mut set = ReplicaSet::simulated(cfg);
        let fleet = set.run_trace_limited(&trace, cap);
        assert_eq!(solo.to_json(true), fleet.fleet.to_json(true),
                   "cap {cap:?}");
    }
}
