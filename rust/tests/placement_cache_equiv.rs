//! Score-cache equivalence suite (PR 8): the epoch-keyed memo behind
//! `Engine::load_memory_over_time` must be invisible. Two angles:
//!
//! 1. **Per-step oracle agreement**: drive randomized fleets through
//!    admissions, decodes, API parks, preemptions, rescues, and
//!    completions, and after *every* fleet step assert each replica's
//!    cached score is bit-identical to the from-scratch recompute
//!    (`load_memory_over_time_uncached`). In debug builds the engine
//!    additionally shadow-recomputes on every cache hit and aborts on
//!    divergence, so a missed `touch_load` call site fails twice over.
//! 2. **Placement byte-identity**: the same trace run with
//!    `placement_cache` on and off must produce identical placement
//!    assignments and an identical fleet report (timeline included) —
//!    the cache is a perf lever, never a policy change.

use lamps::bench::Dataset;
use lamps::cluster::ReplicaSet;
use lamps::config::{PlacementKind, PrefixCacheConfig, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::workload::Trace;

/// Deterministic splitmix-flavored LCG — no rand dependency.
fn lcg(state: &mut u64) -> u64 {
    *state = state
        .wrapping_mul(6364136223846793005)
        .wrapping_add(1442695040888963407);
    *state >> 33
}

/// Randomized mixed spec: every fourth request is a long-prompt heavy
/// job (forcing preemption and admission rescues on small budgets),
/// one in three carries an API call (parks and resumptions), and
/// prompts share one of three textual families (real prefix-cache and
/// shared-index traffic, not just token counts).
fn random_spec(id: u64, rng: &mut u64) -> RequestSpec {
    let heavy = lcg(rng) % 4 == 0;
    let family = lcg(rng) % 3;
    let prompt = format!(
        "family-{family} shared preamble for the placement equivalence \
         suite; user turn {}", lcg(rng) % 97);
    let prompt_tokens = Tokens(if heavy {
        700 + lcg(rng) % 500
    } else {
        48 + lcg(rng) % 96
    });
    let api_calls = if lcg(rng) % 3 == 0 {
        vec![ApiCallSpec {
            decode_before: Tokens(8 + lcg(rng) % 24),
            api_type: ApiType::Qa,
            duration: Micros(400_000 + (lcg(rng) % 5) * 250_000),
            response_tokens: Tokens(4 + lcg(rng) % 12),
        }]
    } else {
        vec![]
    };
    RequestSpec {
        id: RequestId(id),
        arrival: Micros(id * 40_000),
        prompt,
        prompt_tokens,
        api_calls,
        final_decode: Tokens(24 + lcg(rng) % 48),
    }
}

fn random_trace(n: u64, seed: u64) -> Trace {
    let mut rng = seed;
    let specs = (0..n).map(|i| random_spec(i, &mut rng)).collect();
    Trace::new("equiv-fuzz", 25.0, specs)
}

/// The config matrix the suite sweeps: placement policy x prefix cache
/// (with the fleet-shared index under affinity) on a small 3-replica
/// fleet whose budget forces preemptions and rescues.
fn configs() -> Vec<(&'static str, SystemConfig)> {
    let base = {
        let mut cfg = SystemConfig::preset("lamps").unwrap();
        cfg.replicas = 3;
        cfg.memory_budget = Tokens(3_000);
        cfg
    };
    let mut out = Vec::new();
    let mut mot = base.clone();
    mot.placement = PlacementKind::MemoryOverTime;
    out.push(("memory-over-time", mot));
    let mut mot_cache = base.clone();
    mot_cache.placement = PlacementKind::MemoryOverTime;
    mot_cache.prefix_cache = PrefixCacheConfig::on();
    out.push(("memory-over-time + prefix cache", mot_cache));
    let mut affinity = base.clone();
    affinity.placement = PlacementKind::PrefixAffinity;
    affinity.prefix_cache = PrefixCacheConfig::on();
    affinity.shared_prefix = true;
    out.push(("prefix-affinity + shared index", affinity));
    out
}

const STEP_CAP: usize = 400_000;

/// Angle 1: after every fleet step, every replica's cached probe must
/// agree bit-for-bit with the stateless recompute.
#[test]
fn cached_score_matches_recompute_after_every_step() {
    for (name, cfg) in configs() {
        let trace = random_trace(60, 0xC0FFEE ^ cfg.placement as u64);
        let mut set = ReplicaSet::simulated(cfg);
        for spec in &trace.requests {
            set.enqueue(spec.clone());
        }
        let mut steps = 0usize;
        loop {
            let more = set.step();
            for i in 0..set.len() {
                let e = set.replica(i);
                let cached = e.load_memory_over_time();
                let fresh = e.load_memory_over_time_uncached();
                assert_eq!(
                    cached.to_bits(), fresh.to_bits(),
                    "[{name}] replica {i} step {steps}: cached score \
                     {cached} != recompute {fresh}");
            }
            steps += 1;
            assert!(steps < STEP_CAP,
                    "[{name}] fleet did not drain in {STEP_CAP} steps");
            if !more {
                break;
            }
        }
    }
}

/// Angle 1 on curated traffic: the InferCept-style multi-API dataset
/// (every request parks at least once) through the same per-step check.
#[test]
fn cached_score_matches_recompute_on_multi_api_traffic() {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.replicas = 3;
    cfg.memory_budget = Tokens(4_000);
    cfg.placement = PlacementKind::MemoryOverTime;
    let trace = Dataset::MultiApi.generate(40, 6.0, 42);
    let mut set = ReplicaSet::simulated(cfg);
    for spec in &trace.requests {
        set.enqueue(spec.clone());
    }
    let mut steps = 0usize;
    loop {
        let more = set.step();
        for i in 0..set.len() {
            let e = set.replica(i);
            assert_eq!(e.load_memory_over_time().to_bits(),
                       e.load_memory_over_time_uncached().to_bits(),
                       "replica {i} diverged at step {steps}");
        }
        steps += 1;
        assert!(steps < STEP_CAP, "fleet did not drain");
        if !more {
            break;
        }
    }
}

/// Angle 2: cache on vs cache off is byte-identical — same placement
/// assignments, same fleet report (timeline included).
#[test]
fn placement_assignments_identical_cache_on_and_off() {
    for (name, cfg) in configs() {
        let trace = random_trace(60, 0xBADCAB ^ cfg.placement as u64);
        let run = |cache: bool| {
            let mut cfg = cfg.clone();
            cfg.placement_cache = cache;
            let mut set = ReplicaSet::simulated(cfg);
            set.set_record_timeline(true);
            let report = set.run_trace(&trace);
            (report.to_json(true), set.assignments().to_vec())
        };
        let (report_on, assign_on) = run(true);
        let (report_off, assign_off) = run(false);
        assert_eq!(assign_on, assign_off,
                   "[{name}] placement assignments diverged between \
                    cache on and off");
        assert_eq!(report_on, report_off,
                   "[{name}] fleet report diverged between cache on \
                    and off");
    }
}
