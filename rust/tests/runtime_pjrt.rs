//! PJRT runtime integration: load the AOT artifacts and exercise the real
//! compute path (prefill, decode, predictor) plus a whole-engine run on
//! the PJRT backend.
//!
//! These tests need `make artifacts` to have run; they skip (pass with a
//! notice) when artifacts are absent so plain `cargo test` stays green in
//! a fresh checkout. The whole file is compiled out without the `pjrt`
//! feature (`--no-default-features` builds have no runtime layer).

#![cfg(feature = "pjrt")]

use lamps::config::{SchedulerKind, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::backend::{Backend, DecodeSlot};
use lamps::engine::clock::Clock;
use lamps::engine::pjrt_backend::PjrtBackend;
use lamps::engine::Engine;
use lamps::predictor::opt_classifier::PjrtPredictor;
use lamps::runtime::{ArtifactMeta, ModelRuntime, PredictorRuntime,
                     RuntimeClient};

fn artifacts() -> Option<ArtifactMeta> {
    match ArtifactMeta::load_default() {
        Ok(meta) => Some(meta),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn model_prefill_decode_roundtrip() {
    let Some(meta) = artifacts() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let model = ModelRuntime::load(&client, &meta, "gptj-tiny").unwrap();
    let b = model.meta.batch;
    let s = model.meta.max_seq;

    let mut tokens = vec![0i32; b * s];
    tokens[..5].copy_from_slice(&[1, 40, 41, 42, 43]);
    let mut lengths = vec![0i32; b];
    lengths[0] = 5;
    let pre = model.run_prefill(&tokens, &lengths).unwrap();
    assert_eq!(pre.next_tokens.len(), b);
    assert_eq!(pre.k.len(), model.meta.kv_elements());
    let next = pre.next_tokens[0];
    assert!((0..model.meta.vocab_size as i32).contains(&next));

    // Decode one step from the prefilled cache.
    let mut token = vec![0i32; b];
    token[0] = next;
    let mut pos = vec![0i32; b];
    pos[0] = 5;
    let dec = model.run_decode(&token, &pos, &pre.k, &pre.v).unwrap();
    assert!((0..model.meta.vocab_size as i32)
        .contains(&dec.next_tokens[0]));

    // Determinism: same inputs -> same outputs.
    let dec2 = model.run_decode(&token, &pos, &pre.k, &pre.v).unwrap();
    assert_eq!(dec.next_tokens, dec2.next_tokens);
}

#[test]
fn prefill_then_decode_matches_longer_prefill() {
    // The KV-cache identity the serving path relies on, checked through
    // the real HLO executables: greedy(prefill(p)) fed through one decode
    // step must equal greedy(prefill(p + [t])).
    let Some(meta) = artifacts() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let model = ModelRuntime::load(&client, &meta, "gptj-tiny").unwrap();
    let b = model.meta.batch;
    let s = model.meta.max_seq;

    let prompt = [1i32, 100, 200, 300];
    let mut tokens = vec![0i32; b * s];
    tokens[..4].copy_from_slice(&prompt);
    let mut lengths = vec![0i32; b];
    lengths[0] = 4;
    let pre = model.run_prefill(&tokens, &lengths).unwrap();
    let t5 = pre.next_tokens[0];

    let mut token = vec![0i32; b];
    token[0] = t5;
    let mut pos = vec![0i32; b];
    pos[0] = 4;
    let dec = model.run_decode(&token, &pos, &pre.k, &pre.v).unwrap();
    let t6_decode = dec.next_tokens[0];

    let mut tokens2 = vec![0i32; b * s];
    tokens2[..4].copy_from_slice(&prompt);
    tokens2[4] = t5;
    let mut lengths2 = vec![0i32; b];
    lengths2[0] = 5;
    let pre2 = model.run_prefill(&tokens2, &lengths2).unwrap();
    assert_eq!(t6_decode, pre2.next_tokens[0],
               "decode-step continuation must match longer prefill");
}

#[test]
fn predictor_orders_brief_below_exhaustive() {
    let Some(meta) = artifacts() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let pred = PredictorRuntime::load(&client, &meta).unwrap();
    // The size hint + detail word carry the length signal (corpus.py).
    let brief = pred
        .predict_bin("call the weather api with a brief answer scale n2 \
                      please fetch the current value")
        .unwrap();
    let verbose = pred
        .predict_bin("call the code api with a exhaustive answer scale \
                      n55 please fetch the current value")
        .unwrap();
    assert!(brief < verbose, "brief bin {brief} vs verbose {verbose}");
    assert!(pred.bin_to_tokens(verbose) > pred.bin_to_tokens(brief));
}

#[test]
fn pjrt_backend_generates_tokens() {
    let Some(meta) = artifacts() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let model = ModelRuntime::load(&client, &meta, "gptj-tiny").unwrap();
    let vocab = model.meta.vocab_size as i32;
    let mut backend = PjrtBackend::new(model);
    let id = RequestId(7);
    let elapsed = backend.materialize(id, "call the weather api",
                                      Tokens(5), Tokens(5));
    assert!(elapsed > Micros::ZERO);
    for _ in 0..4 {
        let slots = [DecodeSlot {
            id,
            ctx: Tokens(5),
        }];
        backend.decode(&slots);
    }
    let generated = backend.generated_tokens(id).unwrap().to_vec();
    assert_eq!(generated.len(), 4);
    assert!(generated.iter().all(|t| (0..vocab).contains(t)));
    backend.release(id);
    // History survives release for post-completion retrieval.
    assert_eq!(backend.generated_tokens(id).unwrap(), &generated[..]);
}

#[test]
fn engine_on_pjrt_backend_serves_requests() {
    // The full stack: LAMPS engine + PJRT compute + PJRT predictor, real
    // token generation, wall-clock.
    let Some(meta) = artifacts() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let model = ModelRuntime::load(&client, &meta, "gptj-tiny").unwrap();
    let pred = PredictorRuntime::load(&client, &meta).unwrap();
    let batch = model.meta.batch;
    let max_seq = model.meta.max_seq;

    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.scheduler = SchedulerKind::Lamps;
    cfg.memory_budget = Tokens((batch * max_seq) as u64);
    cfg.max_batch = batch;
    cfg.block_size = 16;

    let backend = Box::new(PjrtBackend::new(model));
    let predictor = Box::new(PjrtPredictor::new(pred));
    let mut engine =
        Engine::new(cfg, backend, predictor, Clock::wall_clock());

    for i in 0..3u64 {
        engine.submit(RequestSpec {
            id: RequestId(i),
            arrival: Micros::ZERO,
            prompt: format!("call the weather api with a brief answer \
                             scale n{} please", 2 + i),
            prompt_tokens: Tokens(10),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(4),
                api_type: ApiType::Tool(0),
                duration: Micros(20_000), // 20 ms simulated API
                response_tokens: Tokens(2),
            }],
            final_decode: Tokens(5),
        });
    }
    engine.run_until_idle(None);
    for i in 0..3u64 {
        let r = engine.request(RequestId(i)).unwrap();
        assert!(r.is_finished(), "r{i} unfinished");
        assert!(r.finished_at.unwrap() >= Micros(20_000),
                "API wait must be real time");
    }
    assert_eq!(engine.metrics.completed(), 3);
    // Real tokens came out of the model.
    let any = engine.backend_any().unwrap();
    let backend = any.downcast_ref::<PjrtBackend>().unwrap();
    let toks = backend.generated_tokens(RequestId(0)).unwrap();
    assert!(toks.len() >= 9, "4 pre-API + 5 final tokens, got {toks:?}");
}
