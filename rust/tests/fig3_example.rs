//! Exact reproduction of the paper's Fig. 3 worked example (§3.1).
//!
//! Three requests, all arriving at t=0, one execution slot, memory budget
//! of 6 token-units, 1 decode token = 1 time unit (Table 1):
//!
//! | req | total len | API after | API duration | handling  |
//! |-----|-----------|-----------|--------------|-----------|
//! | R1  | 6         | 5         | 2            | Preserve  |
//! | R2  | 2         | 1         | 7            | Discard   |
//! | R3  | 3         | 2         | 1            | Swap      |
//!
//! The paper reports average completion times:
//!   FCFS (Fig 3a) 11.66, SJF (Fig 3b) 10.33, SJF-total (Fig 3c) 11,
//!   LAMPS (Fig 3d) 10.
//! These tests assert the **exact** per-request completion times behind
//! those averages.

use lamps::config::{CostModel, SchedulerKind, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                           RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::Engine;

const UNIT: u64 = 1_000_000; // 1 time unit = 1 s in microseconds

fn fig3_spec(id: u64, pre: u64, api_units: u64, post: u64) -> RequestSpec {
    RequestSpec {
        id: RequestId(id),
        arrival: Micros::ZERO,
        prompt: String::new(),
        prompt_tokens: Tokens(0),
        api_calls: vec![ApiCallSpec {
            decode_before: Tokens(pre),
            api_type: ApiType::Qa,
            duration: Micros(api_units * UNIT),
            response_tokens: Tokens(0),
        }],
        final_decode: Tokens(post),
    }
}

fn fig3_engine(scheduler: SchedulerKind, lookahead: bool) -> Engine {
    let cfg = SystemConfig {
        scheduler,
        memory_budget: Tokens(6),
        max_batch: 1,
        block_size: 1,
        starvation_threshold: None,
        admission_lookahead: lookahead,
        cost: CostModel::unit(),
        ..SystemConfig::default()
    };
    let mut engine = Engine::simulated(cfg);
    // Table 1's strategies (determined by the INFERCEPT equations in the
    // paper's cost regime) are given explicitly.
    engine.submit_with_handling(fig3_spec(1, 5, 2, 1),
                                vec![HandlingStrategy::Preserve]);
    engine.submit_with_handling(fig3_spec(2, 1, 7, 1),
                                vec![HandlingStrategy::Discard]);
    engine.submit_with_handling(fig3_spec(3, 2, 1, 1),
                                vec![HandlingStrategy::Swap]);
    engine
}

fn completions(engine: &Engine) -> [f64; 3] {
    let f = |id: u64| {
        engine
            .request(RequestId(id))
            .unwrap()
            .finished_at
            .expect("finished")
            .as_secs_f64()
    };
    [f(1), f(2), f(3)]
}

fn average(xs: &[f64; 3]) -> f64 {
    xs.iter().sum::<f64>() / 3.0
}

#[test]
fn fcfs_matches_fig3a() {
    // Walkthrough (paper §3.1): R1 decodes 0..5, preserves through its
    // API 5..7 while R2's pre-API part runs 5..6 (it discards in time);
    // R3 is rejected during the call (it would still hold memory at 7).
    // R1 resumes 7..8; R3 runs 8..12; R2's recompute+post runs 13..15.
    let mut engine = fig3_engine(SchedulerKind::Fcfs, true);
    engine.run_until_idle(None);
    let done = completions(&engine);
    assert_eq!(done, [8.0, 15.0, 12.0], "completion times");
    assert!((average(&done) - 11.6667).abs() < 1e-3,
            "avg {} vs paper 11.66", average(&done));
}

#[test]
fn sjf_matches_fig3b() {
    // SJF by output length: R2 (2) < R3 (3) < R1 (6). The paper: "At time
    // unit 9, R1 enters its API call" and R2's post-API part must wait
    // for R1 to finish.
    let mut engine = fig3_engine(SchedulerKind::Sjf, true);
    engine.run_until_idle(None);
    let done = completions(&engine);
    assert_eq!(done, [12.0, 14.0, 5.0], "completion times");
    assert!((average(&done) - 10.3333).abs() < 1e-3,
            "avg {} vs paper 10.33", average(&done));
}

#[test]
fn sjf_total_matches_fig3c() {
    // SJF by total length (output + API): R3 (4) < R1 (8) < R2 (9).
    let mut engine = fig3_engine(SchedulerKind::SjfTotal, true);
    engine.run_until_idle(None);
    let done = completions(&engine);
    assert_eq!(done, [11.0, 18.0, 4.0], "completion times");
    assert!((average(&done) - 11.0).abs() < 1e-3,
            "avg {} vs paper 11", average(&done));
}

#[test]
fn lamps_matches_fig3d() {
    // Memory-over-time ranking: R3 < R2 < R1. "The post-API part of R2
    // becomes ready at time unit 10, but due to memory constraints, it
    // waits until R1 finishes."
    let mut engine = fig3_engine(SchedulerKind::Lamps, true);
    engine.run_until_idle(None);
    let done = completions(&engine);
    assert_eq!(done, [12.0, 14.0, 4.0], "completion times");
    assert!((average(&done) - 10.0).abs() < 1e-3,
            "avg {} vs paper 10", average(&done));
}

#[test]
fn policy_ordering_matches_paper() {
    // LAMPS (10) < SJF (10.33) < SJF-total (11) < FCFS (11.66).
    let mut avgs = Vec::new();
    for kind in [SchedulerKind::Lamps, SchedulerKind::Sjf,
                 SchedulerKind::SjfTotal, SchedulerKind::Fcfs] {
        let mut engine = fig3_engine(kind, true);
        engine.run_until_idle(None);
        avgs.push(average(&completions(&engine)));
    }
    assert!(avgs[0] < avgs[1] && avgs[1] < avgs[2] && avgs[2] < avgs[3],
            "expected LAMPS < SJF < SJF-total < FCFS, got {avgs:?}");
}

#[test]
fn all_requests_complete_without_lookahead_too() {
    // The clairvoyant reservation shapes the schedule but must never be
    // required for liveness.
    for kind in [SchedulerKind::Fcfs, SchedulerKind::Sjf,
                 SchedulerKind::SjfTotal, SchedulerKind::Lamps] {
        let mut engine = fig3_engine(kind, false);
        engine.run_until_idle(None);
        for id in [1, 2, 3] {
            assert!(engine.request(RequestId(id)).unwrap().is_finished(),
                    "{kind:?} r{id} unfinished without lookahead");
        }
    }
}
