//! Event-stream invariants of the session API, end to end through the
//! serving frontend: per session, events arrive in causal order
//! (`Queued` ≤ `Placed` ≤ [`Rescued`] ≤ `FirstToken` ≤ terminal),
//! exactly one terminal event (`Finished` xor `Dropped`) closes the
//! stream, `ApiCallStarted`/`ApiCallCompleted` pair up per index, and
//! nothing is ever delivered after the terminal — including on a
//! randomized multi-replica run with the admission re-queue rescuing
//! sessions between replicas mid-stream.

use std::time::Duration;

use lamps::config::{CostModel, HandlingPolicy, PlacementKind,
                    SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                           RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::audit::{check_stream, StreamEvent};
use lamps::server::{self, RequestEvent};

fn fast_cost() -> CostModel {
    CostModel {
        decode_base: Micros(200),
        decode_per_ctx_token_us: 0.0,
        prefill_per_token_us: 5.0,
        swap_base_us: 0.0,
        swap_per_token_us: 0.0,
        rank_overhead_per_request_us: 0.0,
    }
}

fn spec(prompt_tokens: u64, api_calls: Vec<ApiCallSpec>,
        final_decode: u64) -> RequestSpec {
    RequestSpec {
        id: RequestId(0), // assigned by the server
        arrival: Micros::ZERO,
        prompt: String::new(),
        prompt_tokens: Tokens(prompt_tokens),
        api_calls,
        final_decode: Tokens(final_decode),
    }
}

fn sim_call(decode_before: u64, api_ms: u64, response: u64)
            -> ApiCallSpec {
    ApiCallSpec {
        decode_before: Tokens(decode_before),
        api_type: ApiType::Tool(0),
        duration: Micros(api_ms * 1000),
        response_tokens: Tokens(response),
    }
}

/// The satellite invariants, checked over one session's full stream
/// by the promoted stream machine ([`lamps::audit::check_stream`] —
/// the same checker the engine's always-on auditor feeds), plus the
/// server-level head shape the engine-journal alphabet deliberately
/// leaves optional (sessions always announce Queued then Placed).
fn assert_stream_invariants(events: &[RequestEvent]) {
    assert!(!events.is_empty(), "a session delivers at least a terminal");
    // Exactly one terminal event, and it closes the stream.
    let terminals =
        events.iter().filter(|e| e.is_terminal()).count();
    assert_eq!(terminals, 1, "exactly one terminal event: {events:?}");
    assert!(events.last().unwrap().is_terminal(),
            "the terminal event must be last: {events:?}");
    // Causal prefix: Queued first, Placed second.
    assert!(matches!(events[0], RequestEvent::Queued),
            "stream must start with Queued: {events:?}");
    assert!(matches!(events[1], RequestEvent::Placed { .. }),
            "Placed must directly follow Queued: {events:?}");
    // Everything else — rescue-before-execution, FirstToken ≤ 1 and
    // before Tokens, API calls pairing in index order without nesting,
    // finishing only with no call open, nothing after the terminal —
    // is the machine's contract.
    let mapped = events.iter().filter_map(|e| {
        Some(match e {
            RequestEvent::Queued => StreamEvent::Queued,
            RequestEvent::Placed { .. } => StreamEvent::Placed,
            RequestEvent::Rescued { .. } => StreamEvent::Rescued,
            RequestEvent::FirstToken => StreamEvent::FirstToken,
            RequestEvent::Tokens { .. } => StreamEvent::Tokens,
            RequestEvent::ApiCallStarted { index, .. } => {
                StreamEvent::ApiStarted { index: *index }
            }
            RequestEvent::ApiCallCompleted { index, .. } => {
                StreamEvent::ApiCompleted { index: *index }
            }
            RequestEvent::Finished(_) => {
                StreamEvent::Terminal { finished: true }
            }
            RequestEvent::Dropped { .. } => {
                StreamEvent::Terminal { finished: false }
            }
            // Non-terminal protocol errors carry no lifecycle state.
            RequestEvent::Error { .. } => return None,
        })
    });
    if let Err(e) = check_stream(RequestId(0), mapped) {
        panic!("stream invariant violated: {e}\nin {events:?}");
    }
}

/// Drain a session to its terminal event, then assert the stream is
/// truly closed (nothing may ever follow the terminal).
fn drain(session: server::SessionHandle) -> Vec<RequestEvent> {
    let mut events = Vec::new();
    loop {
        let ev = session
            .next_event()
            .expect("stream must stay open through the terminal");
        let terminal = ev.is_terminal();
        events.push(ev);
        if terminal {
            break;
        }
    }
    assert!(session.next_event().is_none(),
            "no event may be delivered after the terminal one");
    events
}

#[test]
fn single_session_causal_order_and_api_pairing() {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    let (handle, _join) = server::spawn_sim(cfg);
    let session = handle
        .open_session(spec(
            3,
            vec![sim_call(2, 20, 2), sim_call(1, 5, 0)],
            2,
        ))
        .unwrap();
    let events = drain(session);
    assert_stream_invariants(&events);
    // Both calls started and completed.
    let starts = events
        .iter()
        .filter(|e| matches!(e, RequestEvent::ApiCallStarted { .. }))
        .count();
    assert_eq!(starts, 2);
    let RequestEvent::Finished(c) = events.last().unwrap() else {
        panic!("expected Finished: {events:?}");
    };
    assert_eq!(c.tokens_decoded, 5, "2 + 1 + 2 decode tokens");
    assert!(c.dropped.is_none());
    handle.shutdown();
}

#[test]
fn dropped_session_gets_terminal_reason() {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.memory_budget = Tokens(10);
    let (handle, _join) = server::spawn_sim(cfg);
    let session = handle.open_session(spec(50, vec![], 1)).unwrap();
    let events = drain(session);
    assert_stream_invariants(&events);
    let RequestEvent::Dropped { reason } = events.last().unwrap() else {
        panic!("expected Dropped: {events:?}");
    };
    assert!(reason.contains("capacity"), "{reason}");
    // The blocking wrapper reports the same drop as a zero-token
    // completion carrying the reason.
    let completion =
        handle.submit_blocking(spec(50, vec![], 1)).unwrap();
    assert_eq!(completion.tokens_decoded, 0);
    assert!(completion.dropped.as_deref().unwrap().contains("capacity"));
    handle.shutdown();
}

#[test]
fn rescued_session_streams_from_new_owner() {
    // Deterministic admission-rescue through the serving frontend:
    // round-robin puts a 25-token hog on replica 0 and parks it there
    // under a Preserve API call; the next replica-0 arrival cannot fit
    // and must be rescued to the idle replica 1, its stream carrying
    // Rescued{0→1} before any execution event.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.handling = HandlingPolicy::Forced(HandlingStrategy::Preserve);
    cfg.replicas = 2;
    cfg.placement = PlacementKind::RoundRobin;
    cfg.memory_budget = Tokens(30);
    cfg.block_size = 1;
    let (handle, _join) = server::spawn_sim(cfg);

    // Hog → replica 0 (round-robin slot 0).
    let hog = handle
        .open_session(spec(25, vec![sim_call(2, 400, 0)], 1))
        .unwrap();
    // Small filler → replica 1 (slot 1); completes immediately.
    let filler = handle.open_session(spec(2, vec![], 1)).unwrap();
    assert_stream_invariants(&drain(filler));
    // Wait until the hog is parked (its API call started) so its
    // memory is held when the victim arrives.
    let mut hog_events = Vec::new();
    loop {
        let ev = hog.next_event().expect("hog stream open");
        let parked =
            matches!(ev, RequestEvent::ApiCallStarted { .. });
        hog_events.push(ev);
        if parked {
            break;
        }
    }

    // Victim → replica 0 (slot 2): 21 admission tokens cannot fit
    // beside the hog's held 28; the re-queue must move it to replica 1.
    let victim = handle.open_session(spec(20, vec![], 2)).unwrap();
    let events = drain(victim);
    assert_stream_invariants(&events);
    let rescued = events
        .iter()
        .find(|e| matches!(e, RequestEvent::Rescued { .. }));
    let Some(RequestEvent::Rescued { from, to }) = rescued else {
        panic!("expected a rescue: {events:?}");
    };
    assert_eq!((*from, *to), (0, 1));
    assert!(matches!(events.last().unwrap(),
                     RequestEvent::Finished(_)),
            "the rescued session must be served: {events:?}");

    // The hog itself completes normally after its call returns.
    loop {
        let ev = hog.next_event().expect("hog stream open");
        let terminal = ev.is_terminal();
        hog_events.push(ev);
        if terminal {
            break;
        }
    }
    assert!(hog.next_event().is_none());
    assert_stream_invariants(&hog_events);
    handle.shutdown();
}

/// Tiny deterministic PRNG (the offline vendor set has no rand crate).
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

#[test]
fn randomized_replicated_run_never_events_after_terminal() {
    // Satellite invariant at fleet scale: replicas = 4 with the
    // admission re-queue enabled (the default), a randomized mix of
    // shapes — some too big to serve at all (Dropped), some parked on
    // API calls, some rescued between replicas — and every session's
    // stream must stay causally ordered, close with exactly one
    // terminal event, and deliver nothing after it.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.handling = HandlingPolicy::Forced(HandlingStrategy::Preserve);
    cfg.replicas = 4;
    cfg.placement = PlacementKind::RoundRobin;
    cfg.memory_budget = Tokens(60);
    cfg.block_size = 1;
    let (handle, _join) = server::spawn_sim(cfg);

    let mut rng = XorShift(0x5EED_CAFE);
    let mut specs = Vec::new();
    for _ in 0..24 {
        let prompt = 1 + rng.below(70); // some exceed the 60 budget
        let api_calls = if rng.below(2) == 0 {
            vec![sim_call(1 + rng.below(3), rng.below(50),
                          rng.below(4))]
        } else {
            vec![]
        };
        let final_decode = 1 + rng.below(5);
        let stagger = rng.below(10);
        specs.push((spec(prompt, api_calls, final_decode), stagger));
    }

    let streams: Vec<Vec<RequestEvent>> =
        std::thread::scope(|scope| {
            let joins: Vec<_> = specs
                .into_iter()
                .map(|(request, stagger)| {
                    let h = handle.clone();
                    scope.spawn(move || {
                        std::thread::sleep(
                            Duration::from_millis(stagger));
                        let session = h.open_session(request).unwrap();
                        drain(session)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });

    let mut finished = 0;
    let mut dropped = 0;
    for events in &streams {
        assert_stream_invariants(events);
        match events.last().unwrap() {
            RequestEvent::Finished(c) => {
                assert!(c.dropped.is_none());
                finished += 1;
            }
            RequestEvent::Dropped { .. } => dropped += 1,
            other => panic!("non-terminal last event {other:?}"),
        }
    }
    assert_eq!(finished + dropped, 24);
    assert!(finished > 0, "the mix must serve most sessions");
    assert!(dropped > 0,
            "the mix must include oversized (dropped) sessions");
    handle.shutdown();
}
