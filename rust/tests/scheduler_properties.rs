//! Property-based tests over coordinator invariants.
//!
//! proptest is not in the offline vendor set (DESIGN.md §2); these are
//! hand-rolled randomized properties driven by the crate's deterministic
//! `util::Rng` — seeds are fixed, so failures are exactly reproducible.

use lamps::config::{CostModel, SchedulerKind, SystemConfig};
use lamps::coordinator::handling::{select_strategy, waste_of, WasteInputs};
use lamps::coordinator::ranking::{memory_over_time, RankInputs};
use lamps::core::request::{ApiCallSpec, ApiType, HandlingStrategy, Request,
                           RequestSpec, SegmentPrediction};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::Engine;
use lamps::kv::BlockManager;
use lamps::util::Rng;
use lamps::workload::{infercept, toolbench};

const CASES: usize = 200;

fn random_spec(rng: &mut Rng, id: u64) -> RequestSpec {
    let n_calls = rng.int_range(0, 3) as usize;
    let api_calls = (0..n_calls)
        .map(|_| ApiCallSpec {
            decode_before: Tokens(rng.int_range(1, 60)),
            api_type: ApiType::Qa,
            duration: Micros(rng.int_range(1_000, 30_000_000)),
            response_tokens: Tokens(rng.int_range(0, 20)),
        })
        .collect();
    RequestSpec {
        id: RequestId(id),
        arrival: Micros(rng.int_range(0, 10_000_000)),
        prompt: String::new(),
        prompt_tokens: Tokens(rng.int_range(1, 100)),
        api_calls,
        final_decode: Tokens(rng.int_range(1, 120)),
    }
}

fn oracle_request(spec: RequestSpec, strategy: HandlingStrategy) -> Request {
    let preds: Vec<SegmentPrediction> = (0..spec.num_segments())
        .map(|seg| SegmentPrediction {
            decode_tokens: spec.segment_decode(seg),
            api_duration: spec.api_calls.get(seg).map(|c| c.duration),
            response_tokens: spec
                .api_calls
                .get(seg)
                .map(|c| c.response_tokens)
                .unwrap_or(Tokens::ZERO),
        })
        .collect();
    let handling = vec![strategy; spec.api_calls.len()];
    Request::new(spec, preds, handling)
}

// ---------------------------------------------------------------------
// Waste-equation properties
// ---------------------------------------------------------------------

#[test]
fn prop_selected_strategy_minimizes_waste() {
    let mut rng = Rng::new(0xA11CE);
    let cost = CostModel::paper_scale();
    for _ in 0..CASES {
        let inp = WasteInputs {
            ctx: Tokens(rng.int_range(0, 5_000)),
            api_duration: Micros(rng.int_range(0, 60_000_000)),
            c_other: Tokens(rng.int_range(0, 50_000)),
            cached: Tokens::ZERO,
        };
        let chosen = select_strategy(&inp, &cost);
        let w_chosen = waste_of(chosen, &inp, &cost);
        for s in HandlingStrategy::ALL {
            assert!(w_chosen <= waste_of(s, &inp, &cost) + 1e-9,
                    "{chosen:?} not minimal for {inp:?}");
        }
    }
}

#[test]
fn prop_waste_monotone_in_duration_for_preserve() {
    let mut rng = Rng::new(0xBEEF);
    let cost = CostModel::paper_scale();
    for _ in 0..CASES {
        let ctx = Tokens(rng.int_range(1, 5_000));
        let c_other = Tokens(rng.int_range(0, 20_000));
        let d1 = rng.int_range(0, 10_000_000);
        let d2 = d1 + rng.int_range(1, 10_000_000);
        let w1 = waste_of(HandlingStrategy::Preserve, &WasteInputs {
            ctx,
            api_duration: Micros(d1),
            c_other,
            cached: Tokens::ZERO,
        }, &cost);
        let w2 = waste_of(HandlingStrategy::Preserve, &WasteInputs {
            ctx,
            api_duration: Micros(d2),
            c_other,
            cached: Tokens::ZERO,
        }, &cost);
        assert!(w2 >= w1);
    }
}

#[test]
fn prop_long_enough_api_never_preserves() {
    // As T_INT grows with everything else fixed, Preserve's waste grows
    // without bound while Discard/Swap stay constant.
    let mut rng = Rng::new(0xCAFE);
    let cost = CostModel::paper_scale();
    for _ in 0..CASES {
        let inp = WasteInputs {
            ctx: Tokens(rng.int_range(1, 2_000)),
            api_duration: Micros(3_600_000_000), // one hour
            c_other: Tokens(rng.int_range(0, 20_000)),
            cached: Tokens::ZERO,
        };
        assert_ne!(select_strategy(&inp, &cost),
                   HandlingStrategy::Preserve, "{inp:?}");
    }
}

// ---------------------------------------------------------------------
// Ranking properties
// ---------------------------------------------------------------------

#[test]
fn prop_rank_nonnegative_and_finite() {
    let mut rng = Rng::new(0xD00D);
    let cost = CostModel::paper_scale();
    let inputs = RankInputs {
        t_iter: Micros(10_000),
        c_other_est: Tokens(1_000),
        account_prefill: false,
        prefix_cached_block: None,
    };
    for i in 0..CASES as u64 {
        for strategy in HandlingStrategy::ALL {
            let r = oracle_request(random_spec(&mut rng, i), strategy);
            let score = memory_over_time(&r, &cost, &inputs);
            assert!(score.is_finite() && score >= 0.0, "score {score}");
        }
    }
}

#[test]
fn prop_rank_monotone_in_progress() {
    // Decoding tokens never increases the remaining integral.
    let mut rng = Rng::new(0xF00);
    let cost = CostModel::paper_scale();
    let inputs = RankInputs {
        t_iter: Micros(10_000),
        c_other_est: Tokens(1_000),
        account_prefill: false,
        prefix_cached_block: None,
    };
    for i in 0..CASES as u64 {
        let spec = random_spec(&mut rng, i);
        let mut r = oracle_request(spec, HandlingStrategy::Preserve);
        let mut prev = memory_over_time(&r, &cost, &inputs);
        let seg_len = r.spec.segment_decode(0).0;
        for _ in 0..seg_len.min(10) {
            r.segment_generated += Tokens(1);
            // logical context grows by the same token; remaining ramp
            // shrinks by strictly more than the context growth adds.
            let score = memory_over_time(&r, &cost, &inputs);
            assert!(score <= prev + 1e-6,
                    "progress increased score: {prev} -> {score}");
            prev = score;
        }
    }
}

// ---------------------------------------------------------------------
// Block-manager properties
// ---------------------------------------------------------------------

#[test]
fn prop_block_manager_conserves_blocks() {
    let mut rng = Rng::new(0x5EED);
    for case in 0..50 {
        let block_size = rng.int_range(1, 32);
        let budget = Tokens(rng.int_range(1, 200) * block_size);
        let mut m = BlockManager::new(budget, block_size);
        let capacity = m.capacity();
        let mut live: Vec<RequestId> = Vec::new();
        for op in 0..400 {
            let coin = rng.f64();
            if coin < 0.5 {
                let id = RequestId(case * 1_000 + op);
                let tokens = Tokens(rng.int_range(0, 3 * block_size));
                if m.can_fit(id, tokens) {
                    m.allocate(id, tokens).unwrap();
                    live.push(id);
                } else {
                    assert!(m.allocate(id, tokens).is_err());
                    // Failed allocation must not leak state.
                    assert!(!m.contains(id) || live.contains(&id));
                }
            } else if coin < 0.8 {
                if let Some(&id) = live.last() {
                    if rng.f64() < 0.7 && m.can_fit(id, Tokens(1)) {
                        m.append_token(id).unwrap();
                    }
                }
            } else if let Some(id) = live.pop() {
                m.free(id).unwrap();
            }
            // Invariants.
            assert!(m.used_tokens() <= m.reserved_tokens());
            assert!(m.reserved_tokens() <= capacity);
            assert!(m.free_tokens() + m.reserved_tokens() == capacity);
            assert!(m.occupancy() >= 0.0 && m.occupancy() <= 1.0);
        }
        for id in live {
            m.free(id).unwrap();
        }
        assert_eq!(m.used_tokens(), Tokens::ZERO);
        assert_eq!(m.free_tokens(), capacity);
    }
}

// ---------------------------------------------------------------------
// Whole-engine properties over random workloads
// ---------------------------------------------------------------------

#[test]
fn prop_engine_accounting_invariants() {
    // For random (dataset, scheduler, rate, seed) cells: every
    // non-dropped request completes, memory returns to zero, latency >=
    // TTFT per request, completion >= arrival.
    let mut rng = Rng::new(0x1AB5);
    for case in 0..12 {
        let seed = rng.next_u64() % 1_000;
        let rate = 1.0 + rng.f64() * 6.0;
        let n = 30 + (rng.next_u64() % 40) as usize;
        let trace = match case % 3 {
            0 => infercept::single_api_dataset(n, rate, seed),
            1 => infercept::multi_api_dataset(n, rate, seed),
            _ => toolbench::dataset(n, rate, seed),
        };
        let scheduler = match case % 4 {
            0 => SchedulerKind::Fcfs,
            1 => SchedulerKind::Sjf,
            2 => SchedulerKind::SjfTotal,
            _ => SchedulerKind::Lamps,
        };
        let mut cfg = SystemConfig::default();
        cfg.scheduler = scheduler;
        cfg.seed = seed;
        let mut engine = Engine::simulated(cfg);
        let report = engine.run_trace(&trace);
        assert_eq!(report.completed + engine.dropped.len(), n,
                   "case {case}");
        assert_eq!(engine.kv_occupancy(), 0.0, "case {case}");
        for rec in engine.metrics.records() {
            if let (Some(lat), Some(ttft)) = (rec.latency(), rec.ttft()) {
                assert!(ttft <= lat, "case {case}: ttft > latency");
            }
            if let Some(f) = rec.finished {
                assert!(f >= rec.arrival);
            }
        }
    }
}

#[test]
fn prop_engine_deterministic_across_schedulers() {
    let mut rng = Rng::new(0xDE7);
    for case in 0..6 {
        let seed = rng.next_u64() % 500;
        let trace = infercept::multi_api_dataset(40, 4.0, seed);
        for kind in [SchedulerKind::Fcfs, SchedulerKind::Lamps] {
            let mut cfg = SystemConfig::default();
            cfg.scheduler = kind;
            let a = Engine::simulated(cfg.clone()).run_trace(&trace);
            let b = Engine::simulated(cfg).run_trace(&trace);
            assert_eq!(a.latency.mean_us, b.latency.mean_us,
                       "case {case} {kind:?}");
            assert_eq!(a.iterations, b.iterations);
        }
    }
}
