//! Serving-frontend integration: engine thread + blocking submission, and
//! the JSON-lines TCP listener, on the simulated backend with a fast cost
//! model (wall-clock friendly).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use lamps::config::{ApiSourceKind, CostModel, SystemConfig};
use lamps::core::request::{ApiCallSpec, ApiType, RequestSpec};
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::backend::SimBackend;
use lamps::predictor::oracle::OraclePredictor;
use lamps::server::{self, RequestEvent, WireRequest};
use lamps::util::json;

fn fast_cost() -> CostModel {
    CostModel {
        decode_base: Micros(200), // 0.2 ms per iteration
        decode_per_ctx_token_us: 0.0,
        prefill_per_token_us: 5.0,
        swap_base_us: 0.0,
        swap_per_token_us: 0.0,
        rank_overhead_per_request_us: 0.0,
    }
}

fn spawn_sim_server() -> server::ServerHandle {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    let (handle, _join) = server::spawn(move || {
        (cfg,
         Box::new(SimBackend::new(fast_cost()))
             as Box<dyn lamps::engine::backend::Backend>,
         Box::new(OraclePredictor)
             as Box<dyn lamps::predictor::Predictor>)
    });
    handle
}

fn simple_spec(output: u64) -> RequestSpec {
    RequestSpec {
        id: RequestId(0),
        arrival: Micros::ZERO,
        prompt: "hello world".to_string(),
        prompt_tokens: Tokens(3),
        api_calls: vec![],
        final_decode: Tokens(output),
    }
}

#[test]
fn submit_blocking_roundtrip() {
    let handle = spawn_sim_server();
    let completion = handle.submit_blocking(simple_spec(10)).unwrap();
    assert_eq!(completion.tokens_decoded, 10);
    // Wall clock + sim backend: decode cost is modeled, not slept, so
    // only real scheduling time elapses — assert monotone sanity only.
    assert!(completion.latency_us > 0);
    assert!(completion.ttft_us.unwrap() <= completion.latency_us);
    handle.shutdown();
}

#[test]
fn concurrent_submissions_all_complete() {
    let handle = spawn_sim_server();
    let mut joins = Vec::new();
    for i in 0..8u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            h.submit_blocking(simple_spec(5 + i)).unwrap()
        }));
    }
    let mut ids = Vec::new();
    for j in joins {
        let c = j.join().unwrap();
        assert!(c.tokens_decoded >= 5);
        ids.push(c.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 8, "ids must be unique");
    handle.shutdown();
}

#[test]
fn api_request_waits_wall_time() {
    let handle = spawn_sim_server();
    let wire = WireRequest::parse(
        r#"{"prompt": "call the weather api", "output_tokens": 3,
            "pre_api_tokens": 2, "api_ms": 30}"#).unwrap();
    let start = std::time::Instant::now();
    let completion = handle.submit_blocking(wire.to_spec()).unwrap();
    let elapsed = start.elapsed();
    assert!(elapsed >= Duration::from_millis(30),
            "API wait must be real: {elapsed:?}");
    assert!(completion.latency_us >= 30_000);
    handle.shutdown();
}

#[test]
fn spawn_sim_serves_with_composer_knobs() {
    // The config-only frontend constructor, with the batch-composer
    // knobs (chunked prefill + async swap) active end-to-end.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.compose.prefill_chunk = Some(4);
    cfg.compose.async_swap = true;
    let handle = {
        let (handle, _join) = server::spawn_sim(cfg);
        handle
    };
    let mut spec = simple_spec(6);
    spec.prompt_tokens = Tokens(19); // 5 chunks of <=4 tokens
    let completion = handle.submit_blocking(spec).unwrap();
    assert_eq!(completion.tokens_decoded, 6);
    assert!(completion.ttft_us.unwrap() <= completion.latency_us);
    handle.shutdown();
}

#[test]
fn spawn_sim_replicated_serves_all() {
    // Multi-replica dispatch end-to-end: submissions are placed across
    // three engines and completions fan back in from their owners.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.replicas = 3;
    cfg.placement = lamps::config::PlacementKind::RoundRobin;
    let (handle, _join) = server::spawn_sim(cfg);
    let mut joins = Vec::new();
    for i in 0..9u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            h.submit_blocking(simple_spec(4 + i)).unwrap()
        }));
    }
    let mut ids = Vec::new();
    for j in joins {
        let c = j.join().unwrap();
        assert!(c.tokens_decoded >= 4);
        ids.push(c.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 9, "ids must be unique across replicas");
    handle.shutdown();
}

#[test]
fn external_session_round_trip_in_process() {
    // `--api-source external` end to end through the session API: the
    // engine parks the request (strategy chosen from the *predicted*
    // duration) and only the client's tool result — posted well after
    // the park — completes it, with the tool's actual response length
    // replacing the spec's.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.api_source = ApiSourceKind::External;
    let (handle, _join) = server::spawn_sim(cfg);
    let spec = RequestSpec {
        id: RequestId(0),
        arrival: Micros::ZERO,
        prompt: "look this up".to_string(),
        prompt_tokens: Tokens(3),
        api_calls: vec![ApiCallSpec {
            decode_before: Tokens(2),
            api_type: ApiType::Qa,
            duration: Micros(500_000), // prediction hint only
            response_tokens: Tokens(0),
        }],
        final_decode: Tokens(3),
    };
    let session = handle.open_session(spec).unwrap();
    // Drive to the park point.
    let started = loop {
        let ev = session.next_event().expect("stream open");
        if let RequestEvent::ApiCallStarted {
            index,
            predicted_us,
            external,
            ..
        } = ev
        {
            break (index, predicted_us, external);
        }
        assert!(!ev.is_terminal(),
                "must not finish before the tool result: {ev:?}");
    };
    assert_eq!(started, (0, 500_000, true),
               "parked under the predicted duration, client-owned");
    // A misdirected result (wrong index) is rejected with a
    // non-terminal Error event; the call stays parked for the real
    // answer.
    session.complete_api_call(1, 9).unwrap();
    match session.next_event().expect("stream open") {
        RequestEvent::Error { message } => {
            assert!(message.contains("parked on call 0"), "{message}");
        }
        other => panic!("expected an error event, got {other:?}"),
    }
    // The engine holds the request until we answer.
    std::thread::sleep(Duration::from_millis(30));
    session.complete_api_call(0, 5).unwrap();
    let mut completed_us = None;
    let completion = loop {
        match session.next_event().expect("stream open") {
            RequestEvent::ApiCallCompleted { index, actual_us } => {
                assert_eq!(index, 0);
                completed_us = Some(actual_us);
            }
            RequestEvent::Finished(c) => break c,
            RequestEvent::Dropped { reason } => {
                panic!("dropped: {reason}")
            }
            _ => {}
        }
    };
    assert!(session.next_event().is_none(), "stream closed");
    let actual = completed_us.expect("completion event before finish");
    assert!(actual >= 30_000,
            "the park time is the measured duration: {actual}");
    assert_eq!(completion.tokens_decoded, 5, "2 pre-API + 3 final");
    assert!(completion.dropped.is_none());
    handle.shutdown();
}

#[test]
fn tcp_v2_external_session_round_trip() {
    // Protocol v2 over real TCP: a typed request frame opens the
    // session, event frames stream back, a scripted client drives the
    // externally-held call with a tool_result frame, and the session
    // closes with a finished frame.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.api_source = ApiSourceKind::External;
    let (handle, _join) = server::spawn_sim(cfg);
    let addr = "127.0.0.1:17072";
    let server_handle = handle.clone();
    std::thread::spawn(move || {
        let _ = server::serve_tcp(server_handle, addr);
    });
    let mut stream = None;
    for _ in 0..50 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let read_frame = |reader: &mut BufReader<TcpStream>| {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        json::parse(&line).expect("frames are valid JSON")
    };

    // An unknown frame type gets an injection-proof error frame.
    writer.write_all(b"{\"type\": \"bogus\"}\n").unwrap();
    writer.flush().unwrap();
    let v = read_frame(&mut reader);
    assert_eq!(v.str_field("type").unwrap(), "error");

    // A v1 one-shot carrying an API call is rejected up front on an
    // external-source server: its tool result could never be posted
    // back, and blocking the reader on it would deadlock the
    // connection.
    writer
        .write_all(b"{\"prompt\": \"v1\", \"output_tokens\": 2, \
                      \"pre_api_tokens\": 1, \"api_ms\": 5}\n")
        .unwrap();
    writer.flush().unwrap();
    let v = read_frame(&mut reader);
    assert_eq!(v.str_field("type").unwrap(), "error");
    assert!(v.str_field("error").unwrap().contains("v2 session"));

    // ...while a call-free v1 one-shot still works as before.
    writer
        .write_all(b"{\"prompt\": \"v1 plain\", \"output_tokens\": 2}\n")
        .unwrap();
    writer.flush().unwrap();
    let v = read_frame(&mut reader);
    assert_eq!(v.u64_field("tokens_decoded").unwrap(), 2);

    let request = "{\"type\":\"request\",\
                    \"prompt\":\"use the calculator\",\
                    \"output_tokens\":3,\
                    \"api_calls\":[{\"decode_before\":2,\
                    \"api_type\":\"math\",\"response_tokens\":2}]}\n";
    writer.write_all(request.as_bytes()).unwrap();
    writer.flush().unwrap();

    // queued announces the id; then frames stream until the park.
    let v = read_frame(&mut reader);
    assert_eq!(v.str_field("type").unwrap(), "queued");
    let id = v.u64_field("id").unwrap();
    let started = loop {
        let v = read_frame(&mut reader);
        let t = v.str_field("type").unwrap();
        assert_ne!(t, "finished",
                   "must not finish before the tool result");
        assert_ne!(t, "dropped");
        if t == "api_call_started" {
            break v;
        }
    };
    assert_eq!(started.u64_field("id").unwrap(), id);
    assert_eq!(started.u64_field("index").unwrap(), 0);
    assert_eq!(started.get("external").unwrap().as_bool(), Some(true));
    // predicted_us defaults to the math class's Table 2 mean (90 us).
    assert_eq!(started.u64_field("predicted_us").unwrap(), 90);

    let tool_result = format!(
        "{{\"type\": \"tool_result\", \"id\": {id}, \"index\": 0, \
         \"response_tokens\": 2}}\n");
    writer.write_all(tool_result.as_bytes()).unwrap();
    writer.flush().unwrap();

    let mut saw_completed = false;
    loop {
        let v = read_frame(&mut reader);
        match v.str_field("type").unwrap().as_str() {
            "api_call_completed" => {
                assert_eq!(v.u64_field("index").unwrap(), 0);
                saw_completed = true;
            }
            "finished" => {
                assert_eq!(v.u64_field("id").unwrap(), id);
                assert_eq!(v.u64_field("tokens_decoded").unwrap(), 5);
                break;
            }
            "dropped" => panic!("dropped: {v:?}"),
            _ => {}
        }
    }
    assert!(saw_completed, "completion frame precedes finished");

    // A tool_result for a session that no longer exists comes back as
    // an error frame instead of vanishing into the server's stderr.
    let stale = format!(
        "{{\"type\": \"tool_result\", \"id\": {id}, \"index\": 0, \
         \"response_tokens\": 1}}\n");
    writer.write_all(stale.as_bytes()).unwrap();
    writer.flush().unwrap();
    let v = read_frame(&mut reader);
    assert_eq!(v.str_field("type").unwrap(), "error");
    assert_eq!(v.u64_field("id").unwrap(), id);
    assert!(v.str_field("error").unwrap().contains("unknown session"));
    handle.shutdown();
}

#[test]
fn shutdown_aborts_parked_external_calls() {
    // Without the shutdown abort, the engine thread would wait out
    // the 10-minute client backstop for a call nobody will answer;
    // the session must instead close promptly with a Dropped terminal
    // and the engine thread must exit.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.api_source = ApiSourceKind::External;
    let (handle, join) = server::spawn_sim(cfg);
    let session = handle
        .open_session(RequestSpec {
            id: RequestId(0),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(2),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(1),
                api_type: ApiType::Qa,
                duration: Micros(1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(1),
        })
        .unwrap();
    loop {
        let ev = session.next_event().expect("stream open");
        if matches!(ev, RequestEvent::ApiCallStarted { .. }) {
            break;
        }
        assert!(!ev.is_terminal(), "{ev:?}");
    }
    handle.shutdown();
    loop {
        match session.next_event() {
            Some(RequestEvent::Dropped { reason }) => {
                assert!(reason.contains("shutting down"), "{reason}");
                break;
            }
            Some(ev) => assert!(!ev.is_terminal(), "{ev:?}"),
            None => panic!("stream closed without a terminal event"),
        }
    }
    // Bounded shutdown: the engine thread exits once the aborted
    // session is closed.
    join.join().unwrap();
}

#[test]
fn tcp_json_lines_roundtrip() {
    let handle = spawn_sim_server();
    let addr = "127.0.0.1:17071";
    let server_handle = handle.clone();
    std::thread::spawn(move || {
        let _ = server::serve_tcp(server_handle, addr);
    });
    // Wait for the listener.
    let mut stream = None;
    for _ in 0..50 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writer
        .write_all(b"{\"prompt\": \"hi there\", \"output_tokens\": 4}\n")
        .unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.u64_field("tokens_decoded").unwrap(), 4);
    assert!(v.u64_field("latency_us").unwrap() > 0);

    // Malformed request gets an error object, connection stays usable.
    writer.write_all(b"not json\n").unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    writer
        .write_all(b"{\"prompt\": \"again\", \"output_tokens\": 2}\n")
        .unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.u64_field("tokens_decoded").unwrap(), 2);
    handle.shutdown();
}
