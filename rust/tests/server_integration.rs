//! Serving-frontend integration: engine thread + blocking submission, and
//! the JSON-lines TCP listener, on the simulated backend with a fast cost
//! model (wall-clock friendly).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use lamps::config::{CostModel, SystemConfig};
use lamps::core::request::RequestSpec;
use lamps::core::types::{Micros, RequestId, Tokens};
use lamps::engine::backend::SimBackend;
use lamps::predictor::oracle::OraclePredictor;
use lamps::server::{self, WireRequest};
use lamps::util::json;

fn fast_cost() -> CostModel {
    CostModel {
        decode_base: Micros(200), // 0.2 ms per iteration
        decode_per_ctx_token_us: 0.0,
        prefill_per_token_us: 5.0,
        swap_base_us: 0.0,
        swap_per_token_us: 0.0,
        rank_overhead_per_request_us: 0.0,
    }
}

fn spawn_sim_server() -> server::ServerHandle {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    let (handle, _join) = server::spawn(move || {
        (cfg,
         Box::new(SimBackend::new(fast_cost()))
             as Box<dyn lamps::engine::backend::Backend>,
         Box::new(OraclePredictor)
             as Box<dyn lamps::predictor::Predictor>)
    });
    handle
}

fn simple_spec(output: u64) -> RequestSpec {
    RequestSpec {
        id: RequestId(0),
        arrival: Micros::ZERO,
        prompt: "hello world".to_string(),
        prompt_tokens: Tokens(3),
        api_calls: vec![],
        final_decode: Tokens(output),
    }
}

#[test]
fn submit_blocking_roundtrip() {
    let handle = spawn_sim_server();
    let completion = handle.submit_blocking(simple_spec(10)).unwrap();
    assert_eq!(completion.tokens_decoded, 10);
    // Wall clock + sim backend: decode cost is modeled, not slept, so
    // only real scheduling time elapses — assert monotone sanity only.
    assert!(completion.latency_us > 0);
    assert!(completion.ttft_us.unwrap() <= completion.latency_us);
    handle.shutdown();
}

#[test]
fn concurrent_submissions_all_complete() {
    let handle = spawn_sim_server();
    let mut joins = Vec::new();
    for i in 0..8u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            h.submit_blocking(simple_spec(5 + i)).unwrap()
        }));
    }
    let mut ids = Vec::new();
    for j in joins {
        let c = j.join().unwrap();
        assert!(c.tokens_decoded >= 5);
        ids.push(c.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 8, "ids must be unique");
    handle.shutdown();
}

#[test]
fn api_request_waits_wall_time() {
    let handle = spawn_sim_server();
    let wire = WireRequest {
        prompt: "call the weather api".to_string(),
        pre_api_tokens: 2,
        api_ms: 30,
        output_tokens: 3,
    };
    let start = std::time::Instant::now();
    let completion = handle.submit_blocking(wire.to_spec()).unwrap();
    let elapsed = start.elapsed();
    assert!(elapsed >= Duration::from_millis(30),
            "API wait must be real: {elapsed:?}");
    assert!(completion.latency_us >= 30_000);
    handle.shutdown();
}

#[test]
fn spawn_sim_serves_with_composer_knobs() {
    // The config-only frontend constructor, with the batch-composer
    // knobs (chunked prefill + async swap) active end-to-end.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.compose.prefill_chunk = Some(4);
    cfg.compose.async_swap = true;
    let handle = {
        let (handle, _join) = server::spawn_sim(cfg);
        handle
    };
    let mut spec = simple_spec(6);
    spec.prompt_tokens = Tokens(19); // 5 chunks of <=4 tokens
    let completion = handle.submit_blocking(spec).unwrap();
    assert_eq!(completion.tokens_decoded, 6);
    assert!(completion.ttft_us.unwrap() <= completion.latency_us);
    handle.shutdown();
}

#[test]
fn spawn_sim_replicated_serves_all() {
    // Multi-replica dispatch end-to-end: submissions are placed across
    // three engines and completions fan back in from their owners.
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.cost = fast_cost();
    cfg.replicas = 3;
    cfg.placement = lamps::config::PlacementKind::RoundRobin;
    let (handle, _join) = server::spawn_sim(cfg);
    let mut joins = Vec::new();
    for i in 0..9u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            h.submit_blocking(simple_spec(4 + i)).unwrap()
        }));
    }
    let mut ids = Vec::new();
    for j in joins {
        let c = j.join().unwrap();
        assert!(c.tokens_decoded >= 4);
        ids.push(c.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 9, "ids must be unique across replicas");
    handle.shutdown();
}

#[test]
fn tcp_json_lines_roundtrip() {
    let handle = spawn_sim_server();
    let addr = "127.0.0.1:17071";
    let server_handle = handle.clone();
    std::thread::spawn(move || {
        let _ = server::serve_tcp(server_handle, addr);
    });
    // Wait for the listener.
    let mut stream = None;
    for _ in 0..50 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writer
        .write_all(b"{\"prompt\": \"hi there\", \"output_tokens\": 4}\n")
        .unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.u64_field("tokens_decoded").unwrap(), 4);
    assert!(v.u64_field("latency_us").unwrap() > 0);

    // Malformed request gets an error object, connection stays usable.
    writer.write_all(b"not json\n").unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    writer
        .write_all(b"{\"prompt\": \"again\", \"output_tokens\": 2}\n")
        .unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.u64_field("tokens_decoded").unwrap(), 2);
    handle.shutdown();
}
