//! Randomized property tests of the BlockManager invariants, driven by
//! the crate's deterministic `util::Rng` (fixed seeds — every failure is
//! exactly reproducible):
//!
//! - alloc/free/grow round-trips never leak or duplicate blocks,
//! - `used_tokens` always equals the sum of live allocations,
//! - a failed (OOM) allocation leaves all observable state unchanged and
//!   reports `free` in requester-tokens (the unit `can_fit` checks).

use std::collections::BTreeMap;

use lamps::core::types::{RequestId, Tokens};
use lamps::kv::{BlockManager, KvError};
use lamps::util::Rng;

/// Shadow model: per-request token counts tracked independently.
fn check_against_shadow(m: &BlockManager,
                        shadow: &BTreeMap<RequestId, u64>,
                        capacity: Tokens) {
    let shadow_sum: u64 = shadow.values().sum();
    assert_eq!(m.used_tokens(), Tokens(shadow_sum),
               "used_tokens must equal the sum of live allocations");
    for (&id, &tokens) in shadow {
        assert_eq!(m.tokens_of(id), Tokens(tokens));
        assert!(m.contains(id));
    }
    assert!(m.used_tokens() <= m.reserved_tokens());
    assert!(m.reserved_tokens() <= capacity);
    assert_eq!(m.free_tokens() + m.reserved_tokens(), capacity,
               "blocks must be conserved");
}

#[test]
fn prop_random_op_sequences_hold_invariants() {
    let mut rng = Rng::new(0xB10C_0001);
    for case in 0..40u64 {
        let block_size = rng.int_range(1, 24);
        let budget = Tokens(rng.int_range(2, 120) * block_size);
        let mut m = BlockManager::new(budget, block_size);
        let capacity = m.capacity();
        let mut shadow: BTreeMap<RequestId, u64> = BTreeMap::new();
        let mut next_id = case * 100_000;

        for _ in 0..600 {
            let coin = rng.f64();
            if coin < 0.40 {
                // Fresh or growing allocation.
                let id = if shadow.is_empty() || rng.f64() < 0.5 {
                    next_id += 1;
                    RequestId(next_id)
                } else {
                    *shadow.keys().nth(
                        (rng.next_u64() % shadow.len() as u64) as usize)
                        .unwrap()
                };
                let tokens = Tokens(rng.int_range(0, 4 * block_size));
                let fits = m.can_fit(id, tokens);
                let before_used = m.used_tokens();
                let before_free = m.free_tokens();
                let before_own = m.tokens_of(id);
                match m.allocate(id, tokens) {
                    Ok(()) => {
                        assert!(fits, "allocate succeeded where \
                                       can_fit said no");
                        *shadow.entry(id).or_insert(0) += tokens.0;
                    }
                    Err(KvError::OutOfMemory { requested, free }) => {
                        assert!(!fits);
                        assert_eq!(requested, tokens);
                        // `free` is the requester-token bound can_fit
                        // enforces: anything <= free must fit.
                        assert_eq!(free, m.available_for(id));
                        assert!(m.can_fit(id, free));
                        assert!(!m.can_fit(id, free + Tokens(1)));
                        // OOM must leave state untouched.
                        assert_eq!(m.used_tokens(), before_used);
                        assert_eq!(m.free_tokens(), before_free);
                        assert_eq!(m.tokens_of(id), before_own);
                        if before_own == Tokens::ZERO {
                            assert!(!m.contains(id)
                                        || shadow.contains_key(&id));
                        }
                    }
                    Err(e) => panic!("unexpected error {e}"),
                }
            } else if coin < 0.70 {
                // Grow-by-one (the decode append path).
                if let Some(&id) = shadow.keys().next() {
                    if m.can_fit(id, Tokens(1)) {
                        m.append_token(id).unwrap();
                        *shadow.get_mut(&id).unwrap() += 1;
                    } else {
                        assert!(matches!(
                            m.append_token(id),
                            Err(KvError::OutOfMemory { .. })));
                    }
                }
            } else if coin < 0.95 {
                // Free a random live allocation.
                if !shadow.is_empty() {
                    let idx =
                        (rng.next_u64() % shadow.len() as u64) as usize;
                    let id = *shadow.keys().nth(idx).unwrap();
                    let expect = shadow.remove(&id).unwrap();
                    assert_eq!(m.free(id).unwrap(), Tokens(expect));
                    assert!(!m.contains(id));
                }
            } else {
                // Operations on unknown ids must error cleanly.
                let ghost = RequestId(next_id + 999_999);
                assert!(matches!(m.free(ghost),
                                 Err(KvError::UnknownRequest(_))));
                assert!(matches!(m.append_token(ghost),
                                 Err(KvError::UnknownRequest(_))));
            }
            check_against_shadow(&m, &shadow, capacity);
        }

        // Drain: everything frees back to an empty manager.
        let ids: Vec<RequestId> = shadow.keys().copied().collect();
        for id in ids {
            let expect = shadow.remove(&id).unwrap();
            assert_eq!(m.free(id).unwrap(), Tokens(expect));
        }
        assert_eq!(m.used_tokens(), Tokens::ZERO);
        assert_eq!(m.free_tokens(), capacity);
        assert_eq!(m.occupancy(), 0.0, "case {case}");
    }
}

#[test]
fn prop_blocks_never_shared_between_live_requests() {
    let mut rng = Rng::new(0xB10C_0002);
    for _ in 0..20 {
        let mut m = BlockManager::new(Tokens(64 * 16), 16);
        let mut live: Vec<RequestId> = Vec::new();
        for op in 0..200u64 {
            if rng.f64() < 0.6 {
                let id = RequestId(op);
                let tokens = Tokens(rng.int_range(1, 40));
                if m.can_fit(id, tokens) {
                    m.allocate(id, tokens).unwrap();
                    if !live.contains(&id) {
                        live.push(id);
                    }
                }
            } else if let Some(id) = live.pop() {
                m.free(id).unwrap();
            }
            // No physical block may appear in two allocations.
            let mut seen = std::collections::HashSet::new();
            for id in &live {
                for b in m.blocks_of(*id).unwrap() {
                    assert!(seen.insert(*b),
                            "block {b} owned by two requests");
                }
            }
        }
    }
}
