//! Randomized property tests of the BlockManager invariants, driven by
//! the crate's deterministic `util::Rng` (fixed seeds — every failure is
//! exactly reproducible):
//!
//! - alloc/free/grow round-trips never leak or duplicate blocks,
//! - `used_tokens` always equals the sum of live allocations,
//! - a failed (OOM) allocation leaves all observable state unchanged and
//!   reports `free` in requester-tokens (the unit `can_fit` checks).
//!
//! With the prefix cache attached, additionally:
//!
//! - block conservation: free + distinct-pinned + zero-ref-cached
//!   always equals capacity,
//! - refcounts never underflow and a shared block's refcount equals the
//!   number of live allocations holding it,
//! - a cache-hit allocation never materializes a duplicate physical
//!   block for content that is already cached,
//! - eviction (capacity or pressure) only ever touches zero-ref blocks:
//!   a pinned block is never reclaimed out from under its holders.

use std::collections::{BTreeMap, BTreeSet, HashSet};

use lamps::core::types::{RequestId, Tokens};
use lamps::kv::{BlockHash, BlockManager, KvError};
use lamps::util::Rng;

/// Shadow model: per-request token counts tracked independently.
fn check_against_shadow(m: &BlockManager,
                        shadow: &BTreeMap<RequestId, u64>,
                        capacity: Tokens) {
    // The promoted self-check — the same one the engine's invariant
    // auditor runs after every step (`lamps::audit`).
    if let Err(e) = m.check_invariants() {
        panic!("BlockManager self-check failed: {e}");
    }
    let shadow_sum: u64 = shadow.values().sum();
    assert_eq!(m.used_tokens(), Tokens(shadow_sum),
               "used_tokens must equal the sum of live allocations");
    for (&id, &tokens) in shadow {
        assert_eq!(m.tokens_of(id), Tokens(tokens));
        assert!(m.contains(id));
    }
    assert!(m.used_tokens() <= m.reserved_tokens());
    assert!(m.reserved_tokens() <= capacity);
    assert_eq!(m.free_tokens() + m.reserved_tokens(), capacity,
               "blocks must be conserved");
}

#[test]
fn prop_random_op_sequences_hold_invariants() {
    let mut rng = Rng::new(0xB10C_0001);
    for case in 0..40u64 {
        let block_size = rng.int_range(1, 24);
        let budget = Tokens(rng.int_range(2, 120) * block_size);
        let mut m = BlockManager::new(budget, block_size);
        let capacity = m.capacity();
        let mut shadow: BTreeMap<RequestId, u64> = BTreeMap::new();
        let mut next_id = case * 100_000;

        for _ in 0..600 {
            let coin = rng.f64();
            if coin < 0.40 {
                // Fresh or growing allocation.
                let id = if shadow.is_empty() || rng.f64() < 0.5 {
                    next_id += 1;
                    RequestId(next_id)
                } else {
                    *shadow.keys().nth(
                        (rng.next_u64() % shadow.len() as u64) as usize)
                        .unwrap()
                };
                let tokens = Tokens(rng.int_range(0, 4 * block_size));
                let fits = m.can_fit(id, tokens);
                let before_used = m.used_tokens();
                let before_free = m.free_tokens();
                let before_own = m.tokens_of(id);
                match m.allocate(id, tokens) {
                    Ok(()) => {
                        assert!(fits, "allocate succeeded where \
                                       can_fit said no");
                        *shadow.entry(id).or_insert(0) += tokens.0;
                    }
                    Err(KvError::OutOfMemory { requested, free }) => {
                        assert!(!fits);
                        assert_eq!(requested, tokens);
                        // `free` is the requester-token bound can_fit
                        // enforces: anything <= free must fit.
                        assert_eq!(free, m.available_for(id));
                        assert!(m.can_fit(id, free));
                        assert!(!m.can_fit(id, free + Tokens(1)));
                        // OOM must leave state untouched.
                        assert_eq!(m.used_tokens(), before_used);
                        assert_eq!(m.free_tokens(), before_free);
                        assert_eq!(m.tokens_of(id), before_own);
                        if before_own == Tokens::ZERO {
                            assert!(!m.contains(id)
                                        || shadow.contains_key(&id));
                        }
                    }
                    Err(e) => panic!("unexpected error {e}"),
                }
            } else if coin < 0.70 {
                // Grow-by-one (the decode append path).
                if let Some(&id) = shadow.keys().next() {
                    if m.can_fit(id, Tokens(1)) {
                        m.append_token(id).unwrap();
                        *shadow.get_mut(&id).unwrap() += 1;
                    } else {
                        assert!(matches!(
                            m.append_token(id),
                            Err(KvError::OutOfMemory { .. })));
                    }
                }
            } else if coin < 0.95 {
                // Free a random live allocation.
                if !shadow.is_empty() {
                    let idx =
                        (rng.next_u64() % shadow.len() as u64) as usize;
                    let id = *shadow.keys().nth(idx).unwrap();
                    let expect = shadow.remove(&id).unwrap();
                    assert_eq!(m.free(id).unwrap(), Tokens(expect));
                    assert!(!m.contains(id));
                }
            } else {
                // Operations on unknown ids must error cleanly.
                let ghost = RequestId(next_id + 999_999);
                assert!(matches!(m.free(ghost),
                                 Err(KvError::UnknownRequest(_))));
                assert!(matches!(m.append_token(ghost),
                                 Err(KvError::UnknownRequest(_))));
            }
            check_against_shadow(&m, &shadow, capacity);
        }

        // Drain: everything frees back to an empty manager.
        let ids: Vec<RequestId> = shadow.keys().copied().collect();
        for id in ids {
            let expect = shadow.remove(&id).unwrap();
            assert_eq!(m.free(id).unwrap(), Tokens(expect));
        }
        assert_eq!(m.used_tokens(), Tokens::ZERO);
        assert_eq!(m.free_tokens(), capacity);
        assert_eq!(m.occupancy(), 0.0, "case {case}");
    }
}

/// Shadow of one live prefixed allocation: logical tokens, the content
/// chain it was allocated against, and the chain hashes it *holds* a
/// refcount on (cache hits at allocation + registrations).
struct PrefixShadow {
    tokens: u64,
    chain: Vec<BlockHash>,
    held: BTreeSet<usize>,
}

/// Cross-checks every observable prefix-cache invariant against the
/// shadow model. See the module docs for the list.
fn check_prefix_invariants(m: &BlockManager,
                           shadow: &BTreeMap<RequestId, PrefixShadow>,
                           total_blocks: u64, block_size: u64) {
    // The promoted self-check — the same one the engine's invariant
    // auditor runs after every step (`lamps::audit`).
    if let Err(e) = m.check_invariants() {
        panic!("BlockManager self-check failed: {e}");
    }
    // Block conservation across the three physical states.
    let free = m.free_tokens().0 / block_size;
    assert_eq!(free + m.pinned_blocks() + m.cached_blocks(), total_blocks,
               "free + pinned + cached must equal capacity");

    // Distinct live blocks == pinned count: no pinned block was ever
    // evicted/leaked (it would resurface under another request and
    // shrink the distinct set), and cached/free blocks never appear in
    // a live allocation.
    let mut distinct: HashSet<u32> = HashSet::new();
    let mut token_sum = 0u64;
    for (&id, sh) in shadow {
        assert_eq!(m.tokens_of(id), Tokens(sh.tokens));
        distinct.extend(m.blocks_of(id).unwrap().iter().copied());
        token_sum += sh.tokens;
    }
    assert_eq!(distinct.len() as u64, m.pinned_blocks(),
               "pinned accounting must match the live allocations");
    assert_eq!(m.used_tokens(), Tokens(token_sum));

    // Refcounts equal the number of live holders; shared content maps
    // to exactly one canonical physical block (never a duplicate).
    let mut holders: BTreeMap<BlockHash, Vec<(RequestId, usize)>> =
        BTreeMap::new();
    for (&id, sh) in shadow {
        for &i in &sh.held {
            holders.entry(sh.chain[i]).or_default().push((id, i));
        }
    }
    for (&hash, held_by) in &holders {
        let rc = m.prefix_refcount(hash).unwrap_or_else(|| {
            panic!("held hash {hash} missing from cache (evicted while \
                    pinned?)")
        });
        assert_eq!(rc as usize, held_by.len(),
                   "refcount of {hash} must equal its live holders");
        let canonical = m.blocks_of(held_by[0].0).unwrap()[held_by[0].1];
        for &(id, i) in held_by {
            assert_eq!(m.blocks_of(id).unwrap()[i], canonical,
                       "shared hash {hash} must map to one block");
        }
    }
}

#[test]
fn prop_prefix_cache_invariants_hold() {
    let mut rng = Rng::new(0xB10C_0003);
    for case in 0..25u64 {
        let block_size = rng.int_range(1, 12);
        let total_blocks = rng.int_range(4, 48);
        let cache_cap = if rng.f64() < 0.5 {
            None
        } else {
            Some(rng.int_range(0, 6))
        };
        let mut m = BlockManager::with_prefix_cache(
            Tokens(total_blocks * block_size), block_size, cache_cap);
        // Four "prompt families" with disjoint chains: requests inside a
        // family share content; across families nothing may alias.
        let families: Vec<Vec<BlockHash>> = (0..4)
            .map(|f| (0..8).map(|i| 0x5EED_0000 + f * 1000 + i).collect())
            .collect();
        let mut shadow: BTreeMap<RequestId, PrefixShadow> = BTreeMap::new();
        let mut next_id = case * 1_000_000;

        for _ in 0..400 {
            let coin = rng.f64();
            if coin < 0.40 {
                // Fresh prefixed allocation from a random family.
                next_id += 1;
                let id = RequestId(next_id);
                let family = (rng.next_u64() % 4) as usize;
                let chain = families[family].clone();
                let tokens = rng.int_range(1, 9 * block_size + 1);
                let before_used = m.used_tokens();
                let before_cached = m.cached_blocks();
                match m.allocate_prefixed(id, Tokens(tokens), &chain) {
                    Ok(cached) => {
                        assert_eq!(cached.0 % block_size, 0,
                                   "hits are whole blocks");
                        assert!(cached.0 <= tokens,
                                "cannot hit more than allocated");
                        let hits = (cached.0 / block_size) as usize;
                        shadow.insert(id, PrefixShadow {
                            tokens,
                            chain,
                            held: (0..hits).collect(),
                        });
                    }
                    Err(KvError::OutOfMemory { .. }) => {
                        assert!(!m.contains(id));
                        assert_eq!(m.used_tokens(), before_used);
                        assert_eq!(m.cached_blocks(), before_cached,
                                   "failed alloc must not disturb cache");
                    }
                    Err(e) => panic!("unexpected error {e}"),
                }
            } else if coin < 0.55 {
                // Grow a live allocation (plain path; never re-walks).
                if let Some((&id, _)) = shadow.iter().next() {
                    let tokens = rng.int_range(0, 2 * block_size);
                    if m.can_fit(id, Tokens(tokens)) {
                        m.allocate(id, Tokens(tokens)).unwrap();
                        shadow.get_mut(&id).unwrap().tokens += tokens;
                    }
                }
            } else if coin < 0.75 {
                // Register a live allocation's materialized content.
                if !shadow.is_empty() {
                    let idx =
                        (rng.next_u64() % shadow.len() as u64) as usize;
                    let id = *shadow.keys().nth(idx).unwrap();
                    let sh = shadow.get_mut(&id).unwrap();
                    let full = ((sh.tokens / block_size) as usize)
                        .min(sh.chain.len());
                    // Predict which indexes register: not yet held by
                    // this request and content not cached by anyone.
                    let newly: Vec<usize> = (0..full)
                        .filter(|i| {
                            !sh.held.contains(i)
                                && m.prefix_refcount(sh.chain[*i])
                                    .is_none()
                        })
                        .collect();
                    m.register_prefix(id, Tokens(sh.tokens), &sh.chain);
                    sh.held.extend(newly);
                }
            } else if coin < 0.95 {
                // Free a random live allocation.
                if !shadow.is_empty() {
                    let idx =
                        (rng.next_u64() % shadow.len() as u64) as usize;
                    let id = *shadow.keys().nth(idx).unwrap();
                    let sh = shadow.remove(&id).unwrap();
                    assert_eq!(m.free(id).unwrap(), Tokens(sh.tokens));
                }
            } else {
                // Retention cap honored at all times.
                if let Some(cap) = cache_cap {
                    assert!(m.cached_blocks() <= cap,
                            "retained {} > cap {cap}",
                            m.cached_blocks());
                }
            }
            check_prefix_invariants(&m, &shadow, total_blocks, block_size);
        }

        // Drain and verify the cache alone owns what is left.
        let ids: Vec<RequestId> = shadow.keys().copied().collect();
        for id in ids {
            let sh = shadow.remove(&id).unwrap();
            assert_eq!(m.free(id).unwrap(), Tokens(sh.tokens));
        }
        assert_eq!(m.used_tokens(), Tokens::ZERO);
        assert_eq!(m.pinned_blocks(), 0);
        assert_eq!(m.free_tokens().0 / block_size + m.cached_blocks(),
                   total_blocks, "case {case}");
    }
}

#[test]
fn prop_blocks_never_shared_between_live_requests() {
    let mut rng = Rng::new(0xB10C_0002);
    for _ in 0..20 {
        let mut m = BlockManager::new(Tokens(64 * 16), 16);
        let mut live: Vec<RequestId> = Vec::new();
        for op in 0..200u64 {
            if rng.f64() < 0.6 {
                let id = RequestId(op);
                let tokens = Tokens(rng.int_range(1, 40));
                if m.can_fit(id, tokens) {
                    m.allocate(id, tokens).unwrap();
                    if !live.contains(&id) {
                        live.push(id);
                    }
                }
            } else if let Some(id) = live.pop() {
                m.free(id).unwrap();
            }
            // No physical block may appear in two allocations.
            let mut seen = std::collections::HashSet::new();
            for id in &live {
                for b in m.blocks_of(*id).unwrap() {
                    assert!(seen.insert(*b),
                            "block {b} owned by two requests");
                }
            }
        }
    }
}
