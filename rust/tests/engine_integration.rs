//! End-to-end engine scenarios on the simulated backend: the paper's
//! qualitative claims at mini scale, plus determinism and accounting
//! invariants.

use lamps::config::{HandlingPolicy, PredictorKind, SystemConfig};
use lamps::core::request::HandlingStrategy;
use lamps::core::types::{Micros, Tokens};
use lamps::engine::Engine;
use lamps::metrics::RunReport;
use lamps::workload::{infercept, toolbench, Trace};

fn run(preset: &str, trace: &Trace) -> RunReport {
    let cfg = SystemConfig::preset(preset).unwrap();
    Engine::simulated(cfg).run_trace(trace)
}

/// Memory-contended variant: the paper's evaluation regime is
/// memory-bound (§1); gains appear when the KV budget binds.
fn run_contended(preset: &str, trace: &Trace) -> RunReport {
    let mut cfg = SystemConfig::preset(preset).unwrap();
    cfg.memory_budget = Tokens(12_000);
    Engine::simulated(cfg).run_trace(trace)
}

fn run_cfg(cfg: SystemConfig, trace: &Trace) -> RunReport {
    Engine::simulated(cfg).run_trace(trace)
}

#[test]
fn single_api_trace_completes_under_all_systems() {
    let trace = infercept::single_api_dataset(80, 2.0, 11);
    for preset in ["vllm", "infercept", "lamps", "lamps-no-sched", "sjf",
                   "sjf-total"] {
        let report = run(preset, &trace);
        assert_eq!(report.completed, 80, "{preset}");
        assert!(report.latency.mean_us > 0.0);
        assert!(report.ttft.mean_us <= report.latency.mean_us,
                "{preset}: TTFT must not exceed end-to-end latency");
    }
}

#[test]
fn multi_api_trace_completes() {
    let trace = infercept::multi_api_dataset(60, 2.0, 13);
    let report = run("lamps", &trace);
    assert_eq!(report.completed, 60);
    // Multi-API requests decode across several segments.
    let total_decode: u64 =
        trace.requests.iter().map(|r| r.total_decode().0).sum();
    assert_eq!(report.tokens_decoded, total_decode);
}

#[test]
fn toolbench_trace_completes() {
    let trace = toolbench::dataset(50, 2.0, 17);
    let report = run("lamps", &trace);
    assert_eq!(report.completed, 50);
}

#[test]
fn sim_report_json_shape_pinned() {
    // Regression pin for the `--api-source` seam: a simulated-source
    // run (the default) must keep the exact PR 4 report shape — the
    // external-only keys (api_calls_completed, api_pred_abs_err_us,
    // api_pred_err_hist) may never leak into it, and nothing else may
    // appear or vanish.
    let trace = infercept::single_api_dataset(30, 2.0, 7);
    let report = run("lamps", &trace);
    assert!(report.completed > 0);
    assert_eq!(report.api_calls_completed, 0,
               "no externally-resolved calls on a sim run");
    let v = lamps::util::json::parse(&report.to_json(false)).unwrap();
    let keys: Vec<&str> = v
        .as_obj()
        .unwrap()
        .keys()
        .map(|k| k.as_str())
        .collect();
    assert_eq!(keys, [
        "blocks_allocated",
        "completed",
        "discard_count",
        "duration_us",
        "iterations",
        "latency",
        "materialize_us",
        "preemptions",
        "prefix_cached_blocks",
        "prefix_evictions",
        "prefix_hit_tokens",
        "preserve_count",
        "rejected_memory",
        "rejected_reservation",
        "rejected_slot",
        "submitted",
        "swap_count",
        "swap_overlap_us",
        "swap_restore_cached_tokens",
        "swap_stall_us",
        "throughput_rps",
        "tokens_decoded",
        "tokens_prefilled",
        "tokens_recomputed",
        "ttft",
    ], "exactly the PR 4 sim-report shape");
}

#[test]
fn deterministic_replay() {
    let trace = infercept::multi_api_dataset(40, 3.0, 23);
    let a = run("lamps", &trace);
    let b = run("lamps", &trace);
    assert_eq!(a.latency.mean_us, b.latency.mean_us);
    assert_eq!(a.ttft.p99_us, b.ttft.p99_us);
    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.tokens_decoded, b.tokens_decoded);
}

#[test]
fn lamps_beats_vllm_under_load() {
    // The headline claim (§6.2) at mini scale: under pressure, LAMPS's
    // predicted handling + memory-over-time scheduling beats vLLM's
    // FCFS + always-discard.
    let trace = infercept::multi_api_dataset(150, 6.0, 31);
    let lamps = run_contended("lamps", &trace);
    let vllm = run_contended("vllm", &trace);
    assert!(lamps.latency.mean_us < vllm.latency.mean_us,
            "lamps {} vs vllm {}", lamps.latency.mean_us,
            vllm.latency.mean_us);
    assert!(lamps.ttft.mean_us < vllm.ttft.mean_us,
            "lamps ttft {} vs vllm ttft {}", lamps.ttft.mean_us,
            vllm.ttft.mean_us);
}

#[test]
fn infercept_beats_vllm_under_load() {
    // Min-waste handling alone (FCFS kept) already improves on
    // always-discard.
    let trace = infercept::multi_api_dataset(150, 6.0, 37);
    let icept = run_contended("infercept", &trace);
    let vllm = run_contended("vllm", &trace);
    assert!(icept.latency.mean_us < vllm.latency.mean_us,
            "infercept {} vs vllm {}", icept.latency.mean_us,
            vllm.latency.mean_us);
}

#[test]
fn lamps_beats_infercept_under_load() {
    let trace = infercept::multi_api_dataset(200, 8.0, 41);
    let lamps = run_contended("lamps", &trace);
    let icept = run_contended("infercept", &trace);
    assert!(lamps.latency.mean_us < icept.latency.mean_us,
            "lamps {} vs infercept {}", lamps.latency.mean_us,
            icept.latency.mean_us);
}

#[test]
fn preserve_holds_more_memory_than_discard() {
    // Fig 2's mechanism: all-Preserve keeps KV occupied through API
    // calls; all-Discard frees it.
    let trace = infercept::single_api_dataset(60, 3.0, 43);
    let mk = |strategy| {
        let mut cfg = SystemConfig::preset("lamps-no-sched").unwrap();
        cfg.handling = HandlingPolicy::Forced(strategy);
        let mut engine = Engine::simulated(cfg);
        engine.record_timeline = true;
        engine.run_trace(&trace)
    };
    let preserve = mk(HandlingStrategy::Preserve);
    let discard = mk(HandlingStrategy::Discard);
    let avg_kv = |r: &RunReport| {
        r.timeline.iter().map(|p| p.kv_occupancy).sum::<f64>()
            / r.timeline.len().max(1) as f64
    };
    assert!(avg_kv(&preserve) > avg_kv(&discard),
            "preserve kv {} vs discard kv {}", avg_kv(&preserve),
            avg_kv(&discard));
    // Discard pays recompute work instead.
    assert!(discard.tokens_recomputed > 0);
    assert_eq!(preserve.tokens_recomputed, 0);
}

#[test]
fn starvation_threshold_improves_tail() {
    // Fig 9's mechanism: with promotion, P99 latency must not be much
    // worse than without, and typically improves under pressure.
    let trace = infercept::multi_api_dataset(200, 8.0, 47);
    let mut with = SystemConfig::preset("lamps").unwrap();
    with.starvation_threshold = Some(100);
    let mut without = SystemConfig::preset("lamps").unwrap();
    without.starvation_threshold = None;
    let rep_with = run_cfg(with, &trace);
    let rep_without = run_cfg(without, &trace);
    assert!(rep_with.latency.p99_us <= rep_without.latency.p99_us * 1.05,
            "threshold should not hurt tail: with {} vs without {}",
            rep_with.latency.p99_us, rep_without.latency.p99_us);
}

#[test]
fn large_prediction_error_degrades_lamps() {
    // Fig 11: performance degrades as injected error grows.
    let trace = infercept::multi_api_dataset(150, 7.0, 53);
    let mut exact = SystemConfig::preset("lamps").unwrap();
    exact.predictor = PredictorKind::Oracle;
    let mut noisy = SystemConfig::preset("lamps").unwrap();
    noisy.predictor = PredictorKind::NoisyOracle { error_pct: 1.0 };
    let rep_exact = run_cfg(exact, &trace);
    let rep_noisy = run_cfg(noisy, &trace);
    assert_eq!(rep_exact.completed, rep_noisy.completed);
    assert!(rep_exact.latency.mean_us <= rep_noisy.latency.mean_us * 1.10,
            "oracle {} should not be much worse than 100% error {}",
            rep_exact.latency.mean_us, rep_noisy.latency.mean_us);
}

#[test]
fn time_cap_stops_early() {
    let trace = infercept::single_api_dataset(200, 2.0, 59);
    let cfg = SystemConfig::preset("lamps").unwrap();
    let mut engine = Engine::simulated(cfg);
    let report =
        engine.run_trace_limited(&trace,
                                 Some(Micros::from_secs_f64(20.0)));
    assert!(report.completed < 200);
    assert!(report.duration <= Micros::from_secs_f64(120.0));
}

#[test]
fn no_api_trace_equals_plain_serving() {
    // With API calls stripped, all handling policies coincide; the run
    // must still complete and never recompute.
    let trace = infercept::strip_api_calls(
        &infercept::single_api_dataset(50, 2.0, 61));
    for preset in ["vllm", "infercept", "lamps"] {
        let report = run(preset, &trace);
        assert_eq!(report.completed, 50, "{preset}");
        assert_eq!(report.tokens_recomputed, 0, "{preset}");
    }
}

#[test]
fn memory_budget_is_respected_throughout() {
    let mut cfg = SystemConfig::preset("lamps").unwrap();
    cfg.memory_budget = Tokens(2_000); // tight
    let trace = infercept::single_api_dataset(60, 4.0, 67);
    let mut engine = Engine::simulated(cfg);
    for spec in &trace.requests {
        engine.enqueue(spec.clone());
    }
    let mut steps = 0u64;
    while engine.step() {
        assert!(engine.kv_occupancy() <= 1.0 + 1e-9);
        steps += 1;
        assert!(steps < 2_000_000, "runaway");
    }
    // Tight memory may drop oversized requests, but everything else
    // completes and all memory is returned.
    assert_eq!(engine.kv_occupancy(), 0.0);
}

#[test]
fn score_update_interval_changes_little() {
    // §4.3's selective score update: interval 10 must stay close to
    // interval 1 on latency while doing less ranking work.
    let trace = toolbench::dataset(120, 4.0, 71);
    let mut every = SystemConfig::preset("lamps").unwrap();
    every.score_update_interval = 1;
    let mut sparse = SystemConfig::preset("lamps").unwrap();
    sparse.score_update_interval = 10;
    let rep_every = run_cfg(every, &trace);
    let rep_sparse = run_cfg(sparse, &trace);
    assert_eq!(rep_every.completed, rep_sparse.completed);
    let ratio = rep_sparse.latency.mean_us / rep_every.latency.mean_us;
    assert!(ratio < 1.30, "sparse updates cost {ratio:.2}x latency");
}
