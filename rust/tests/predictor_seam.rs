//! The `--api-pred` duration seam, end to end: byte-identity of the
//! off path, determinism of the learned path, estimator convergence
//! under injected Gaussian error, and the rescue/adopt contract (a
//! moved request neither re-predicts nor double-updates).

use lamps::cluster::ReplicaSet;
use lamps::config::{ApiPredKind, PredictorKind, SystemConfig};
use lamps::engine::Engine;
use lamps::util::json;
use lamps::workload::infercept;

fn lamps_cfg() -> SystemConfig {
    SystemConfig::preset("lamps").unwrap()
}

/// `--api-pred static` (the default) must be byte-identical to a
/// config that never mentions the knob — engine report and fleet
/// report alike — and the learned-only `api_pred_model` key must not
/// leak into the off-path JSON.
#[test]
fn static_mode_reports_are_byte_identical_to_default() {
    let trace = infercept::multi_api_dataset(60, 2.0, 21);

    let default_json =
        Engine::simulated(lamps_cfg()).run_trace(&trace).to_json(true);
    let mut cfg = lamps_cfg();
    cfg.api_pred = ApiPredKind::Static;
    let static_json =
        Engine::simulated(cfg).run_trace(&trace).to_json(true);
    assert_eq!(default_json, static_json,
               "explicit --api-pred static must not move a byte");
    assert!(!static_json.contains("api_pred_model"),
            "estimator state must not leak into the off-path report");

    let fleet_default = ReplicaSet::simulated(lamps_cfg())
        .run_trace(&trace)
        .to_json(true);
    let mut cfg = lamps_cfg();
    cfg.api_pred = ApiPredKind::Static;
    let fleet_static =
        ReplicaSet::simulated(cfg).run_trace(&trace).to_json(true);
    assert_eq!(fleet_default, fleet_static);
    assert!(!fleet_static.contains("api_pred_model"));
}

/// Two identical learned runs produce bit-identical reports (estimator
/// state included): the estimators are deterministic, fixed-order
/// state with no wall-clock or map-order dependence.
#[test]
fn learned_mode_is_deterministic_across_runs() {
    let trace = infercept::multi_api_dataset(60, 2.0, 23);
    let run = || {
        let mut cfg = lamps_cfg();
        cfg.predictor = PredictorKind::NoisyOracle { error_pct: 0.3 };
        cfg.api_pred = ApiPredKind::Learned;
        Engine::simulated(cfg).run_trace(&trace).to_json(true)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "learned runs must be bit-identical");
    assert!(a.contains("api_pred_model"),
            "learned report must expose estimator state");

    let fleet = || {
        let mut cfg = lamps_cfg();
        cfg.predictor = PredictorKind::NoisyOracle { error_pct: 0.3 };
        cfg.api_pred = ApiPredKind::Learned;
        ReplicaSet::simulated(cfg).run_trace(&trace).to_json(true)
    };
    assert_eq!(fleet(), fleet());
}

/// Under injected Gaussian error the estimators fill in and stay
/// coherent: every populated class has a positive mean, ordered
/// quantiles, a blend weight in [0, 1], and the class counts sum to
/// the engine's observation total.
#[test]
fn estimators_converge_under_injected_error() {
    let trace = infercept::multi_api_dataset(80, 2.0, 29);
    let mut cfg = lamps_cfg();
    cfg.predictor = PredictorKind::NoisyOracle { error_pct: 0.5 };
    cfg.api_pred = ApiPredKind::Learned;
    let mut engine = Engine::simulated(cfg);
    let report = engine.run_trace(&trace);
    assert!(engine.api_pred_observations() > 0,
            "simulated returns must feed the estimators");

    let v = json::parse(&report.to_json(false)).unwrap();
    let model = v.get("api_pred_model").expect("learned state in JSON");
    let classes = model.as_obj().expect("per-class object");
    assert!(!classes.is_empty());
    let mut total_n = 0u64;
    for (label, est) in classes {
        let f = |key: &str| {
            est.get(key)
                .and_then(|x| x.as_f64())
                .unwrap_or_else(|| panic!("{label}.{key} missing"))
        };
        let n = f("n");
        assert!(n >= 1.0, "{label}: n");
        total_n += n as u64;
        assert!(f("mean_us") > 0.0, "{label}: mean");
        assert!(f("p50_us") <= f("p90_us"), "{label}: quantile order");
        let blend = f("blend");
        assert!((0.0..=1.0).contains(&blend), "{label}: blend");
        assert!(f("rel_err_ema") >= 0.0, "{label}: rel_err_ema");
    }
    assert_eq!(total_n, engine.api_pred_observations(),
               "class counts must sum to the engine total");
    // 50% injected noise must register as observed error somewhere.
    assert!(classes.values().any(|est| {
        est.get("rel_err_ema").and_then(|x| x.as_f64()).unwrap_or(0.0)
            > 0.05
    }), "injected error must show up in the error EMAs");
}

/// Rescue/adopt carries predictions verbatim: moving a waiting request
/// from a cold replica to a warm one must neither re-predict the
/// segments through the adopter's estimators nor add an observation on
/// either side.
#[test]
fn adopted_request_neither_repredicts_nor_double_updates() {
    let probe_trace = infercept::multi_api_dataset(2, 2.0, 31);
    let probe = probe_trace.requests[0].clone();
    let id = probe.id;

    // Cold owner: learned but with zero observations, so submit-time
    // predictions are the raw class priors.
    let mut cfg = lamps_cfg();
    cfg.predictor = PredictorKind::NoisyOracle { error_pct: 0.6 };
    cfg.api_pred = ApiPredKind::Learned;
    let mut owner = Engine::simulated(cfg.clone());

    // Warm adopter: run a trace through it first so its estimators are
    // hot — if adopt re-predicted, they would rewrite the estimates.
    let mut adopter = Engine::simulated(cfg);
    adopter.run_trace(&infercept::multi_api_dataset(60, 2.0, 37));
    let warm_obs = adopter.api_pred_observations();
    assert!(warm_obs >= 4, "adopter must be warm for the pin to bite");

    owner.submit(probe);
    let before = owner
        .request(id)
        .expect("submitted request is resident")
        .predictions
        .clone();
    assert!(!before.is_empty());

    let w = owner.withdraw_waiting(id).expect("request is waiting");
    adopter.adopt(w);

    let after = &adopter
        .request(id)
        .expect("adopted request is resident")
        .predictions;
    assert_eq!(&before, after,
               "adopt must carry predictions verbatim, not re-predict \
                through the warm estimators");
    assert_eq!(adopter.api_pred_observations(), warm_obs,
               "a move is not an outcome — no estimator update");
    assert_eq!(owner.api_pred_observations(), 0,
               "withdrawing must not record an outcome either");
}
