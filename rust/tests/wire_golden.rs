//! Golden-transcript test: replay `examples/protocol_v2.ndjson`
//! through the typed wire layer and prove byte-for-byte compatibility
//! with the documented protocol.
//!
//! Every `<-` (server) line must be in the canonical `util::json`
//! writer form AND come out of the typed `wire::Encoder` identical to
//! the byte. Every `->` (client) line must round-trip through
//! `wire::Frame::parse` and the typed `to_line()` constructors (the
//! legacy v1 line only parses — its canonical form is the v2 shape).
//! The transcript's malformed tool_result must produce exactly the
//! error text the following server line documents.

use lamps::util::json::{self, Value};
use lamps::wire::{CompletionFrame, Encoder, EventFrame, Frame};

fn transcript() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/../examples/protocol_v2.ndjson");
    std::fs::read_to_string(path).expect("transcript readable")
}

fn u(v: &Value, key: &'static str) -> u64 {
    v.u64_field(key).expect(key)
}

/// Rebuild the typed `EventFrame` a server line documents, borrowing
/// the string fields straight out of the parsed `Value`.
fn typed<'a>(v: &'a Value, line: &str) -> EventFrame<'a> {
    let err = |v: &'a Value| {
        v.get("error")
            .and_then(|e| e.as_str())
            .expect("error field is a string")
    };
    match v.get("type").and_then(|t| t.as_str()) {
        Some("queued") => EventFrame::Queued { id: u(v, "id") },
        Some("placed") => EventFrame::Placed {
            id: u(v, "id"),
            replica: u(v, "replica"),
        },
        Some("first_token") => {
            EventFrame::FirstToken { id: u(v, "id") }
        }
        Some("tokens") => EventFrame::Tokens {
            id: u(v, "id"),
            chunk: u(v, "chunk"),
        },
        Some("api_call_started") => EventFrame::ApiCallStarted {
            id: u(v, "id"),
            index: u(v, "index"),
            strategy: v
                .get("strategy")
                .and_then(|s| s.as_str())
                .expect("strategy is a string"),
            predicted_us: u(v, "predicted_us"),
            external: v
                .get("external")
                .and_then(|b| b.as_bool())
                .expect("external is a bool"),
        },
        Some("api_call_completed") => EventFrame::ApiCallCompleted {
            id: u(v, "id"),
            index: u(v, "index"),
            actual_us: u(v, "actual_us"),
        },
        Some("finished") => EventFrame::Finished(completion(v)),
        Some("dropped") => EventFrame::Dropped {
            id: u(v, "id"),
            reason: v
                .get("reason")
                .and_then(|r| r.as_str())
                .expect("reason is a string"),
        },
        Some("error") => match v.get("id") {
            Some(_) => EventFrame::SessionError {
                id: u(v, "id"),
                error: err(v),
            },
            None => EventFrame::Error { error: err(v) },
        },
        Some(other) => {
            panic!("transcript line has unmapped type {other}: {line}")
        }
        // v1 completion object: no "type" key at all.
        None => EventFrame::Completion(completion(v)),
    }
}

fn completion<'a>(v: &'a Value) -> CompletionFrame<'a> {
    // Every transcript completion carries generated:null; a non-null
    // token list would need a backing slice this helper can't borrow.
    assert!(matches!(v.get("generated"), Some(Value::Null)),
            "transcript completions carry generated:null");
    CompletionFrame {
        id: u(v, "id"),
        latency_us: u(v, "latency_us"),
        ttft_us: v.get("ttft_us").and_then(|t| t.as_u64()),
        tokens_decoded: u(v, "tokens_decoded"),
        generated: None,
        dropped: v.get("dropped").and_then(|d| d.as_str()),
    }
}

#[test]
fn transcript_replays_byte_identically_through_the_typed_wire_layer() {
    let text = transcript();
    // Set when a `->` line is (deliberately) malformed; the next `<-`
    // line documents the exact error frame it must produce.
    let mut pending_parse_error: Option<String> = None;
    let mut server_lines = 0usize;
    let mut client_lines = 0usize;
    for raw in text.lines() {
        let raw = raw.trim();
        if raw.is_empty() || raw.starts_with('#') {
            continue;
        }
        if let Some(line) = raw.strip_prefix("-> ") {
            client_lines += 1;
            match Frame::parse(line) {
                Ok(Frame::Request(req)) => {
                    assert_eq!(req.to_line(), line,
                               "request to_line() must emit the \
                                documented canonical bytes");
                }
                Ok(Frame::ToolResult(tr)) => {
                    assert_eq!(tr.to_line(), line,
                               "tool_result to_line() must emit the \
                                documented canonical bytes");
                }
                Ok(Frame::Cancel(c)) => {
                    assert_eq!(c.to_line(), line,
                               "cancel to_line() must emit the \
                                documented canonical bytes");
                }
                Ok(Frame::V1Request(req)) => {
                    assert_eq!(req.prompt, "hello");
                    assert_eq!(req.output_tokens, 3);
                    assert!(req.api_calls.is_empty(),
                            "the v1 line has no implicit call");
                }
                Err(e) => {
                    pending_parse_error = Some(e.reply_message());
                }
            }
        } else if let Some(line) = raw.strip_prefix("<- ") {
            server_lines += 1;
            let v = json::parse(line).expect("server line is JSON");
            assert_eq!(json::write(&v), line,
                       "transcript server lines are in canonical \
                        writer form");
            let frame = typed(&v, line);
            assert_eq!(Encoder::frame_to_string(&frame), line,
                       "typed encoder must reproduce the line");
            if let Some(reply) = pending_parse_error.take() {
                let documented = v
                    .get("error")
                    .and_then(|e| e.as_str())
                    .expect("error reply documents its text");
                assert_eq!(reply, documented,
                           "parse error reply must match the \
                            documented frame");
            }
        } else {
            panic!("transcript line has no direction marker: {raw}");
        }
    }
    assert!(pending_parse_error.is_none(),
            "a malformed client line was never answered");
    // The transcript shrank? Something was deleted — this test exists
    // to notice exactly that.
    assert!(client_lines >= 5, "expected >=5 client lines");
    assert!(server_lines >= 11, "expected >=11 server lines");
}

/// The whole server->client transcript must also batch through one
/// reusable encoder into exactly the concatenated documented bytes —
/// the pump's drain path, not just frame-at-a-time encoding.
#[test]
fn transcript_batches_through_one_encoder_drain() {
    let text = transcript();
    let mut expected = String::new();
    let mut enc = Encoder::with_capacity(64);
    let mut parsed: Vec<Value> = Vec::new();
    for raw in text.lines() {
        let raw = raw.trim();
        if let Some(line) = raw.strip_prefix("<- ") {
            expected.push_str(line);
            expected.push('\n');
            parsed.push(json::parse(line).expect("server line"));
        }
    }
    for v in &parsed {
        enc.push(&typed(v, "batched"));
    }
    let mut out: Vec<u8> = Vec::new();
    enc.drain_to(&mut out).expect("Vec write cannot fail");
    assert_eq!(String::from_utf8(out).expect("utf8"), expected);
    assert!(enc.is_empty(), "drain must reset the buffer");
}
