//! Offline **stub** of the `xla` PJRT bindings: mirrors the API surface
//! `lamps::runtime` compiles against, but carries no real XLA. Client
//! construction and HLO loading return a descriptive error at runtime, so
//! every PJRT entry point fails fast while the simulator path (the tier-1
//! test surface) is fully functional. The PJRT integration tests detect
//! missing artifacts and skip, so `cargo test` stays green.
//!
//! Pure-data pieces (`Literal` packing/reshaping) are implemented for
//! real so unit code around them behaves sensibly.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `xla::Error` role.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable — this build vendors the offline xla stub; \
         link the real xla/PJRT crate to run compiled artifacts"))
}

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S32,
    F32,
}

/// Conversion between Rust scalars and literal storage.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_f64(self) -> f64;
    fn from_f64(v: f64) -> Self;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> i32 {
        v as i32
    }
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
}

/// Host-side tensor literal (dense, row-major). Functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            ty: T::TY,
            data: data.iter().map(|x| x.to_f64()).collect(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims)));
        }
        Ok(Literal {
            ty: self.ty,
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f64(x)).collect())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("tuple decomposition of stub literal"))
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(unavailable("tuple decomposition of stub literal"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module handle (never constructible at runtime in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "parsing HLO text at {}", path.as_ref().display())))
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compilation"))
    }
}

/// Device buffer holding one execution output.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("buffer fetch"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T])
                                      -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1i32, 2, 3, 4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn f32_literals() {
        let l = Literal::vec1(&[0.5f32, 1.5]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![0.5, 1.5]);
    }

    #[test]
    fn runtime_entry_points_fail_fast() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/no/such.hlo").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("stub"));
    }
}
