//! Offline stand-in for the `anyhow` crate: the subset this workspace
//! uses (`Result`, `Error`, `anyhow!`, `bail!`, `Context`), vendored so
//! the build needs no network access. API-compatible for those items, so
//! swapping in the real crate later is a one-line Cargo change.

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error with an optional chain of context messages.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
    context: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            inner: Box::new(StringError(message.to_string())),
            context: Vec::new(),
        }
    }

    fn push_context(mut self, ctx: String) -> Error {
        self.context.push(ctx);
        self
    }

    /// The root cause, like `anyhow::Error::root_cause`.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cause: &(dyn StdError + 'static) = self.inner.as_ref();
        while let Some(source) = cause.source() {
            cause = source;
        }
        cause
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below legal.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            inner: Box::new(e),
            context: Vec::new(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first, then the chain down to the cause.
        for ctx in self.context.iter().rev() {
            write!(f, "{ctx}: ")?;
        }
        write!(f, "{}", self.inner)
    }
}

// Debug renders like Display plus the source chain — what `?` in `main`
// prints.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")?;
        let mut source = self.inner.source();
        while let Some(s) = source {
            write!(f, "\n\nCaused by:\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

/// `anyhow::Result<T>` — defaulted error parameter, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Plain-string error used by `anyhow!` / `Error::msg`.
#[derive(Debug)]
struct StringError(String);

impl fmt::Display for StringError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl StdError for StringError {}

/// Attach context to an error, as `anyhow::Context` does.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self, context: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().push_context(f().to_string()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// `anyhow!("...")` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "nope")
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("nope"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r
            .context("reading file")
            .map_err(|e| e.push_context("loading config".into()))
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading config: reading file: nope");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert!(format!("{e}").starts_with("step 3: "));
    }

    #[test]
    fn anyhow_and_bail_macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()),
                   "failed with code 7");
        let e = anyhow!("x={}", 2);
        assert_eq!(format!("{e}"), "x=2");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn collect_into_result() {
        let parsed: Result<Vec<u32>> = ["1", "2"]
            .iter()
            .map(|s| s.parse::<u32>().map_err(Error::from))
            .collect();
        assert_eq!(parsed.unwrap(), vec![1, 2]);
    }
}
