//! Seeded `wire-format` violations: JSON frames assembled by string
//! splicing instead of `util::json::obj` (the PR 5 injection class).

pub fn error_frame(id: u64, msg: &str) -> String {
    format!("{{\"type\":\"error\",\"id\":{id},\"error\":\"{msg}\"}}")
}

pub fn append_event(out: &mut String) {
    out.push_str(r#"{"type":"event","name":"first_token"}"#);
}
