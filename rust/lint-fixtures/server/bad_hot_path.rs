//! Seeded `wire-hot-path` violations: allocating `util::json`
//! round-trips on the serving hot path instead of the typed
//! `crate::wire` layer (the PR 7 zero-copy class).

pub fn dispatch(line: &str) -> String {
    let value = json::parse(line).unwrap_or(json::Value::Null);
    json::write(&value)
}
