//! Seeded violation for `probe-hot-loop`: prompt hashing inside the
//! per-replica scoring loop. The chain must be computed once per
//! arrival (ArrivalScratch) and borrowed by every probe.

pub fn worst_replica(replicas: &[Engine], spec: &RequestSpec) -> usize {
    let mut best = 0;
    let mut most_cached = 0u64;
    for (i, e) in replicas.iter().enumerate() {
        let chain = prefix::content_chain(spec, 16, spec.prompt_tokens);
        let cached = e.cached_blocks(&chain);
        if cached > most_cached {
            best = i;
            most_cached = cached;
        }
    }
    best
}
