//! Seeded violation for `gossip-seam`: a cluster-layer consumer
//! mutating the fleet's `SharedPrefixIndex` mirror directly instead of
//! feeding journal deltas through the gossip pipeline, so the mirror
//! outruns the modeled network.

pub fn steal_credit(index: &mut SharedPrefixIndex, hash: BlockHash,
                    replica: usize) {
    index.mirror_insert(hash, replica);
}

pub fn drop_claim(index: &mut SharedPrefixIndex, hash: BlockHash,
                  replica: usize) {
    index.mirror_remove(hash, replica);
}
