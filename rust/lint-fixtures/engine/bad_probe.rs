//! Seeded `probe-purity` violation: a placement probe that takes
//! `&mut` can perturb the state it scores.

pub fn placement_score(engines: &mut Vec<u64>, tokens: u64) -> f64 {
    engines.push(tokens);
    engines.len() as f64
}
