//! Seeded `panic` violations: unwrap/expect/panic!/indexing in
//! scheduler-critical code without an escape.

pub fn pop(queue: &mut Vec<u64>, lookup: Option<u64>) -> u64 {
    let head = queue.pop().unwrap();
    let hit = lookup.expect("must be resident");
    if head == 0 {
        panic!("zero head");
    }
    head + hit + queue[0]
}
