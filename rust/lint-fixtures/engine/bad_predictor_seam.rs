//! Seeded violation for `predictor-seam`: an engine-layer consumer
//! reading the Table 2 stats directly instead of going through the
//! `predictor::duration` seam, so learned estimators never see (or
//! revise) this estimate.

pub fn api_eta(api: ApiType) -> Micros {
    api_stats::predicted_duration(api)
}

pub fn api_budget(api: ApiType) -> u64 {
    let stats = api_stats::stats_for(api);
    stats.response_tokens.0.round() as u64
}
