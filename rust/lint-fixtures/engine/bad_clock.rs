//! Seeded `wall-clock` violations: real time read outside the
//! `engine/clock.rs` seam breaks virtual-clock determinism.

pub fn stamp_us() -> u128 {
    let t = std::time::Instant::now();
    let epoch = std::time::SystemTime::now();
    let _ = epoch;
    t.elapsed().as_micros()
}
