//! A clean fixture: the same panic-class sites as `bad_panic.rs`, but
//! each carries a well-formed `lamps-lint` escape naming the rule and
//! a reason — this file must scan clean.

pub fn pop(queue: &mut Vec<u64>, lookup: Option<u64>) -> u64 {
    // lamps-lint: allow(panic) invariant: caller checked non-empty
    let head = queue.pop().unwrap();
    let hit = lookup.expect("resident"); // lamps-lint: allow(panic) invariant: admission pinned it
    head + hit
}
