//! Seeded `float-iter` violations: f64 accumulation over HashMap
//! iteration order (the PR 3 placement-reproducibility class).

use std::collections::HashMap;

pub fn mean_load(per_replica: &HashMap<u64, f64>) -> f64 {
    let mut total = 0.0;
    for load in per_replica.values() {
        total += load;
    }
    total / per_replica.len().max(1) as f64
}

pub fn chained(per_replica: &HashMap<u64, f64>) -> f64 {
    per_replica.values().copied().sum::<f64>()
}
