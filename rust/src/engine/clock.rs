//! Virtual vs. wall clock. The engine advances time after each unit of
//! work and jumps/waits when idle; which of those is a simulation update
//! or a real sleep is the only difference between bench runs and live
//! serving.

use std::time::Instant;

use crate::core::types::Micros;

#[derive(Debug)]
pub enum Clock {
    /// Discrete-event time: `advance` adds, `wait_until` jumps.
    Virtual { now: Micros },
    /// Real time anchored at engine start: `advance` re-reads the wall
    /// clock (the work already took the time), `wait_until` sleeps.
    Wall { start: Instant },
}

impl Clock {
    pub fn virtual_clock() -> Clock {
        Clock::Virtual { now: Micros::ZERO }
    }

    pub fn wall_clock() -> Clock {
        Clock::Wall { start: Instant::now() }
    }

    pub fn now(&self) -> Micros {
        match self {
            Clock::Virtual { now } => *now,
            Clock::Wall { start } => {
                Micros(start.elapsed().as_micros() as u64)
            }
        }
    }

    /// Account for `elapsed` of work just performed.
    pub fn advance(&mut self, elapsed: Micros) -> Micros {
        match self {
            Clock::Virtual { now } => {
                *now += elapsed;
                *now
            }
            // Wall time already passed while the backend executed.
            Clock::Wall { .. } => self.now(),
        }
    }

    /// Block (or jump) until `target`; returns the new now.
    pub fn wait_until(&mut self, target: Micros) -> Micros {
        match self {
            Clock::Virtual { now } => {
                if target > *now {
                    *now = target;
                }
                *now
            }
            Clock::Wall { .. } => {
                let now = self.now();
                if target > now {
                    std::thread::sleep(std::time::Duration::from_micros(
                        (target - now).0));
                }
                self.now()
            }
        }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_advance_and_jump() {
        let mut c = Clock::virtual_clock();
        assert_eq!(c.now(), Micros::ZERO);
        assert_eq!(c.advance(Micros(100)), Micros(100));
        assert_eq!(c.wait_until(Micros(500)), Micros(500));
        // waiting into the past is a no-op
        assert_eq!(c.wait_until(Micros(10)), Micros(500));
    }

    #[test]
    fn wall_clock_monotone() {
        let mut c = Clock::wall_clock();
        let a = c.now();
        let b = c.advance(Micros(1)); // ignored; reads real time
        assert!(b >= a);
        let target = c.now() + Micros(2_000);
        let after = c.wait_until(target);
        assert!(after >= target);
    }
}
