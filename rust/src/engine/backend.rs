//! Execution backends: the engine's scheduling logic is backend-agnostic;
//! a [`Backend`] supplies the *cost* (and, for PJRT, the actual compute) of
//! prefill, decode, and swap operations.
//!
//! - [`SimBackend`] — analytic cost model over a virtual clock; used for
//!   paper-scale figure sweeps (API durations up to ~30 s x thousands of
//!   requests cannot run in wall-clock).
//! - [`crate::engine::pjrt_backend::PjrtBackend`] — real token generation
//!   through the AOT-compiled HLO artifacts.

use crate::config::CostModel;
use crate::core::types::{Micros, RequestId, Tokens};

/// One member of a decode batch.
#[derive(Debug, Clone, Copy)]
pub struct DecodeSlot {
    pub id: RequestId,
    /// Live context size (tokens with KV entries) for this request.
    pub ctx: Tokens,
}

/// Execution backend contract. All methods return the elapsed time of the
/// operation (virtual for the simulator, measured for PJRT).
pub trait Backend {
    /// Hard cap on concurrently resident sequences (PJRT executables have
    /// a fixed batch dimension). `None` = unbounded.
    fn slot_capacity(&self) -> Option<usize> {
        None
    }

    /// Hard cap on per-request context length. `None` = unbounded.
    fn max_context(&self) -> Option<u64> {
        None
    }

    /// Materialize context for `id` (prompt prefill, post-Discard
    /// recompute, or API-response append). `total_ctx` is the full
    /// logical context after materialization; `increment` is the newly
    /// materialized part (what an incremental system computes — the
    /// simulator charges prefill cost on it). `prompt` is the request's
    /// prompt text (used by real backends; the simulator ignores it).
    fn materialize(&mut self, id: RequestId, prompt: &str,
                   total_ctx: Tokens, increment: Tokens) -> Micros;

    /// One decode iteration over `batch`: every slot appends one token.
    fn decode(&mut self, batch: &[DecodeSlot]) -> Micros;

    /// Move `ctx` tokens of `id`'s KV state to host memory.
    fn swap_out(&mut self, id: RequestId, ctx: Tokens) -> Micros;

    /// Restore `id`'s KV state from host memory.
    fn swap_in(&mut self, id: RequestId, ctx: Tokens) -> Micros;

    /// Drop all backend state for `id` (finished or preempted).
    fn release(&mut self, id: RequestId);

    /// Can this backend resume decoding from KV state it never saw a
    /// `materialize` call for? The engine only lets prefix-cache hits
    /// skip prefill when this is true. The simulator is stateless
    /// (true); the PJRT backend keeps per-request fixed-slot state that
    /// must be built by its own `materialize`, so it opts out and the
    /// cache degrades to a no-op there until the runtime grows real
    /// paged-KV sharing.
    fn supports_prefix_reuse(&self) -> bool {
        true
    }

    /// Downcast hook (used to reach PJRT-specific accessors like
    /// generated-token histories from behind the trait object).
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }
}

/// Analytic backend: charges the configured [`CostModel`], generates no
/// real tokens.
#[derive(Debug, Clone)]
pub struct SimBackend {
    pub cost: CostModel,
}

impl SimBackend {
    pub fn new(cost: CostModel) -> SimBackend {
        SimBackend { cost }
    }
}

impl Backend for SimBackend {
    fn materialize(&mut self, _id: RequestId, _prompt: &str,
                   _total_ctx: Tokens, increment: Tokens) -> Micros {
        self.cost.prefill_time(increment)
    }

    fn decode(&mut self, batch: &[DecodeSlot]) -> Micros {
        let total_ctx: Tokens = batch.iter().map(|s| s.ctx).sum();
        self.cost.decode_iter_time(total_ctx)
    }

    fn swap_out(&mut self, _id: RequestId, ctx: Tokens) -> Micros {
        self.cost.swap_time(ctx)
    }

    fn swap_in(&mut self, _id: RequestId, ctx: Tokens) -> Micros {
        self.cost.swap_time(ctx)
    }

    fn release(&mut self, _id: RequestId) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_costs_match_model() {
        let mut b = SimBackend::new(CostModel::paper_scale());
        assert_eq!(b.materialize(RequestId(1), "", Tokens(150),
                                 Tokens(100)),
                   Micros(10_000));
        let batch = [
            DecodeSlot { id: RequestId(1), ctx: Tokens(100) },
            DecodeSlot { id: RequestId(2), ctx: Tokens(200) },
        ];
        assert_eq!(b.decode(&batch), Micros(10_300));
        assert_eq!(b.swap_out(RequestId(1), Tokens(10)), Micros(1300));
        assert_eq!(b.swap_in(RequestId(1), Tokens(10)), Micros(1300));
    }

    #[test]
    fn sim_unbounded() {
        let b = SimBackend::new(CostModel::unit());
        assert_eq!(b.slot_capacity(), None);
        assert_eq!(b.max_context(), None);
    }
}
