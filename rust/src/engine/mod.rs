//! The serving engine: iteration-level scheduling loop (paper Algorithm 1)
//! over a pluggable execution [`Backend`] and [`Clock`].
//!
//! One scheduling round:
//! 1. admit arrivals (predict + assign handling strategies),
//! 2. drain returned API calls back into the waiting queue,
//! 3. rank the waiting queue (scheduler policy + starvation promotion),
//! 4. admit requests into the running batch under the memory budget and
//!    the clairvoyant reservation check (see below),
//! 5. materialize admitted contexts (prefill / recompute / swap-in),
//! 6. run one decode iteration; route API-encounters to the P/D/S queues,
//!    complete finished requests.
//!
//! **Reservation admission** (`admission_lookahead`): a candidate is only
//! admitted if every in-flight Preserve/Swap API request can still resume
//! at its *predicted* return time given the candidate's own predicted
//! memory trajectory. This is the mechanism that lets a short request run
//! "inside" another request's API call in the paper's Fig 3 walkthrough
//! (R2 admitted during R1's call because it discards in time; R3 rejected
//! because it would still hold memory when R1 resumes).

pub mod api_executor;
pub mod backend;
pub mod clock;
pub mod pjrt_backend;

use std::collections::HashMap;

use crate::config::{HandlingPolicy, PredictorKind, SchedulerKind,
                    SystemConfig};
use crate::coordinator::handling::{select_strategy, WasteInputs};
use crate::coordinator::scheduler::{make_scheduler, ScheduleContext,
                                    Scheduler};
use crate::core::request::{HandlingStrategy, Phase, Request, RequestSpec};
use crate::core::types::{Micros, RequestId, Tokens};
use crate::kv::{BlockManager, SwapSpace};
use crate::metrics::{MetricsCollector, RunReport, TimelinePoint};
use crate::predictor::oracle::{NoisyOraclePredictor, OraclePredictor};
use crate::predictor::Predictor;
use crate::workload::Trace;

use api_executor::ApiExecutor;
use backend::{Backend, DecodeSlot, SimBackend};
use clock::Clock;

/// Safety valve against scheduling livelock in buggy configs.
const MAX_ITERATIONS: u64 = 200_000_000;

pub struct Engine {
    pub cfg: SystemConfig,
    scheduler: Box<dyn Scheduler>,
    predictor: Box<dyn Predictor>,
    backend: Box<dyn Backend>,
    clock: Clock,
    kv: BlockManager,
    swap: SwapSpace,
    api: ApiExecutor,

    requests: HashMap<RequestId, Request>,
    waiting: Vec<RequestId>,
    running: Vec<RequestId>,
    /// Arrival-sorted, not-yet-submitted specs (drained by time).
    pending: std::collections::VecDeque<RequestSpec>,
    /// Predicted API return times for in-flight calls (the scheduler's
    /// knowledge; true returns live in the executor heap).
    pred_return: HashMap<RequestId, Micros>,

    pub metrics: MetricsCollector,
    iteration: u64,
    /// EMA of decode iteration duration (t_iter estimate for ranking and
    /// the lookahead projection).
    t_iter_ema: f64,
    /// EMA of co-batched context (the C_other estimate, §3.2.1).
    c_other_ema: f64,
    /// Record per-iteration timeline points (Fig 2); off by default for
    /// large sweeps.
    pub record_timeline: bool,
    /// Requests dropped because they can never fit the memory budget.
    pub dropped: Vec<RequestId>,
}

impl Engine {
    pub fn new(cfg: SystemConfig, backend: Box<dyn Backend>,
               predictor: Box<dyn Predictor>, clock: Clock) -> Engine {
        let kv = BlockManager::new(cfg.memory_budget, cfg.block_size);
        let t_iter0 = cfg.cost.decode_iter_time(Tokens::ZERO).0 as f64;
        let c_other0 = cfg.memory_budget.0 as f64 / 2.0;
        Engine {
            scheduler: make_scheduler(cfg.scheduler),
            predictor,
            backend,
            clock,
            kv,
            swap: SwapSpace::unbounded(),
            api: ApiExecutor::new(),
            requests: HashMap::new(),
            waiting: Vec::new(),
            running: Vec::new(),
            pending: std::collections::VecDeque::new(),
            pred_return: HashMap::new(),
            metrics: MetricsCollector::new(),
            iteration: 0,
            t_iter_ema: t_iter0,
            c_other_ema: c_other0,
            record_timeline: false,
            dropped: Vec::new(),
            cfg,
        }
    }

    /// Simulated engine: analytic backend + virtual clock + the predictor
    /// named in the config.
    pub fn simulated(cfg: SystemConfig) -> Engine {
        let backend = Box::new(SimBackend::new(cfg.cost));
        let predictor: Box<dyn Predictor> = match cfg.predictor {
            PredictorKind::Oracle => Box::new(OraclePredictor),
            PredictorKind::NoisyOracle { error_pct } => {
                Box::new(NoisyOraclePredictor::new(error_pct, cfg.seed))
            }
            PredictorKind::Pjrt => {
                panic!("PJRT predictor requires Engine::new with a \
                        PjrtPredictor (see runtime::)")
            }
        };
        Engine::new(cfg, backend, predictor, Clock::virtual_clock())
    }

    pub fn now(&self) -> Micros {
        self.clock.now()
    }

    pub fn request(&self, id: RequestId) -> Option<&Request> {
        self.requests.get(&id)
    }

    pub fn kv_occupancy(&self) -> f64 {
        self.kv.occupancy()
    }

    /// Downcast access to backend-specific state (e.g. PJRT generated
    /// tokens).
    pub fn backend_any(&self) -> Option<&dyn std::any::Any> {
        self.backend.as_any()
    }

    // ------------------------------------------------------------------
    // Submission
    // ------------------------------------------------------------------

    /// Queue a spec for arrival-time-driven submission.
    pub fn enqueue(&mut self, spec: RequestSpec) {
        self.pending.push_back(spec);
    }

    /// Submit immediately with predicted handling per the config policy.
    pub fn submit(&mut self, spec: RequestSpec) {
        let predictions = self.predictor.predict(&spec);
        let handling = self.assign_handling(&spec, &predictions);
        self.submit_prepared(spec, predictions, handling);
    }

    /// Submit with explicit per-call strategies (tests / Fig 3).
    pub fn submit_with_handling(&mut self, spec: RequestSpec,
                                handling: Vec<HandlingStrategy>) {
        let predictions = self.predictor.predict(&spec);
        self.submit_prepared(spec, predictions, handling);
    }

    fn submit_prepared(&mut self, spec: RequestSpec,
                       predictions: Vec<crate::core::request::SegmentPrediction>,
                       handling: Vec<HandlingStrategy>) {
        let id = spec.id;
        let arrival = spec.arrival;
        self.metrics.on_arrival(id, arrival);
        let req = Request::new(spec, predictions, handling);
        if req.admission_memory() > self.kv.capacity() {
            // Can never fit; fail fast instead of livelocking.
            self.dropped.push(id);
            return;
        }
        self.requests.insert(id, req);
        self.waiting.push(id);
    }

    /// Handling assignment at admission (LAMPS §4.2). For `MinWasteAtApi`
    /// (INFERCEPT) the real decision happens at encounter time; Preserve
    /// placeholders are stored until then.
    fn assign_handling(
        &self, spec: &RequestSpec,
        predictions: &[crate::core::request::SegmentPrediction])
        -> Vec<HandlingStrategy> {
        match self.cfg.handling {
            HandlingPolicy::Forced(s) => vec![s; spec.api_calls.len()],
            HandlingPolicy::MinWasteAtApi => {
                vec![HandlingStrategy::Preserve; spec.api_calls.len()]
            }
            HandlingPolicy::MinWastePredicted => {
                let mut ctx = spec.prompt_tokens.0 as f64;
                let mut out = Vec::with_capacity(spec.api_calls.len());
                for (i, _call) in spec.api_calls.iter().enumerate() {
                    let pred = &predictions[i];
                    ctx += pred.decode_tokens.0 as f64;
                    let inp = WasteInputs {
                        ctx: Tokens(ctx as u64),
                        api_duration: pred
                            .api_duration
                            .unwrap_or(Micros::ZERO),
                        c_other: Tokens(self.c_other_ema as u64),
                    };
                    out.push(select_strategy(&inp, &self.cfg.cost));
                    ctx += pred.response_tokens.0 as f64;
                }
                out
            }
        }
    }

    // ------------------------------------------------------------------
    // Main loop
    // ------------------------------------------------------------------

    /// Run a trace to completion (virtual-clock runs) and report.
    pub fn run_trace(&mut self, trace: &Trace) -> RunReport {
        self.run_trace_limited(trace, None)
    }

    /// Run a trace, stopping at `time_cap` if given (Fig 8's 30-minute
    /// throughput window).
    pub fn run_trace_limited(&mut self, trace: &Trace,
                             time_cap: Option<Micros>) -> RunReport {
        for spec in &trace.requests {
            self.enqueue(spec.clone());
        }
        self.run_until_idle(time_cap);
        self.metrics.end_time = self.now();
        self.metrics.report()
    }

    /// Drive rounds until every submitted request finished (or dropped),
    /// or the cap is reached.
    pub fn run_until_idle(&mut self, time_cap: Option<Micros>) {
        while self.step() {
            if let Some(cap) = time_cap {
                if self.now() >= cap {
                    break;
                }
            }
            if self.iteration >= MAX_ITERATIONS {
                panic!("engine exceeded MAX_ITERATIONS — scheduling \
                        livelock?");
            }
        }
        self.metrics.end_time = self.now();
    }

    /// One scheduling round. Returns false when fully idle with no
    /// pending work.
    pub fn step(&mut self) -> bool {
        let now = self.now();
        self.drain_arrivals(now);
        self.drain_api_returns(now);
        // Algorithm 1 line 17: the running batch is rebuilt from the
        // sorted queue every iteration. Deselected requests keep their KV
        // (pause, not preemption).
        for id in self.running.drain(..) {
            let req = self.requests.get_mut(&id).unwrap();
            req.phase = Phase::Waiting;
            self.waiting.push(id);
        }
        self.rank_waiting();
        self.admit();

        if self.running.is_empty() {
            // Idle: jump to the next event.
            let next_arrival = self.pending.front().map(|s| s.arrival);
            let next_return = self.api.next_return();
            let target = match (next_arrival, next_return) {
                (Some(a), Some(r)) => Some(a.min(r)),
                (Some(a), None) => Some(a),
                (None, Some(r)) => Some(r),
                (None, None) => None,
            };
            match target {
                Some(t) => {
                    self.clock.wait_until(t);
                    return true;
                }
                None => {
                    // No events, nothing runnable. If paused requests
                    // hold memory that blocks everyone, preempt the
                    // lowest-priority holder (vLLM recompute-style) and
                    // retry; otherwise we are done.
                    if !self.waiting.is_empty() {
                        if let Some(victim) = self.pick_preemption_victim()
                        {
                            self.preempt(victim, now);
                            return true;
                        }
                    }
                    return false;
                }
            }
        }

        self.materialize_admitted();
        self.decode_iteration();
        self.iteration += 1;
        self.metrics.iterations = self.iteration;
        if self.record_timeline {
            let held = |ids: &[RequestId]| -> u64 {
                ids.iter().map(|id| self.kv.tokens_of(*id).0).sum()
            };
            let held_api: u64 = self
                .pred_return
                .keys()
                .map(|id| self.kv.tokens_of(*id).0)
                .sum();
            let point = TimelinePoint {
                at: self.now(),
                kv_occupancy: self.kv.occupancy(),
                completed: self.metrics.completed(),
                in_api: self.api.in_flight(),
                running: self.running.len(),
                held_running: held(&self.running),
                held_api,
                held_waiting: held(&self.waiting),
            };
            self.metrics.sample_timeline(point);
        }
        true
    }

    fn drain_arrivals(&mut self, now: Micros) {
        while let Some(front) = self.pending.front() {
            if front.arrival > now {
                break;
            }
            let spec = self.pending.pop_front().unwrap();
            self.submit(spec);
        }
    }

    fn drain_api_returns(&mut self, now: Micros) {
        let mut returned = Vec::new();
        self.api.drain_returned(now, |id| returned.push(id));
        for id in returned {
            let req = self.requests.get_mut(&id).expect("api return");
            let Phase::ApiWait { strategy, .. } = req.phase else {
                panic!("{id} returned but not in ApiWait");
            };
            self.api.note_returned(strategy);
            self.pred_return.remove(&id);
            let seg = req.segment;
            let response = req.spec.api_calls[seg].response_tokens;
            req.segment += 1;
            req.segment_generated = Tokens::ZERO;
            req.logical_context += response;
            match strategy {
                HandlingStrategy::Preserve => {
                    // KV retained; only the response must be materialized.
                    req.pending_materialize = response;
                }
                HandlingStrategy::Discard => {
                    // Everything must be recomputed.
                    req.pending_materialize = req.logical_context;
                }
                HandlingStrategy::Swap => {
                    // Swap-in restores the old context; the response is
                    // new.
                    req.pending_materialize = response;
                }
            }
            req.phase = Phase::Waiting;
            if self.cfg.requeue_as_new {
                // vLLM treats the continuation as a brand-new job.
                req.queue_key = now;
            }
            // Segment changed: invalidate the cached score.
            req.score_iteration = u64::MAX;
            self.waiting.push(id);
        }
    }

    fn schedule_context(&self) -> ScheduleContext {
        ScheduleContext {
            cost: self.cfg.cost,
            t_iter_est: Micros(self.t_iter_ema as u64),
            c_other_est: Tokens(self.c_other_ema as u64),
            iteration: self.iteration,
        }
    }

    /// Refresh scores (selective update, §4.3) and sort the waiting queue
    /// by (starving desc, score asc, id asc) — Algorithm 1 line 16 plus
    /// the §4.4 promotion rule.
    fn rank_waiting(&mut self) {
        let ctx = self.schedule_context();
        let interval = self.cfg.score_update_interval.max(1);
        for id in &self.waiting {
            let req = self.requests.get_mut(id).expect("waiting req");
            let stale = req.score_iteration == u64::MAX
                || (self.scheduler.is_dynamic()
                    && self.iteration.wrapping_sub(req.score_iteration)
                        >= interval);
            if stale {
                req.cached_score = self.scheduler.score(req, &ctx);
                req.score_iteration = self.iteration;
            }
        }
        let requests = &self.requests;
        self.waiting.sort_by(|a, b| {
            let ra = &requests[a];
            let rb = &requests[b];
            rb.starving
                .cmp(&ra.starving)
                .then(ra.cached_score.total_cmp(&rb.cached_score))
                .then(ra.spec.id.cmp(&rb.spec.id))
        });
    }

    /// Admit waiting requests into the running batch (Algorithm 1 lines
    /// 18-31): respect batch capacity, memory, the backend slot cap, and
    /// the reservation lookahead; track starvation counters.
    fn admit(&mut self) {
        let now = self.now();
        let slot_cap = self
            .backend
            .slot_capacity()
            .unwrap_or(usize::MAX)
            .min(self.cfg.max_batch);
        let mut admitted: Vec<RequestId> = Vec::new();
        let mut still_waiting: Vec<RequestId> = Vec::new();

        let waiting = std::mem::take(&mut self.waiting);
        let mut rest: std::collections::VecDeque<RequestId> =
            waiting.into();
        while let Some(id) = rest.pop_front() {
            // A context that outgrew the whole budget can never run again:
            // drop it rather than livelock (real deployments would error
            // the request back to the client).
            if self.requests[&id].admission_memory() > self.kv.capacity() {
                if self.kv.contains(id) {
                    self.kv.free(id).expect("drop free");
                }
                self.swap.discard(id);
                self.backend.release(id);
                self.requests.get_mut(&id).unwrap().phase =
                    Phase::Finished;
                self.dropped.push(id);
                continue;
            }
            let slot_ok =
                self.running.len() + admitted.len() < slot_cap;
            let mut mem_ok = slot_ok && self.fits_memory(id);
            if slot_ok && !mem_ok {
                // Priority preemption: evict worst-ranked *paused* KV
                // holders (they rank strictly below `id` — the queue is
                // sorted) until the candidate fits. vLLM/FCFS/SJF evict
                // unconditionally (vLLM recompute-on-OOM semantics);
                // LAMPS evicts only when the victim's remaining
                // memory-over-time exceeds the candidate's score plus the
                // recompute waste eviction would cause — which is why R2
                // *waits* for preserved R1 in Fig 3d instead of evicting.
                while !mem_ok {
                    let victim = rest
                        .iter()
                        .rev()
                        .find(|v| self.kv.tokens_of(**v) > Tokens::ZERO)
                        .copied();
                    let Some(v) = victim else { break };
                    if self.cfg.scheduler == SchedulerKind::Lamps
                        && !self.requests[&id].starving
                    {
                        // Starving candidates (§4.4 promotion) always get
                        // resources. Otherwise evict only when the
                        // victim's remaining memory-over-time exceeds the
                        // candidate's score plus the recompute waste the
                        // eviction causes — which is why R2 *waits* for
                        // preserved R1 in Fig 3d instead of evicting.
                        let vr = &self.requests[&v];
                        let ctx = vr.logical_context;
                        let evict_cost = self.cfg.cost.prefill_time(ctx).0
                            as f64
                            * ctx.0 as f64;
                        let candidate_score =
                            self.requests[&id].cached_score;
                        if vr.cached_score
                            <= candidate_score + evict_cost
                        {
                            break; // not worth destroying preserved work
                        }
                    }
                    self.preempt_state(v, now);
                    mem_ok = self.fits_memory(id);
                }
            }
            let resv_ok =
                mem_ok && self.fits_reservation(id, &admitted, now);
            if !slot_ok {
                self.metrics.rejected_slot += 1;
            } else if !mem_ok {
                self.metrics.rejected_memory += 1;
            } else if !resv_ok {
                self.metrics.rejected_reservation += 1;
            }
            let can_admit = resv_ok;
            if can_admit {
                let req = self.requests.get_mut(&id).unwrap();
                // Reserve context + 1 headroom slot (the token this
                // iteration will append). All allocation happens here;
                // decode itself never allocates.
                let existing = self.kv.tokens_of(id);
                let delta = (req.logical_context + Tokens(1))
                    .saturating_sub(existing);
                if delta > Tokens::ZERO {
                    self.kv.allocate(id, delta).expect("fits_memory held");
                }
                req.phase = Phase::Running;
                req.was_scheduled = true;
                req.starvation_cnt = 0;
                if req.first_scheduled_at.is_none() {
                    req.first_scheduled_at = Some(now);
                }
                admitted.push(id);
            } else {
                still_waiting.push(id);
            }
        }

        // Starvation accounting for the left-behind (Algorithm 1 lines
        // 22-31): increment, promote at threshold, sticky until finish.
        if let Some(threshold) = self.cfg.starvation_threshold {
            for id in &still_waiting {
                let req = self.requests.get_mut(id).unwrap();
                if !req.starving {
                    req.starvation_cnt += 1;
                    if req.starvation_cnt >= threshold {
                        req.starving = true;
                        req.starvation_cnt = 0;
                    }
                }
            }
        }

        self.waiting = still_waiting;
        self.running.extend(admitted);
    }

    /// Immediate memory check: context + 1 token of headroom must fit.
    fn fits_memory(&self, id: RequestId) -> bool {
        let req = &self.requests[&id];
        let existing = self.kv.tokens_of(id);
        let needed = req
            .logical_context
            .saturating_sub(existing)
            + Tokens(1);
        self.kv.can_fit(id, needed)
    }

    /// Clairvoyant reservation: every in-flight Preserve/Swap API request
    /// must be able to resume at its predicted return time.
    fn fits_reservation(&self, candidate: RequestId,
                        admitted: &[RequestId], now: Micros) -> bool {
        if !self.cfg.admission_lookahead || self.pred_return.is_empty() {
            return true;
        }
        let budget = self.kv.capacity().0;
        for (&p_id, &t_ret) in &self.pred_return {
            let p = &self.requests[&p_id];
            let Phase::ApiWait { strategy, .. } = p.phase else {
                continue;
            };
            let resume_need = match strategy {
                HandlingStrategy::Preserve => {
                    // Held context stays allocated; needs the response +
                    // one-token headroom on top.
                    p.context.0
                        + p.predictions[p.segment].response_tokens.0
                        + 1
                }
                HandlingStrategy::Swap => {
                    p.logical_context.0
                        + p.predictions[p.segment].response_tokens.0
                        + 1
                }
                HandlingStrategy::Discard => continue,
            };
            let mut projected = resume_need;
            // Other preserve-held API waiters keep their memory.
            for (&o_id, _) in &self.pred_return {
                if o_id == p_id {
                    continue;
                }
                let o = &self.requests[&o_id];
                if let Phase::ApiWait {
                    strategy: HandlingStrategy::Preserve, ..
                } = o.phase
                {
                    projected += o.context.0;
                }
            }
            for &q_id in self.running.iter().chain(admitted) {
                projected += self.projected_mem(&self.requests[&q_id],
                                                now, t_ret);
            }
            projected +=
                self.projected_mem(&self.requests[&candidate], now, t_ret);
            if projected > budget {
                return false;
            }
        }
        true
    }

    /// Predicted device memory of `q` at future time `t` (token slots),
    /// assuming it is (or stays) admitted from `now`.
    fn projected_mem(&self, q: &Request, now: Micros, t: Micros) -> u64 {
        if t <= now {
            return q.logical_context.0 + 1;
        }
        let t_iter = self.t_iter_ema.max(1.0);
        let mat_us = self
            .cfg
            .cost
            .prefill_time(q.pending_materialize)
            .0 as f64;
        let avail_us = (t - now).0 as f64 - mat_us;
        let decoded = (avail_us / t_iter).floor().max(0.0) as u64;
        let pred = &q.predictions[q.segment.min(q.predictions.len() - 1)];
        let seg_remaining = pred
            .decode_tokens
            .0
            .saturating_sub(q.segment_generated.0);
        if decoded < seg_remaining {
            q.logical_context.0 + 1 + decoded
        } else {
            // Past its (predicted) API boundary by then.
            let ctx_at_api = q.logical_context.0 + seg_remaining;
            match q.handling.get(q.segment) {
                Some(HandlingStrategy::Preserve) => ctx_at_api,
                Some(_) => 0,
                None => 0, // final segment: finished and freed
            }
        }
    }

    /// Charge prefill / recompute / swap-in work for newly admitted
    /// requests. Prefill blocks the engine (vLLM-style prefill priority).
    fn materialize_admitted(&mut self) {
        let ids: Vec<RequestId> = self.running.clone();
        for id in ids {
            let req = self.requests.get_mut(&id).unwrap();
            let mut elapsed = Micros::ZERO;
            if self.swap.contains(id) {
                let (tokens, t_in) =
                    self.swap.swap_in(id, &self.cfg.cost).expect("swapped");
                let t_backend = self.backend.swap_in(id, tokens);
                let stall = t_in.max(t_backend);
                self.metrics.swap_stall_us += stall.0;
                elapsed += stall;
                req.context = tokens;
            }
            if req.pending_materialize > Tokens::ZERO {
                let ctx = req.pending_materialize;
                let total = req.logical_context;
                let prompt = req.spec.prompt.clone();
                let t = self.backend.materialize(id, &prompt, total, ctx);
                elapsed += t;
                if req.segment > 0
                    && req.pending_materialize == req.logical_context
                {
                    // Post-Discard recompute (wasted work accounting).
                    self.metrics.tokens_recomputed += ctx.0;
                }
                req.context = req.logical_context;
                req.pending_materialize = Tokens::ZERO;
            } else {
                req.context = req.logical_context;
            }
            if elapsed > Micros::ZERO {
                self.metrics.materialize_us += elapsed.0;
                self.clock.advance(elapsed);
            }
        }
    }

    /// One decode iteration for the whole running batch.
    fn decode_iteration(&mut self) {
        let slots: Vec<DecodeSlot> = self
            .running
            .iter()
            .map(|id| DecodeSlot {
                id: *id,
                ctx: self.requests[id].context,
            })
            .collect();
        let elapsed = self.backend.decode(&slots);
        let now = self.clock.advance(elapsed);

        // Profiling EMAs for the ranking inputs.
        self.t_iter_ema = 0.9 * self.t_iter_ema + 0.1 * elapsed.0 as f64;
        if slots.len() > 1 {
            let total: u64 = slots.iter().map(|s| s.ctx.0).sum();
            let c_other = slots
                .iter()
                .map(|s| (total - s.ctx.0) as f64)
                .sum::<f64>()
                / slots.len() as f64;
            self.c_other_ema = 0.95 * self.c_other_ema + 0.05 * c_other;
        }

        // Consume the admission-reserved headroom slot: each running
        // request's new token was pre-allocated in admit().
        let ids: Vec<RequestId> = self.running.clone();
        for id in ids {
            let req = self.requests.get_mut(&id).unwrap();
            debug_assert!(self.kv.tokens_of(id) >= req.context + Tokens(1),
                          "admission must have reserved the headroom \
                           ({id}: tokens_of={}, context={})",
                          self.kv.tokens_of(id).0, req.context.0);
            req.context += Tokens(1);
            req.logical_context += Tokens(1);
            req.segment_generated += Tokens(1);
            self.metrics.tokens_decoded += 1;
            if req.first_token_at.is_none() {
                req.first_token_at = Some(now);
                self.metrics.on_first_token(id, now);
            }
        }

        // Route segment boundaries: API encounters and completions.
        let ids: Vec<RequestId> = self.running.clone();
        let mut leaving: Vec<RequestId> = Vec::new();
        for id in ids {
            let req = &self.requests[&id];
            if req.segment_remaining() > Tokens::ZERO {
                continue;
            }
            if req.at_api_segment() {
                self.encounter_api(id, now);
            } else {
                self.finish(id, now);
            }
            leaving.push(id);
        }
        self.running.retain(|id| !leaving.contains(id));

        // Context-cap guard for finite backends (PJRT max_seq).
        if let Some(cap) = self.backend.max_context() {
            let ids: Vec<RequestId> = self.running.clone();
            for id in ids {
                if self.requests[&id].logical_context.0 >= cap {
                    self.finish(id, now);
                    self.running.retain(|r| *r != id);
                }
            }
        }
    }

    /// Lowest-priority *paused* request still holding device memory —
    /// the victim when memory pressure blocks all progress.
    fn pick_preemption_victim(&self) -> Option<RequestId> {
        self.waiting
            .iter()
            .filter(|id| self.kv.tokens_of(**id) > Tokens::ZERO)
            .max_by(|a, b| {
                let ra = &self.requests[*a];
                let rb = &self.requests[*b];
                ra.cached_score
                    .total_cmp(&rb.cached_score)
                    .then(ra.spec.id.cmp(&rb.spec.id))
            })
            .copied()
    }

    /// vLLM recompute-style preemption: drop device state. The victim
    /// stays wherever it is queued (or is re-queued by the caller).
    fn preempt_state(&mut self, id: RequestId, now: Micros) {
        let req = self.requests.get_mut(&id).unwrap();
        req.phase = Phase::Waiting;
        req.pending_materialize = req.logical_context;
        req.context = Tokens::ZERO;
        if self.cfg.requeue_as_new {
            req.queue_key = now;
        }
        req.score_iteration = u64::MAX;
        if self.kv.contains(id) {
            self.kv.free(id).expect("preempt free");
        }
        self.backend.release(id);
        self.metrics.preemptions += 1;
    }

    /// Preempt + ensure the victim is in the waiting queue (idle-path
    /// deadlock breaking; never duplicates entries).
    fn preempt(&mut self, id: RequestId, now: Micros) {
        self.preempt_state(id, now);
        if !self.waiting.contains(&id) {
            self.waiting.push(id);
        }
    }

    /// The request just hit its segment's API call (Algorithm 1 lines
    /// 34-44).
    fn encounter_api(&mut self, id: RequestId, now: Micros) {
        let (seg, duration, pred_duration, own_ctx) = {
            let req = &self.requests[&id];
            let seg = req.segment;
            let call = &req.spec.api_calls[seg];
            (seg,
             call.duration,
             req.predictions[seg].api_duration.unwrap_or(call.duration),
             req.context)
        };
        // INFERCEPT decides here, with live batch context.
        let strategy = match self.cfg.handling {
            HandlingPolicy::MinWasteAtApi => {
                let c_other: u64 = self
                    .running
                    .iter()
                    .filter(|r| **r != id)
                    .map(|r| self.requests[r].context.0)
                    .sum();
                let inp = WasteInputs {
                    ctx: own_ctx,
                    api_duration: pred_duration,
                    c_other: Tokens(c_other),
                };
                select_strategy(&inp, &self.cfg.cost)
            }
            _ => self.requests[&id].handling[seg],
        };
        {
            let req = self.requests.get_mut(&id).unwrap();
            req.handling[seg] = strategy;
            req.starvation_cnt = 0; // §4.4 reset on API encounter
        }

        match strategy {
            HandlingStrategy::Preserve => {
                self.metrics.strategy_counts[0] += 1;
            }
            HandlingStrategy::Discard => {
                self.metrics.strategy_counts[1] += 1;
                if self.kv.contains(id) {
                    self.kv.free(id).expect("discard free");
                }
                self.backend.release(id);
            }
            HandlingStrategy::Swap => {
                self.metrics.strategy_counts[2] += 1;
                let ctx = self.requests[&id].context;
                let t_book =
                    self.swap.swap_out(id, ctx, &self.cfg.cost);
                let t_backend = self.backend.swap_out(id, ctx);
                // Eqn (3): the transfer stalls the whole batch.
                let stall = t_book.unwrap_or(Micros::ZERO).max(t_backend);
                if stall > Micros::ZERO {
                    self.metrics.swap_stall_us += stall.0;
                    self.clock.advance(stall);
                }
                if self.kv.contains(id) {
                    self.kv.free(id).expect("swap free");
                }
            }
        }

        let return_at = self.clock.now() + duration;
        let req = self.requests.get_mut(&id).unwrap();
        req.phase = Phase::ApiWait {
            strategy,
            return_at,
        };
        self.api.begin(id, return_at, strategy);
        self.pred_return.insert(id, now + pred_duration);
    }

    fn finish(&mut self, id: RequestId, now: Micros) {
        let req = self.requests.get_mut(&id).unwrap();
        req.phase = Phase::Finished;
        req.finished_at = Some(now);
        if self.kv.contains(id) {
            self.kv.free(id).expect("finish free");
        }
        self.swap.discard(id);
        self.backend.release(id);
        self.metrics.on_finished(id, now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CostModel, SchedulerKind};
    use crate::core::request::{ApiCallSpec, ApiType};

    fn unit_cfg(scheduler: SchedulerKind, budget: u64) -> SystemConfig {
        SystemConfig {
            scheduler,
            memory_budget: Tokens(budget),
            max_batch: 1,
            block_size: 1,
            starvation_threshold: None,
            cost: CostModel::unit(),
            ..SystemConfig::default()
        }
    }

    fn simple_spec(id: u64, arrival: u64, decode: u64) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: Micros(arrival),
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![],
            final_decode: Tokens(decode),
        }
    }

    fn api_spec(id: u64, pre: u64, api_units: u64, post: u64)
                -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(pre),
                api_type: ApiType::Qa,
                duration: Micros(api_units * 1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(post),
        }
    }

    #[test]
    fn single_request_runs_to_completion() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit(simple_spec(0, 0, 5));
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 5 decode iterations x 1 s
        assert_eq!(r.finished_at, Some(Micros(5_000_000)));
        assert_eq!(e.metrics.completed(), 1);
    }

    #[test]
    fn api_request_full_lifecycle() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Preserve]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 2 decode + 3 API + 1 decode = 6 units
        assert_eq!(r.finished_at, Some(Micros(6_000_000)));
    }

    #[test]
    fn discard_recompute_charges_time() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Discard]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        // 2 decode + 3 API + 2 recompute + 1 decode = 8 units
        assert_eq!(r.finished_at, Some(Micros(8_000_000)));
        assert_eq!(e.metrics.report().tokens_recomputed, 2);
    }

    #[test]
    fn memory_budget_serializes_requests() {
        // Budget of 6 with two requests of 5 tokens each: they cannot
        // decode concurrently even though max_batch would allow it.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 6);
        cfg.max_batch = 4;
        let mut e = Engine::simulated(cfg);
        e.submit(simple_spec(0, 0, 5));
        e.submit(simple_spec(1, 0, 5));
        e.run_until_idle(None);
        let r0 = e.request(RequestId(0)).unwrap();
        let r1 = e.request(RequestId(1)).unwrap();
        assert!(r0.is_finished() && r1.is_finished());
        // r0 finishes at 5 and frees; r1 runs 5..10 (it could start
        // around iteration 2 when 1 slot frees, but needs headroom; the
        // exact point depends on admission; completion must be >= 10
        // only if fully serialized, >= 7 otherwise).
        assert!(r1.finished_at.unwrap() >= Micros(7_000_000));
        assert_eq!(e.metrics.completed(), 2);
    }

    #[test]
    fn arrival_times_respected() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        let trace = Trace::new("t", 1.0, vec![
            simple_spec(0, 0, 2),
            simple_spec(1, 10_000_000, 2),
        ]);
        let report = e.run_trace(&trace);
        assert_eq!(report.completed, 2);
        let r1 = e.request(RequestId(1)).unwrap();
        // Arrives at 10 s, runs 2 iterations.
        assert_eq!(r1.finished_at, Some(Micros(12_000_000)));
        // TTFT for r1 is 1 iteration.
        assert_eq!(r1.first_token_at, Some(Micros(11_000_000)));
    }

    #[test]
    fn oversized_request_dropped_not_livelocked() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 4));
        e.submit(simple_spec(0, 0, 10)); // needs >4 eventually... admitted
        e.submit(RequestSpec {
            prompt_tokens: Tokens(10), // 10 + 1 > 4: dropped at submit
            ..simple_spec(1, 0, 1)
        });
        assert_eq!(e.dropped, vec![RequestId(1)]);
        e.run_until_idle(None);
        // r0 decodes but is preempted/self-preempted when it outgrows the
        // budget; eventually it cannot fit and gets preempted forever —
        // budget 4 caps context growth; our guard: requests whose context
        // exceeds capacity self-preempt and re-enter; they are finished
        // via preemption churn... ensure no hang and r0 completed or
        // dropped.
        let _ = e.request(RequestId(0));
    }

    #[test]
    fn swap_strategy_roundtrips_memory() {
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.cost.swap_per_token_us = 500_000.0; // 0.5 unit per token
        let mut e = Engine::simulated(cfg);
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Swap]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 2 decode + swap-out stall 1 (2 tok x 0.5) + 3 API
        // + swap-in 1 + 1 decode = 8 units
        assert_eq!(r.finished_at, Some(Micros(8_000_000)));
    }

    #[test]
    fn multi_api_segments() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        let spec = RequestSpec {
            id: RequestId(0),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![
                ApiCallSpec {
                    decode_before: Tokens(2),
                    api_type: ApiType::Math,
                    duration: Micros(1_000_000),
                    response_tokens: Tokens(3),
                },
                ApiCallSpec {
                    decode_before: Tokens(1),
                    api_type: ApiType::Math,
                    duration: Micros(2_000_000),
                    response_tokens: Tokens(0),
                },
            ],
            final_decode: Tokens(2),
        };
        e.submit_with_handling(spec, vec![HandlingStrategy::Preserve,
                                          HandlingStrategy::Preserve]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 2 dec + 1 api + 3 resp materialize + 1 dec + 2 api + 2 dec
        //   = 11 units
        assert_eq!(r.finished_at, Some(Micros(11_000_000)));
        // context: 2 + resp 3 + 1 + 2 = 8
        assert_eq!(r.logical_context, Tokens(8));
    }

    #[test]
    fn kv_freed_after_all_complete() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Lamps, 50));
        for i in 0..5 {
            e.submit(api_spec(i, 2, 2, 2));
        }
        e.run_until_idle(None);
        assert_eq!(e.metrics.completed(), 5);
        assert_eq!(e.kv_occupancy(), 0.0);
    }
}
