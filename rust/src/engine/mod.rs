//! The serving engine: iteration-level scheduling loop (paper Algorithm 1)
//! over a pluggable execution [`Backend`] and [`Clock`].
//!
//! One scheduling round:
//! 1. admit arrivals (predict + assign handling strategies), land
//!    finished background swap transfers, drain returned API calls back
//!    into the waiting queue,
//! 2. rank the waiting queue (scheduler policy + starvation promotion),
//! 3. admit requests into the running batch under the memory budget and
//!    the clairvoyant reservation check (see below),
//! 4. **compose** one mixed prefill+decode iteration under the
//!    `ComposeConfig` token budget ([`crate::coordinator::batch`]):
//!    decode slots plus chunked prefill/recompute segments,
//! 5. **execute** the plan on the backend (chunk materializations, swap
//!    restores, one decode pass),
//! 6. **commit** the results: advance materialization cursors, append
//!    decoded tokens, route API-encounters to the P/D/S queues, complete
//!    finished requests.
//!
//! With `ComposeConfig::default()` the pipeline reproduces the legacy
//! serial loop exactly (whole-context prefill, synchronous swap stalls);
//! `prefill_chunk` bounds how long a big recompute may stall co-batched
//! decodes, and `async_swap` turns eqn (3)'s batch stall into background
//! transfers tracked by a [`TransferQueue`].
//!
//! **Reservation admission** (`admission_lookahead`): a candidate is only
//! admitted if every in-flight Preserve/Swap API request can still resume
//! at its *predicted* return time given the candidate's own predicted
//! memory trajectory. This is the mechanism that lets a short request run
//! "inside" another request's API call in the paper's Fig 3 walkthrough
//! (R2 admitted during R1's call because it discards in time; R3 rejected
//! because it would still hold memory when R1 resumes).

pub mod api_executor;
pub mod backend;
pub mod clock;
#[cfg(feature = "pjrt")]
pub mod pjrt_backend;

use std::collections::{BTreeSet, HashMap};

use crate::config::{ApiSourceKind, ComposeConfig, HandlingPolicy,
                    PredictorKind, SchedulerKind, SystemConfig};
use crate::coordinator::batch::{self, ComposeItem, IterationPlan};
use crate::coordinator::handling::{select_strategy, WasteInputs};
use crate::coordinator::ranking::{memory_over_time,
                                  memory_over_time_fresh,
                                  memory_over_time_fresh_prefixed};
use crate::coordinator::scheduler::{make_scheduler, ScheduleContext,
                                    Scheduler};
use crate::core::request::{HandlingStrategy, Phase, Request, RequestSpec,
                           SegmentPrediction};
use crate::core::slab::SlabMap;
use crate::core::types::{Micros, RequestId, Tokens};
use crate::kv::{prefix, BlockManager, SwapSpace, TransferDir,
                TransferQueue};
use crate::metrics::{MetricsCollector, RunReport, TimelinePoint};
use crate::predictor::duration::DurationModel;
use crate::predictor::oracle::{NoisyOraclePredictor, OraclePredictor};
use crate::predictor::Predictor;
use crate::workload::Trace;

use api_executor::ApiExecutor;
use backend::{Backend, SimBackend};
use clock::Clock;

/// Safety valve against scheduling livelock in buggy configs.
const MAX_ITERATIONS: u64 = 200_000_000;

/// A request pulled off a replica by [`Engine::withdraw_waiting`] for
/// the admission re-queue: everything the adopting sibling needs to
/// resume it **without re-predicting** — the spec, the exact
/// predictions and handling strategies it was admitted with (a second
/// predictor pass would be real inference under PJRT, and a noisy
/// predictor would silently change the handling choice mid-move), and
/// its accrued §4.4 starvation state.
#[derive(Debug, Clone)]
pub struct WithdrawnRequest {
    pub spec: RequestSpec,
    pub predictions: Vec<SegmentPrediction>,
    pub handling: Vec<HandlingStrategy>,
    pub starvation_cnt: u32,
    pub starving: bool,
}

/// Observational per-request lifecycle event, journaled by the engine
/// when a driver armed the journal ([`Engine::enable_events`]) and
/// drained through [`Engine::drain_events`]. The serving frontend maps
/// these onto the typed session event stream
/// (`server::RequestEvent`); simulation runs leave the journal off and
/// pay nothing. Emission never feeds back into scheduling — an engine
/// with events on behaves byte-identically to one without.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineEvent {
    /// The request's first token was decoded at `at`.
    FirstToken { id: RequestId, at: Micros },
    /// `chunk` further tokens were decoded (consecutive per-iteration
    /// singles are coalesced between drains).
    Tokens { id: RequestId, chunk: u64 },
    /// The request hit API call `index` and was parked under
    /// `strategy`; `predicted` is the scheduler's duration estimate
    /// (what the handling choice and the reservation lookahead used).
    /// `external` marks a call the client must resolve via
    /// [`Engine::complete_api_call`].
    ApiStarted {
        id: RequestId,
        index: usize,
        strategy: HandlingStrategy,
        predicted: Micros,
        external: bool,
    },
    /// API call `index` returned after `actual` — the true sampled
    /// duration for simulated calls, the measured park time for
    /// externally-resolved ones.
    ApiCompleted {
        id: RequestId,
        index: usize,
        actual: Micros,
    },
    /// The request finished (served to completion) at `at`.
    Finished { id: RequestId, at: Micros },
    /// The request was dropped unserved.
    Dropped { id: RequestId, reason: String },
}

pub struct Engine {
    pub cfg: SystemConfig,
    scheduler: Box<dyn Scheduler>,
    predictor: Box<dyn Predictor>,
    backend: Box<dyn Backend>,
    clock: Clock,
    kv: BlockManager,
    swap: SwapSpace,
    /// In-flight background swap transfers (`ComposeConfig::async_swap`).
    transfers: TransferQueue,
    api: ApiExecutor,

    requests: SlabMap<RequestId, Request>,
    /// Ids of unfinished requests (submitted, not yet finished/dropped).
    /// `requests` keeps finished entries for result queries, so load
    /// probes iterate this set instead: O(live) per probe, and the
    /// BTreeSet's sorted order keeps f64 summation deterministic across
    /// runs (HashMap order is per-process random).
    live: BTreeSet<RequestId>,
    waiting: Vec<RequestId>,
    running: Vec<RequestId>,
    /// Arrival-sorted, not-yet-submitted specs (drained by time).
    pending: std::collections::VecDeque<RequestSpec>,
    /// Predicted API return times for in-flight calls (the scheduler's
    /// knowledge; true returns live in the executor heap).
    pred_return: HashMap<RequestId, Micros>,

    pub metrics: MetricsCollector,
    iteration: u64,
    /// EMA of decode iteration duration (t_iter estimate for ranking and
    /// the lookahead projection).
    t_iter_ema: f64,
    /// EMA of co-batched context (the C_other estimate, §3.2.1).
    c_other_ema: f64,
    /// Record per-iteration timeline points (Fig 2); off by default for
    /// large sweeps.
    pub record_timeline: bool,
    /// Requests dropped because they can never fit the memory budget.
    pub dropped: Vec<RequestId>,
    /// External wake-up hint folded into the idle-jump event calculation.
    /// A [`ReplicaSet`](crate::cluster::ReplicaSet) points this at its
    /// shared queue's next arrival so a replica's idle jump (and its
    /// no-event preemption fallback) behave exactly like the
    /// single-engine path, where that arrival would sit in the engine's
    /// own pending queue. `None` (the default) changes nothing.
    external_event: Option<Micros>,
    /// Lifecycle event journal (see [`EngineEvent`]); populated only
    /// when a driver armed it via [`Engine::enable_events`].
    events: Vec<EngineEvent>,
    events_on: bool,
    /// Runtime invariant auditor ([`crate::audit`]), armed per
    /// `cfg.audit`. Observe-only: an audited engine schedules
    /// byte-identically to an unaudited one, and a tripped invariant
    /// is fatal (it means a scheduler/KV bug, not a bad request).
    auditor: Option<Box<crate::audit::EngineAuditor>>,
    /// Epoch counter for the placement-score cache: bumped by every
    /// mutation that can change the load aggregate (`touch_load`). A
    /// cached score is valid only while its recorded epoch matches.
    load_epoch: u64,
    /// Memoized `(epoch, load)` for `load_memory_over_time_with` under
    /// the default rank inputs. Interior-mutable so probes stay `&self`
    /// (the probe-purity lint guards that contract).
    load_cache: std::cell::Cell<Option<(u64, f64)>>,
    /// Per-request content-chain memo: the arrival-path chain (seeded
    /// by placement via [`Engine::seed_chain`]) grows in place via
    /// [`prefix::extend_content_chain`] instead of being rehashed at
    /// admission, purge, and registration. Entries die with the
    /// request (terminal free / withdraw / failed submit).
    chain_memo: HashMap<RequestId, Vec<prefix::BlockHash>>,
    /// The API-duration seam (`cfg.api_pred`): every duration estimate
    /// the scheduler consumes is routed through
    /// [`DurationModel::revise`] (a pure read — placement probes use it
    /// too), and observed outcomes update it in `route_api_return`, the
    /// single mutation point. Static mode is the stateless identity.
    duration_model: DurationModel,
    /// Record simulated predicted-vs-actual API outcomes in the metrics
    /// histogram. True whenever the configured predictor is not the
    /// exact oracle (whose gap is identically zero — skipping it keeps
    /// oracle-run report bytes unchanged). External outcomes are always
    /// recorded regardless.
    record_sim_outcomes: bool,
    /// Decommission marker ([`Engine::set_draining`]): a draining
    /// replica finishes its live work but a fleet driver stops routing
    /// new arrivals to it. Purely observational engine-side — nothing
    /// in the scheduler reads it, so a draining engine steps
    /// byte-identically to a live one.
    draining: bool,
}

impl Engine {
    pub fn new(cfg: SystemConfig, backend: Box<dyn Backend>,
               predictor: Box<dyn Predictor>, clock: Clock) -> Engine {
        let mut kv = if cfg.prefix_cache.enabled {
            BlockManager::with_prefix_cache(cfg.memory_budget,
                                            cfg.block_size,
                                            cfg.prefix_cache.cache_blocks)
        } else {
            BlockManager::new(cfg.memory_budget, cfg.block_size)
        };
        if cfg.shared_prefix && cfg.prefix_cache.enabled
            && cfg.replicas > 1
        {
            // Journal resident-set deltas for the fleet's shared prefix
            // index (the ReplicaSet drains them after every step).
            // Purely observational: nothing engine-side reads it back,
            // which is what keeps `--shared-prefix` behavior-identical
            // for every replica in isolation.
            kv.enable_prefix_journal();
        }
        let t_iter0 = cfg.cost.decode_iter_time(Tokens::ZERO).0 as f64;
        let c_other0 = cfg.memory_budget.0 as f64 / 2.0;
        Engine {
            scheduler: make_scheduler(cfg.scheduler),
            predictor,
            backend,
            clock,
            kv,
            swap: SwapSpace::unbounded(),
            transfers: TransferQueue::new(),
            api: ApiExecutor::new(),
            requests: SlabMap::new(),
            live: BTreeSet::new(),
            waiting: Vec::new(),
            running: Vec::new(),
            pending: std::collections::VecDeque::new(),
            pred_return: HashMap::new(),
            metrics: MetricsCollector::new(),
            iteration: 0,
            t_iter_ema: t_iter0,
            c_other_ema: c_other0,
            record_timeline: false,
            dropped: Vec::new(),
            external_event: None,
            events: Vec::new(),
            events_on: false,
            auditor: cfg
                .audit
                .enabled()
                .then(|| Box::new(crate::audit::EngineAuditor::new())),
            load_epoch: 0,
            load_cache: std::cell::Cell::new(None),
            chain_memo: HashMap::new(),
            duration_model: DurationModel::new(cfg.api_pred),
            record_sim_outcomes: !matches!(cfg.predictor,
                                           PredictorKind::Oracle),
            draining: false,
            cfg,
        }
    }

    /// Simulated engine: analytic backend + virtual clock + the predictor
    /// named in the config.
    pub fn simulated(cfg: SystemConfig) -> Engine {
        let backend = Box::new(SimBackend::new(cfg.cost));
        let predictor: Box<dyn Predictor> = match cfg.predictor {
            PredictorKind::Oracle => Box::new(OraclePredictor),
            PredictorKind::NoisyOracle { error_pct } => {
                Box::new(NoisyOraclePredictor::new(error_pct, cfg.seed))
            }
            PredictorKind::Pjrt => {
                // lamps-lint: allow(panic) config error at construction — no result channel exists
                panic!("PJRT predictor requires Engine::new with a \
                        PjrtPredictor (see runtime::)")
            }
        };
        Engine::new(cfg, backend, predictor, Clock::virtual_clock())
    }

    pub fn now(&self) -> Micros {
        self.clock.now()
    }

    /// Outcomes the duration seam has observed (0 in static mode) —
    /// lets tests pin that probes and rescue/adopt moves never update
    /// the estimators.
    pub fn api_pred_observations(&self) -> u64 {
        self.duration_model.observations()
    }

    pub fn request(&self, id: RequestId) -> Option<&Request> {
        self.requests.get(&id)
    }

    pub fn kv_occupancy(&self) -> f64 {
        self.kv.occupancy()
    }

    // ------------------------------------------------------------------
    // Replica-addressable stepping interface (cluster::ReplicaSet)
    // ------------------------------------------------------------------

    /// Earliest future event this engine can jump to when idle (next
    /// arrival, API return, transfer landing, external hint).
    pub fn next_event_time(&self) -> Option<Micros> {
        self.next_event()
    }

    /// Point the idle-jump calculation at an external future event (the
    /// replica set's next shared-queue arrival). Pass `None` to clear.
    pub fn set_external_event(&mut self, t: Option<Micros>) {
        self.external_event = t;
    }

    /// Jump (virtual clock) or sleep (wall clock) to `t`; into the past
    /// it is a no-op. Lets a multi-replica driver keep idle replicas'
    /// clocks in lockstep with the fleet.
    pub fn advance_clock_to(&mut self, t: Micros) {
        self.clock.wait_until(t);
        self.touch_load();
    }

    /// Is there anything left for this engine to do — now or at a future
    /// event it knows about? (External hints do not count: an engine
    /// with no work of its own is idle from the fleet's perspective.)
    pub fn has_live_work(&self) -> bool {
        !self.running.is_empty()
            || !self.waiting.is_empty()
            || !self.pending.is_empty()
            || self.api.in_flight() > 0
            || !self.transfers.is_empty()
    }

    /// Does this engine have work a [`Engine::step`] could act on
    /// immediately — a batch to run or queued requests to admit — as
    /// opposed to only future events (API returns, transfers) it would
    /// wall-clock-sleep for? The serving frontend skips stepping
    /// engines without it so idle replicas don't serialize sleeps.
    pub fn has_runnable_work(&self) -> bool {
        !self.running.is_empty() || !self.waiting.is_empty()
    }

    /// `run_until_idle`'s epilogue for external drivers that call
    /// [`Engine::step`] directly: sync the KV-layer counters and stamp
    /// the end time. Idempotent.
    pub fn finish_run(&mut self) {
        self.sync_prefix_metrics();
        self.metrics.end_time = self.now();
    }

    // ------------------------------------------------------------------
    // Invariant-auditor taps (crate::audit) — read-only state views
    // ------------------------------------------------------------------

    pub(crate) fn audit_kv(&self) -> &BlockManager {
        &self.kv
    }

    pub(crate) fn audit_swap(&self) -> &SwapSpace {
        &self.swap
    }

    /// `(arrival, id)` of every not-yet-submitted pending spec, in
    /// queue order.
    pub(crate) fn audit_pending(
        &self) -> impl Iterator<Item = (Micros, RequestId)> + '_ {
        self.pending.iter().map(|s| (s.arrival, s.id))
    }

    pub(crate) fn audit_waiting(&self) -> &[RequestId] {
        &self.waiting
    }

    pub(crate) fn audit_running(&self) -> &[RequestId] {
        &self.running
    }

    pub(crate) fn audit_live(&self) -> &BTreeSet<RequestId> {
        &self.live
    }

    /// Every id in the request table (finished entries included — the
    /// engine keeps them for result queries).
    pub(crate) fn audit_request_ids(
        &self) -> impl Iterator<Item = RequestId> + '_ {
        self.requests.keys().copied()
    }

    // ------------------------------------------------------------------
    // Placement load signals (cluster placement policies)
    // ------------------------------------------------------------------

    /// Unfinished requests this engine is responsible for, including
    /// enqueued-but-not-yet-submitted arrivals (the least-loaded
    /// placement signal).
    pub fn live_load(&self) -> usize {
        self.pending.len() + self.live.len()
    }

    /// Total outstanding memory-over-time (the LAMPS rank integral,
    /// §4.3) across this engine's live requests — the signal the
    /// memory-over-time placement policy minimizes, so the integral
    /// steers cross-replica placement the same way it steers ordering.
    /// Enqueued-but-unsubmitted arrivals count too, so simultaneous
    /// arrivals dispatched back-to-back see each other's load; they are
    /// scored with a stateless complete-information oracle rather than
    /// the engine's own predictor, keeping this probe side-effect-free
    /// (a noisy predictor's RNG is never advanced, and a PJRT predictor
    /// never runs inference, just because a replica was *considered*
    /// for placement).
    pub fn load_memory_over_time(&self) -> f64 {
        self.load_memory_over_time_with(
            &self.schedule_context().rank_inputs())
    }

    /// [`Engine::load_memory_over_time`] with the epoch cache bypassed:
    /// always the from-scratch O(live + pending) recompute. Public seam
    /// for the equivalence suite and the `micro_placement` A/B path;
    /// placement itself never calls this.
    pub fn load_memory_over_time_uncached(&self) -> f64 {
        self.recompute_load_with(&self.schedule_context().rank_inputs())
    }

    /// [`Engine::load_memory_over_time`] against already-built rank
    /// inputs, so a probe that needs the inputs for its own terms
    /// ([`Engine::placement_score_prefixed`]) builds them once.
    ///
    /// Epoch-cached: rank inputs and every summed term are pure
    /// functions of engine state, every mutation of that state bumps
    /// `load_epoch` (see [`Engine::touch_load`]), so within one epoch
    /// the recompute is bitwise-constant and the memo returns it in
    /// O(1). Debug and audited builds shadow-recompute on every hit and
    /// abort on the first divergence, pinning cached placement
    /// byte-identical to the stateless oracle.
    fn load_memory_over_time_with(
        &self, inputs: &crate::coordinator::ranking::RankInputs) -> f64 {
        if !self.cfg.placement_cache {
            return self.recompute_load_with(inputs);
        }
        if let Some((epoch, value)) = self.load_cache.get() {
            if epoch == self.load_epoch {
                if cfg!(debug_assertions) || self.auditor.is_some() {
                    let fresh = self.recompute_load_with(inputs);
                    if value.to_bits() != fresh.to_bits() {
                        // lamps-lint: allow(panic) audit invariant: a stale cache hit is a scheduler bug, not a bad request
                        panic!("placement-score cache diverged from \
                                recompute at epoch {}: cached {value} \
                                vs fresh {fresh} — a mutation missed \
                                touch_load", self.load_epoch);
                    }
                }
                return value;
            }
        }
        let fresh = self.recompute_load_with(inputs);
        self.load_cache.set(Some((self.load_epoch, fresh)));
        fresh
    }

    /// The stateless from-scratch load aggregate (PR 3 oracle): the
    /// ground truth the epoch cache memoizes.
    fn recompute_load_with(
        &self, inputs: &crate::coordinator::ranking::RankInputs) -> f64 {
        let cost = self.cfg.cost;
        // The sorted `live` index makes this O(live requests) — the
        // engine keeps finished entries around for result queries — and
        // its deterministic order keeps the f64 sum (and therefore
        // placement tie behavior) reproducible across runs.
        let mut total: f64 = self
            .live
            .iter()
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            .map(|id| memory_over_time(&self.requests[id], &cost,
                                       inputs))
            .sum();
        let mut oracle = OraclePredictor;
        for spec in &self.pending {
            // The stateless oracle (never the configured predictor, so
            // a probe can't advance a noisy predictor's RNG), revised
            // through the duration seam — `revise` is a pure read, so
            // probe purity holds in learned mode too.
            let predictions = oracle.predict(spec);
            let predictions = self.revise_predictions(spec, predictions);
            let handling = self.assign_handling(spec, &predictions);
            total += memory_over_time_fresh(spec, &predictions,
                                            &handling, &cost, inputs);
        }
        total
    }

    /// Prefix-affinity placement probe: this replica's outstanding
    /// memory-over-time load plus the arrival's own fresh rank integral
    /// *including its prefill leg*, with `cached` leading tokens of the
    /// prompt already resident in this replica's prefix cache (per the
    /// fleet's shared index) discounted from that leg. Like
    /// [`Engine::load_memory_over_time`], the candidate is scored with
    /// the stateless complete-information oracle so considering a
    /// replica never perturbs it.
    pub fn placement_score_prefixed(&self, spec: &RequestSpec,
                                    cached: Tokens) -> f64 {
        let inputs = self.schedule_context().rank_inputs();
        let mut oracle = OraclePredictor;
        let predictions = oracle.predict(spec);
        let predictions = self.revise_predictions(spec, predictions);
        let handling = self.assign_handling(spec, &predictions);
        self.load_memory_over_time_with(&inputs)
            + memory_over_time_fresh_prefixed(spec, &predictions,
                                              &handling, &self.cfg.cost,
                                              &inputs, cached)
    }

    /// Note a state change that can move the load aggregate or the rank
    /// inputs it is computed under. Called by every mutating entry
    /// point; the next probe recomputes once and re-memoizes. Missing a
    /// call site is caught loudly: debug/audited probes shadow-recompute
    /// every cache hit and abort on divergence.
    fn touch_load(&mut self) {
        self.load_epoch = self.load_epoch.wrapping_add(1);
    }

    /// Force the next placement probe to recompute from scratch — the
    /// `micro_placement` bench's A/B seam (simulates the invalidation a
    /// real mutation would cause without perturbing state).
    pub fn invalidate_placement_cache(&mut self) {
        self.touch_load();
    }

    /// Seed the per-request content-chain memo with a chain computed on
    /// the arrival path (placement already hashed the prompt once —
    /// [`crate::cluster::ArrivalScratch`]). Admission, registration, and
    /// the terminal purge then extend this chain in place instead of
    /// rehashing from position zero. Ignored if the chain was computed
    /// at a different block size, or if a longer memo already exists.
    pub fn seed_chain(&mut self, id: RequestId, block_size: u64,
                      chain: Vec<prefix::BlockHash>) {
        if block_size != self.cfg.block_size.max(1) {
            return;
        }
        let entry = self.chain_memo.entry(id).or_default();
        if entry.len() < chain.len() {
            *entry = chain;
        }
    }

    /// The first `floor(upto / block_size)` chain hashes of `spec`,
    /// extending the memoized chain in place (one-shot hashing: bytes
    /// already covered by the memo are never rehashed). An associated
    /// fn over the memo field so callers can hold `&mut self.kv`
    /// concurrently.
    fn chain_upto<'a>(
        memo: &'a mut HashMap<RequestId, Vec<prefix::BlockHash>>,
        spec: &RequestSpec, block_size: u64, upto: Tokens)
        -> &'a [prefix::BlockHash] {
        let blocks = (upto.0 / block_size.max(1)) as usize;
        let entry = memo.entry(spec.id).or_default();
        if entry.len() < blocks {
            prefix::extend_content_chain(spec, block_size.max(1), entry,
                                         upto);
        }
        // lamps-lint: allow(panic) extend_content_chain just grew the memo to >= blocks entries
        &entry[..blocks]
    }

    // ------------------------------------------------------------------
    // Fleet shared-prefix observation (cluster::SharedPrefixIndex)
    // ------------------------------------------------------------------

    /// Take the prefix-cache resident-set deltas journaled since the
    /// last drain (empty unless `--shared-prefix` armed the journal).
    /// The ReplicaSet feeds these to its fleet-level index observer.
    pub fn drain_prefix_deltas(&mut self) -> Vec<crate::kv::PrefixDelta> {
        self.kv.drain_prefix_deltas()
    }

    /// Every hash resident in this replica's prefix cache — the ground
    /// truth the fleet index must stay a subset of (test invariant).
    pub fn resident_prefix_hashes(&self) -> Vec<prefix::BlockHash> {
        self.kv.resident_prefix_hashes()
    }

    /// Admission headroom for a published load digest: free KV tokens
    /// minus what this replica already owes its accepted-but-unadmitted
    /// backlog ([`Engine::owed_admission_tokens`]). A bounded-staleness
    /// rescue filters siblings on this instead of probing them live.
    pub fn digest_headroom(&self) -> Tokens {
        Tokens(self.kv
            .free_tokens()
            .0
            .saturating_sub(self.owed_admission_tokens().0))
    }

    /// Consecutive leading blocks of `chain` resident in this replica's
    /// prefix cache, in tokens — what a prefix-affinity steer actually
    /// finds on arrival, measured against the (possibly stale)
    /// fleet-index credit that steered it here.
    pub fn cached_lead_tokens(&self, chain: &[prefix::BlockHash]) -> u64 {
        self.kv.cached_lead_tokens(chain)
    }

    /// Warm-up pre-seeding from a sibling's resident hash set: adopt up
    /// to `max_blocks` of `hashes` as zero-ref cached blocks (free-list
    /// only, never evicting live work). Returns blocks seeded. See
    /// [`BlockManager::preseed_cached`].
    pub fn preseed_prefix_cache(&mut self, hashes: &[prefix::BlockHash],
                                max_blocks: u64) -> u64 {
        self.kv.preseed_cached(hashes, max_blocks)
    }

    // ------------------------------------------------------------------
    // Elastic-fleet decommission markers (cluster::net autoscale)
    // ------------------------------------------------------------------

    /// Mark (or unmark) this replica as draining for decommission. The
    /// marker is observational: the engine itself keeps stepping its
    /// live work byte-identically; the fleet driver is what stops
    /// routing arrivals and rescues here.
    pub fn set_draining(&mut self, draining: bool) {
        self.draining = draining;
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// A draining replica whose live work has fully finished — safe to
    /// park (decommission) without dropping anything.
    pub fn drain_complete(&self) -> bool {
        self.draining && !self.has_live_work()
    }

    /// Downcast access to backend-specific state (e.g. PJRT generated
    /// tokens).
    pub fn backend_any(&self) -> Option<&dyn std::any::Any> {
        self.backend.as_any()
    }

    // ------------------------------------------------------------------
    // Lifecycle event journal (server session streams)
    // ------------------------------------------------------------------

    /// Arm the [`EngineEvent`] journal. Purely observational: nothing
    /// engine-side reads it back, so an armed engine schedules
    /// byte-identically to an unarmed one. The driver that armed it
    /// must drain it ([`Engine::drain_events`]) or it grows without
    /// bound.
    pub fn enable_events(&mut self) {
        self.events_on = true;
    }

    /// Take every event journaled since the last drain (always empty
    /// unless [`Engine::enable_events`] armed the journal).
    pub fn drain_events(&mut self) -> Vec<EngineEvent> {
        if self.events.is_empty() {
            return Vec::new();
        }
        std::mem::take(&mut self.events)
    }

    /// Allocation-free drain: swap the journal into `out` (cleared
    /// first), so a pump that drains every loop iteration reuses one
    /// buffer pair forever instead of allocating a fresh `Vec` per
    /// drain ([`Engine::drain_events`] allocates; this does not).
    pub fn drain_events_into(&mut self, out: &mut Vec<EngineEvent>) {
        out.clear();
        std::mem::swap(&mut self.events, out);
    }

    fn push_event(&mut self, ev: EngineEvent) {
        // The auditor sees every event *before* the journal's arming
        // gate, so lifecycle causality is checked even in plain
        // simulation runs that never drain events.
        if let Some(auditor) = self.auditor.as_mut() {
            if let Err(e) = auditor.observe_event(&ev) {
                // lamps-lint: allow(panic) a tripped audit invariant is a scheduler bug — fail loudly
                panic!("{e}");
            }
        }
        if !self.events_on {
            return;
        }
        // Coalesce consecutive per-iteration token singles for the same
        // request so a long decode segment is one frame per drain, not
        // one per token.
        if let EngineEvent::Tokens { id, chunk } = ev {
            if let Some(EngineEvent::Tokens { id: last, chunk: c }) =
                self.events.last_mut()
            {
                if *last == id {
                    *c += chunk;
                    return;
                }
            }
        }
        self.events.push(ev);
    }

    // ------------------------------------------------------------------
    // Externally-resolved API calls (`--api-source external`)
    // ------------------------------------------------------------------

    /// Resolve an externally-held API call: the client ran the tool
    /// and posted its result (a `tool_result` wire frame, routed here
    /// by the serving frontend). Validates that the request is parked
    /// on exactly call `index`, overrides the call's response length
    /// with what the tool actually returned, and routes the return
    /// like any simulated one — the request re-enters the waiting
    /// queue and its next admission materializes the response tokens.
    /// The predicted-vs-actual duration error is recorded in the
    /// metrics (`api_pred_err_hist`).
    pub fn complete_api_call(&mut self, id: RequestId, index: usize,
                             response_tokens: Tokens)
                             -> anyhow::Result<()> {
        let now = self.now();
        let Some(req) = self.requests.get_mut(&id) else {
            anyhow::bail!("unknown request {id}");
        };
        let Phase::ApiWait { return_at, .. } = req.phase else {
            anyhow::bail!("{id} is not waiting on an API call");
        };
        if return_at.is_some() {
            anyhow::bail!("{id}'s API call is simulated, not externally \
                           resolvable");
        }
        if req.segment != index {
            anyhow::bail!("{id} is parked on call {}, not {index}",
                          req.segment);
        }
        if !self.api.resolve_external(id) {
            anyhow::bail!("{id} has no pending external call");
        }
        // lamps-lint: allow(panic) segment index is bounded by the spec's call list
        req.spec.api_calls[index].response_tokens = response_tokens;
        self.touch_load();
        self.route_api_return(id, now);
        Ok(())
    }

    /// Every request currently parked on an externally-held API call
    /// (the serving frontend's timeout-sweep scan list).
    pub fn external_api_ids(&self) -> Vec<RequestId> {
        self.api.external_ids()
    }

    /// Abort an externally-held API call whose client will never
    /// answer (the serving frontend's disconnect/timeout backstop): a
    /// parked external call emits no events, so a vanished client is
    /// undetectable by failed sends, and without this the request
    /// would hold its strategy's state — Preserve pins KV blocks —
    /// forever. The request is dropped terminally, every holding
    /// freed, and a `Dropped` event journaled with `reason`. Returns
    /// false (and does nothing) unless `id` is parked on an external
    /// call.
    pub fn abort_external_call(&mut self, id: RequestId,
                               reason: String) -> bool {
        let Some(req) = self.requests.get(&id) else {
            return false;
        };
        let Phase::ApiWait { strategy, return_at: None } = req.phase
        else {
            return false;
        };
        if !self.api.resolve_external(id) {
            return false;
        }
        self.api.note_returned(strategy);
        self.pred_return.remove(&id);
        // Same teardown order as the mid-run drop in `admit`.
        self.transfers.cancel(id);
        self.free_terminal(id);
        self.swap.discard(id);
        self.backend.release(id);
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = self.requests.get_mut(&id).expect("checked above");
        req.phase = Phase::Finished;
        req.api_started_at = None;
        self.live.remove(&id);
        self.dropped.push(id);
        self.touch_load();
        self.push_event(EngineEvent::Dropped { id, reason });
        true
    }

    // ------------------------------------------------------------------
    // Submission
    // ------------------------------------------------------------------

    /// Queue a spec for arrival-time-driven submission.
    pub fn enqueue(&mut self, spec: RequestSpec) {
        self.pending.push_back(spec);
        self.touch_load();
    }

    /// Submit immediately with predicted handling per the config policy.
    pub fn submit(&mut self, spec: RequestSpec) {
        let predictions = self.predictor.predict(&spec);
        let predictions = self.revise_predictions(&spec, predictions);
        let handling = self.assign_handling(&spec, &predictions);
        self.submit_prepared(spec, predictions, handling);
    }

    /// Submit with explicit per-call strategies (tests / Fig 3).
    pub fn submit_with_handling(&mut self, spec: RequestSpec,
                                handling: Vec<HandlingStrategy>) {
        let predictions = self.predictor.predict(&spec);
        let predictions = self.revise_predictions(&spec, predictions);
        self.submit_prepared(spec, predictions, handling);
    }

    /// Route raw predictor output through the duration seam: each
    /// segment's API-duration estimate is revised against the current
    /// per-class estimator. Pure (`&self`) — the placement probes call
    /// it on candidate specs — and the identity in static mode, so the
    /// off path stays byte-identical. Note the deliberate asymmetry
    /// with [`Engine::submit_prepared`]: a rescued/adopted request
    /// crosses replicas with its predictions carried as-is (no second
    /// predict, no revision).
    fn revise_predictions(&self, spec: &RequestSpec,
                          mut predictions: Vec<SegmentPrediction>)
                          -> Vec<SegmentPrediction> {
        if !self.duration_model.is_learned() {
            return predictions;
        }
        for (seg, call) in spec.api_calls.iter().enumerate() {
            let Some(pred) = predictions.get_mut(seg) else { break };
            if let Some(raw) = pred.api_duration {
                pred.api_duration =
                    Some(self.duration_model.revise(call.api_type, raw));
            }
        }
        predictions
    }

    fn submit_prepared(&mut self, spec: RequestSpec,
                       predictions: Vec<crate::core::request::SegmentPrediction>,
                       handling: Vec<HandlingStrategy>) {
        let id = spec.id;
        let arrival = spec.arrival;
        self.metrics.on_arrival(id, arrival);
        self.touch_load();
        let req = Request::new(spec, predictions, handling);
        if req.admission_memory() > self.kv.capacity() {
            // Can never fit; fail fast instead of livelocking.
            self.dropped.push(id);
            self.chain_memo.remove(&id);
            self.push_event(EngineEvent::Dropped {
                id,
                reason: format!(
                    "admission memory {} tokens exceeds replica KV \
                     capacity {}",
                    req.admission_memory().0,
                    self.kv.capacity().0),
            });
            return;
        }
        self.requests.insert(id, req);
        self.live.insert(id);
        self.waiting.push(id);
    }

    // ------------------------------------------------------------------
    // Placement-aware admission re-queue (cluster::ReplicaSet)
    // ------------------------------------------------------------------

    // (See [`WithdrawnRequest`] for what crosses a re-queue move.)

    /// Never ran and holds no replica-local state (KV blocks, parked
    /// swap context, in-flight transfer) — the shared eligibility gate
    /// of [`Engine::stranded_waiting`] and [`Engine::withdraw_waiting`]:
    /// only such a request may leave this replica.
    fn relocatable(&self, id: RequestId) -> bool {
        let Some(req) = self.requests.get(&id) else {
            return false;
        };
        !req.was_scheduled
            && !self.kv.contains(id)
            && !self.swap.contains(id)
            && !self.transfers.contains(id)
    }

    /// Waiting requests that have never run, hold no device/swap/
    /// transfer state, and cannot currently fit this replica's memory —
    /// the candidates a fleet may re-queue to a sibling with free KV
    /// instead of leaving them to wait out this replica's pressure.
    pub fn stranded_waiting(&self) -> Vec<RequestId> {
        self.waiting
            .iter()
            .copied()
            .filter(|id| self.relocatable(*id) && !self.fits_memory(*id))
            .collect()
    }

    /// Could a not-yet-submitted spec be admitted here right now
    /// (context plus one headroom token)? The sibling-side check of the
    /// admission re-queue.
    pub fn can_fit_fresh(&self, spec: &RequestSpec) -> bool {
        self.can_fit_fresh_with(spec, Tokens::ZERO)
    }

    /// [`Engine::can_fit_fresh`] with `reserved` further tokens already
    /// promised to other not-yet-admitted requests (a rescue sweep's
    /// earlier adoptees, which hold no KV yet and are invisible to the
    /// block manager) — so one sweep cannot overcommit a sibling.
    pub fn can_fit_fresh_with(&self, spec: &RequestSpec,
                              reserved: Tokens) -> bool {
        self.kv
            .can_fit(spec.id, spec.prompt_tokens + Tokens(1) + reserved)
    }

    /// Would a fresh spec pass submit's fail-fast capacity check (its
    /// admission memory fits an *empty* replica)? Steering stats skip
    /// specs that submission would immediately drop.
    pub fn fits_capacity(&self, spec: &RequestSpec) -> bool {
        spec.prompt_tokens + Tokens(1) <= self.kv.capacity()
    }

    /// Tokens this replica already owes to requests it has accepted
    /// but not yet given KV (queued arrivals and zero-KV waiters),
    /// block-rounded the way admission will allocate them. The rescue
    /// sweep seeds its sibling reservations with this, so successive
    /// sweeps cannot overcommit a sibling whose earlier adoptees (or
    /// own backlog) simply have not been admitted yet.
    pub fn owed_admission_tokens(&self) -> Tokens {
        let bs = self.cfg.block_size.max(1);
        let round = |t: u64| t.div_ceil(bs) * bs;
        let waiting: u64 = self
            .waiting
            .iter()
            .filter(|id| !self.kv.contains(**id))
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            .map(|id| round(self.requests[id].admission_memory().0))
            .sum();
        let pending: u64 = self
            .pending
            .iter()
            .map(|s| round(s.prompt_tokens.0 + 1))
            .sum();
        Tokens(waiting + pending)
    }

    /// Withdraw a never-scheduled waiting request from this engine
    /// entirely (queue, request table, lifecycle record) so the fleet
    /// can re-queue it on a sibling. Refuses (`None`) if the request
    /// already ran or holds any device, swap, or transfer state — that
    /// state is replica-local and must stay so.
    pub fn withdraw_waiting(&mut self, id: RequestId)
                            -> Option<WithdrawnRequest> {
        let pos = self.waiting.iter().position(|w| *w == id)?;
        if !self.relocatable(id) {
            return None;
        }
        self.waiting.remove(pos);
        self.live.remove(&id);
        self.pred_return.remove(&id);
        self.chain_memo.remove(&id);
        self.touch_load();
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = self.requests.remove(&id).expect("checked above");
        self.metrics.forget(id);
        Some(WithdrawnRequest {
            spec: req.spec,
            predictions: req.predictions,
            handling: req.handling,
            starvation_cnt: req.starvation_cnt,
            starving: req.starving,
        })
    }

    /// Re-home a request rescued from a sibling (placement-aware
    /// admission re-queue): submit it immediately with the predictions
    /// and handling it already carried, restoring the starvation state
    /// it accrued on the rejecting owner — a §4.4 promotion (or
    /// progress toward one) survives the move instead of the transfer
    /// silently demoting it, and the sibling's predictor never re-runs.
    pub fn adopt(&mut self, w: WithdrawnRequest) {
        let id = w.spec.id;
        self.submit_prepared(w.spec, w.predictions, w.handling);
        if let Some(req) = self.requests.get_mut(&id) {
            req.starvation_cnt = w.starvation_cnt;
            req.starving = w.starving;
        }
    }

    /// Is prefix caching in effect? Requires both the config switch and
    /// a backend that can resume decode from KV state it never
    /// materialized itself (the PJRT backend cannot — its per-request
    /// state is built by its own `materialize` calls, so skipping
    /// prefill there would decode against missing state).
    fn prefix_cache_active(&self) -> bool {
        self.cfg.prefix_cache.enabled
            && self.backend.supports_prefix_reuse()
    }

    /// Tokens of a would-be recompute expected to come from prefix-cache
    /// hits: the full blocks of `ctx`, registered at the API encounter
    /// and retained (reclaimable) through the call. Optimistic about
    /// retention — pressure eviction during the call makes the true
    /// value smaller. Zero when the cache is disabled, so eqn (2) stays
    /// byte-identical to the uncached engine.
    fn cached_recompute_estimate(&self, ctx: Tokens) -> Tokens {
        if !self.prefix_cache_active() {
            return Tokens::ZERO;
        }
        let bs = self.kv.block_size();
        Tokens(ctx.0 / bs * bs)
    }

    /// Consume `id`'s pending restore-residency credit (set when the
    /// re-admission allocation walked the prefix cache): the leading
    /// parked tokens whose blocks are attached to the allocation and
    /// therefore need no PCIe transfer.
    fn take_restore_resident(&mut self, id: RequestId) -> Tokens {
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = self.requests.get_mut(&id).expect("restoring request");
        std::mem::replace(&mut req.restore_resident, Tokens::ZERO)
    }

    /// Book `id`'s swap-in restore — the shared core of the sync and
    /// async paths: consume the residency credit, un-park the context,
    /// charge bookkeeping + backend time for the non-resident remainder
    /// only, and count the skipped tokens. Returns the restored token
    /// count and the transfer time (`None` if nothing was parked); the
    /// caller decides whether that time stalls the batch (sync) or
    /// overlaps it (async).
    fn book_swap_in(&mut self, id: RequestId) -> Option<(Tokens, Micros)> {
        let resident = self.take_restore_resident(id);
        let (tokens, t_in) = self
            .swap
            .swap_in_with_resident(id, &self.cfg.cost, resident)?;
        let t_backend = self
            .backend
            .swap_in(id, tokens.saturating_sub(resident));
        self.metrics.swap_restore_cached_tokens += resident.0;
        Some((tokens, t_in.max(t_backend)))
    }

    /// Handling assignment at admission (LAMPS §4.2). For `MinWasteAtApi`
    /// (INFERCEPT) the real decision happens at encounter time; Preserve
    /// placeholders are stored until then.
    fn assign_handling(
        &self, spec: &RequestSpec,
        predictions: &[crate::core::request::SegmentPrediction])
        -> Vec<HandlingStrategy> {
        match self.cfg.handling {
            HandlingPolicy::Forced(s) => vec![s; spec.api_calls.len()],
            HandlingPolicy::MinWasteAtApi => {
                vec![HandlingStrategy::Preserve; spec.api_calls.len()]
            }
            HandlingPolicy::MinWastePredicted => {
                let mut ctx = spec.prompt_tokens.0 as f64;
                let mut out = Vec::with_capacity(spec.api_calls.len());
                for (i, _call) in spec.api_calls.iter().enumerate() {
                    // lamps-lint: allow(panic) segment index is bounded by the spec's call list
                    let pred = &predictions[i];
                    ctx += pred.decode_tokens.0 as f64;
                    let inp = WasteInputs {
                        ctx: Tokens(ctx as u64),
                        api_duration: pred
                            .api_duration
                            .unwrap_or(Micros::ZERO),
                        c_other: Tokens(self.c_other_ema as u64),
                        cached: self
                            .cached_recompute_estimate(Tokens(ctx as u64)),
                    };
                    out.push(select_strategy(&inp, &self.cfg.cost));
                    ctx += pred.response_tokens.0 as f64;
                }
                out
            }
        }
    }

    // ------------------------------------------------------------------
    // Main loop
    // ------------------------------------------------------------------

    /// Run a trace to completion (virtual-clock runs) and report.
    pub fn run_trace(&mut self, trace: &Trace) -> RunReport {
        self.run_trace_limited(trace, None)
    }

    /// Run a trace, stopping at `time_cap` if given (Fig 8's 30-minute
    /// throughput window).
    pub fn run_trace_limited(&mut self, trace: &Trace,
                             time_cap: Option<Micros>) -> RunReport {
        for spec in &trace.requests {
            self.enqueue(spec.clone());
        }
        self.run_until_idle(time_cap);
        self.metrics.end_time = self.now();
        self.metrics.report()
    }

    /// Drive rounds until every submitted request finished (or dropped),
    /// or the cap is reached.
    pub fn run_until_idle(&mut self, time_cap: Option<Micros>) {
        while self.step() {
            if let Some(cap) = time_cap {
                if self.now() >= cap {
                    break;
                }
            }
            if self.iteration >= MAX_ITERATIONS {
                // lamps-lint: allow(panic) livelock safety valve — aborting beats spinning forever
                panic!("engine exceeded MAX_ITERATIONS — scheduling \
                        livelock?");
            }
        }
        self.finish_run();
    }

    /// Mirror the KV-layer prefix-cache counters into the metrics
    /// collector (kv is the single source of truth for them).
    fn sync_prefix_metrics(&mut self) {
        self.metrics.prefix_hit_tokens = self.kv.prefix_hit_tokens();
        self.metrics.prefix_evictions = self.kv.prefix_evictions();
        self.metrics.prefix_cached_blocks = self.kv.cached_blocks();
        self.metrics.blocks_allocated = self.kv.blocks_allocated();
    }

    /// One scheduling round. Returns false when fully idle with no
    /// pending work.
    pub fn step(&mut self) -> bool {
        let progressed = self.step_inner();
        self.audit_after_step();
        progressed
    }

    /// Post-step invariant audit ([`crate::audit`]); no-op unless the
    /// auditor is armed. Take/put-back so the auditor can borrow the
    /// whole engine read-only while updating its own state.
    fn audit_after_step(&mut self) {
        let Some(mut auditor) = self.auditor.take() else {
            return;
        };
        if let Err(e) = auditor.check_engine(self) {
            // lamps-lint: allow(panic) a tripped audit invariant is a scheduler bug — fail loudly
            panic!("{e}");
        }
        self.auditor = Some(auditor);
    }

    fn step_inner(&mut self) -> bool {
        // A step mutates essentially everything a placement probe reads
        // (queues, EMAs, contexts, segments): one epoch bump up front
        // covers the whole iteration, since no probe can observe the
        // engine mid-step (`&mut self` is held throughout).
        self.touch_load();
        let now = self.now();
        self.drain_arrivals(now);
        self.complete_transfers(now);
        self.drain_api_returns(now);
        // Algorithm 1 line 17: the running batch is rebuilt from the
        // sorted queue every iteration. Deselected requests keep their KV
        // (pause, not preemption).
        for id in self.running.drain(..) {
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let req = self.requests.get_mut(&id).unwrap();
            req.phase = Phase::Waiting;
            self.waiting.push(id);
        }
        self.rank_waiting();
        self.admit();

        if self.running.is_empty() {
            // Idle: jump to the next event (arrival, API return, or a
            // background swap transfer landing).
            match self.next_event() {
                Some(t) => {
                    self.clock.wait_until(t);
                    return true;
                }
                None => {
                    // No events, nothing runnable. If paused requests
                    // hold memory that blocks everyone, preempt the
                    // lowest-priority holder (vLLM recompute-style) and
                    // retry; otherwise we are done.
                    if !self.waiting.is_empty() {
                        if let Some(victim) = self.pick_preemption_victim()
                        {
                            self.preempt(victim, now);
                            return true;
                        }
                    }
                    return false;
                }
            }
        }

        // Tentpole pipeline: compose → execute → commit.
        let plan = self.compose_iteration();
        if plan.is_empty() {
            // Defensive (compose guarantees progress for a non-empty
            // running set): jump to the next event rather than spin.
            return match self.next_event() {
                Some(t) if t > now => {
                    self.clock.wait_until(t);
                    true
                }
                _ => false,
            };
        }
        self.execute_and_commit(plan);
        self.iteration += 1;
        self.metrics.iterations = self.iteration;
        self.sync_prefix_metrics();
        if self.record_timeline {
            let held = |ids: &[RequestId]| -> u64 {
                ids.iter().map(|id| self.kv.tokens_of(*id).0).sum()
            };
            let held_api: u64 = self
                .pred_return
                .keys()
                .map(|id| self.kv.tokens_of(*id).0)
                .sum();
            let point = TimelinePoint {
                at: self.now(),
                kv_occupancy: self.kv.occupancy(),
                completed: self.metrics.completed(),
                in_api: self.api.in_flight(),
                running: self.running.len(),
                held_running: held(&self.running),
                held_api,
                held_waiting: held(&self.waiting),
            };
            self.metrics.sample_timeline(point);
        }
        true
    }

    /// Earliest future event the engine can jump to when nothing is
    /// runnable: the next arrival, API return, background swap transfer
    /// completion, or the external hint a replica-set driver supplied.
    fn next_event(&self) -> Option<Micros> {
        [
            self.pending.front().map(|s| s.arrival),
            self.api.next_return(),
            self.transfers.next_completion(),
            self.external_event,
        ]
        .into_iter()
        .flatten()
        .min()
    }

    fn drain_arrivals(&mut self, now: Micros) {
        while self
            .pending
            .front()
            .is_some_and(|front| front.arrival <= now)
        {
            let Some(spec) = self.pending.pop_front() else { break };
            self.submit(spec);
        }
    }

    fn drain_api_returns(&mut self, now: Micros) {
        let mut returned = Vec::new();
        self.api.drain_returned(now, |id| returned.push(id));
        for id in returned {
            self.route_api_return(id, now);
        }
    }

    /// Route one API return back into the waiting queue — the shared
    /// core of the simulated drain (deadline heap) and the external
    /// resolution path ([`Engine::complete_api_call`]).
    fn route_api_return(&mut self, id: RequestId, now: Micros) {
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = self.requests.get_mut(&id).expect("api return");
        let Phase::ApiWait { strategy, return_at } = req.phase else {
            // lamps-lint: allow(panic) executor-heap ids are parked in ApiWait by construction
            panic!("{id} returned but not in ApiWait");
        };
        self.api.note_returned(strategy);
        self.pred_return.remove(&id);
        let seg = req.segment;
        // lamps-lint: allow(panic) segment index is bounded by the spec's call list
        let call = &req.spec.api_calls[seg];
        let response = call.response_tokens;
        let api = call.api_type;
        // Actual duration: the sampled truth for simulated calls, the
        // measured park time for externally-resolved ones.
        let external = return_at.is_none();
        let actual = if external {
            req.api_started_at.map_or(Micros::ZERO, |t| now - t)
        } else {
            call.duration
        };
        // lamps-lint: allow(panic) segment index is bounded by the spec's call list
        let predicted = req.predictions[seg]
            .api_duration
            .unwrap_or(call.duration);
        req.api_started_at = None;
        req.segment += 1;
        req.segment_generated = Tokens::ZERO;
        req.logical_context += response;
        match strategy {
            HandlingStrategy::Preserve => {
                // KV retained; only the response must be materialized.
                req.pending_materialize = response;
            }
            HandlingStrategy::Discard => {
                // Everything must be recomputed. Flag it here, not
                // only at chunk time: prefix-cache hits at admission
                // shrink `pending_materialize` below
                // `logical_context`, which would otherwise hide the
                // (smaller) recompute from the wasted-work metric.
                req.pending_materialize = req.logical_context;
                req.context = Tokens::ZERO;
                req.recomputing = true;
            }
            HandlingStrategy::Swap => {
                // Swap-in restores the old context; the response is
                // new. Nothing is live until the restore runs.
                req.pending_materialize = response;
                req.context = Tokens::ZERO;
            }
        }
        req.phase = Phase::Waiting;
        if self.cfg.requeue_as_new {
            // vLLM treats the continuation as a brand-new job.
            req.queue_key = now;
        }
        // Segment changed: invalidate the cached score.
        req.score_iteration = u64::MAX;
        self.waiting.push(id);
        if external || self.record_sim_outcomes {
            // The predicted-vs-actual duration gap is observable for
            // externally-resolved calls and for simulated returns under
            // any non-oracle predictor (the exact oracle's gap is
            // identically zero; skipping it keeps oracle-run report
            // bytes unchanged, since the histogram is emitted only when
            // non-empty).
            self.metrics.record_api_outcome(predicted, actual);
        }
        // The outcome sites — this simulated-return path and the
        // external resolution that funnels through it — are the seam's
        // single mutation point: one `observe` per finished call.
        self.duration_model.observe(api, predicted, actual);
        if self.duration_model.is_learned() {
            self.metrics.api_pred_model = self.duration_model.snapshot();
        }
        self.push_event(EngineEvent::ApiCompleted {
            id,
            index: seg,
            actual,
        });
    }

    fn schedule_context(&self) -> ScheduleContext {
        ScheduleContext {
            cost: self.cfg.cost,
            t_iter_est: Micros(self.t_iter_ema as u64),
            c_other_est: Tokens(self.c_other_ema as u64),
            iteration: self.iteration,
            account_prefill: self.cfg.compose.is_chunked(),
            // Live cache: the rank integral discounts its discard term
            // by the expected cached prefix (the same estimate the
            // handling choice uses). None keeps scores byte-identical
            // to the uncached engine.
            prefix_cached_block: if self.prefix_cache_active() {
                Some(self.kv.block_size())
            } else {
                None
            },
        }
    }

    /// Refresh scores (selective update, §4.3) and sort the waiting queue
    /// by (starving desc, score asc, id asc) — Algorithm 1 line 16 plus
    /// the §4.4 promotion rule.
    fn rank_waiting(&mut self) {
        let ctx = self.schedule_context();
        let interval = self.cfg.score_update_interval.max(1);
        for id in &self.waiting {
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let req = self.requests.get_mut(id).expect("waiting req");
            let stale = req.score_iteration == u64::MAX
                || (self.scheduler.is_dynamic()
                    && self.iteration.wrapping_sub(req.score_iteration)
                        >= interval);
            if stale {
                req.cached_score = self.scheduler.score(req, &ctx);
                req.score_iteration = self.iteration;
            }
        }
        let requests = &self.requests;
        self.waiting.sort_by(|a, b| {
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let ra = &requests[a];
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let rb = &requests[b];
            rb.starving
                .cmp(&ra.starving)
                .then(ra.cached_score.cmp(&rb.cached_score))
                .then(ra.spec.id.cmp(&rb.spec.id))
        });
    }

    /// Admit waiting requests into the running batch (Algorithm 1 lines
    /// 18-31): respect batch capacity, memory, the backend slot cap, and
    /// the reservation lookahead; track starvation counters.
    fn admit(&mut self) {
        let now = self.now();
        let slot_cap = self
            .backend
            .slot_capacity()
            .unwrap_or(usize::MAX)
            .min(self.cfg.max_batch);
        let mut admitted: Vec<RequestId> = Vec::new();
        let mut still_waiting: Vec<RequestId> = Vec::new();

        let waiting = std::mem::take(&mut self.waiting);
        let mut rest: std::collections::VecDeque<RequestId> =
            waiting.into();
        while let Some(id) = rest.pop_front() {
            // An in-flight background transfer pins the request: it
            // neither runs nor competes for admission until the
            // transfer lands.
            if self.transfers.contains(id) {
                still_waiting.push(id);
                continue;
            }
            // A context that outgrew the whole budget can never run again:
            // drop it rather than livelock (real deployments would error
            // the request back to the client).
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            if self.requests[&id].admission_memory() > self.kv.capacity() {
                self.transfers.cancel(id);
                self.free_terminal(id);
                self.swap.discard(id);
                self.backend.release(id);
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                self.requests.get_mut(&id).unwrap().phase =
                    Phase::Finished;
                self.live.remove(&id);
                self.dropped.push(id);
                self.push_event(EngineEvent::Dropped {
                    id,
                    reason: "context outgrew the replica KV budget \
                             mid-run"
                        .to_string(),
                });
                continue;
            }
            let slot_ok =
                self.running.len() + admitted.len() < slot_cap;
            let mut mem_ok = slot_ok && self.fits_memory(id);
            if slot_ok && !mem_ok {
                // Priority preemption: evict worst-ranked *paused* KV
                // holders (they rank strictly below `id` — the queue is
                // sorted) until the candidate fits. vLLM/FCFS/SJF evict
                // unconditionally (vLLM recompute-on-OOM semantics);
                // LAMPS evicts only when the victim's remaining
                // memory-over-time exceeds the candidate's score plus the
                // recompute waste eviction would cause — which is why R2
                // *waits* for preserved R1 in Fig 3d instead of evicting.
                while !mem_ok {
                    let victim = rest
                        .iter()
                        .rev()
                        .find(|v| {
                            self.kv.tokens_of(**v) > Tokens::ZERO
                                && !self.transfers.contains(**v)
                        })
                        .copied();
                    let Some(v) = victim else { break };
                    if self.cfg.scheduler == SchedulerKind::Lamps
                        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                        && !self.requests[&id].starving
                    {
                        // Starving candidates (§4.4 promotion) always get
                        // resources. Otherwise evict only when the
                        // victim's remaining memory-over-time exceeds the
                        // candidate's score plus the recompute waste the
                        // eviction causes — which is why R2 *waits* for
                        // preserved R1 in Fig 3d instead of evicting.
                        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                        let vr = &self.requests[&v];
                        let ctx = vr.logical_context;
                        let evict_cost = self.cfg.cost.prefill_time(ctx).0
                            as f64
                            * ctx.0 as f64;
                        let candidate_score =
                            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                            self.requests[&id].cached_score.primary;
                        if vr.cached_score.primary
                            <= candidate_score + evict_cost
                        {
                            break; // not worth destroying preserved work
                        }
                    }
                    self.preempt_state(v, now);
                    mem_ok = self.fits_memory(id);
                }
            }
            let resv_ok =
                mem_ok && self.fits_reservation(id, &admitted, now);
            if !slot_ok {
                self.metrics.rejected_slot += 1;
            } else if !mem_ok {
                self.metrics.rejected_memory += 1;
            } else if !resv_ok {
                self.metrics.rejected_reservation += 1;
            }
            let can_admit = resv_ok;
            if can_admit {
                // Reserve context + 1 headroom slot (the token this
                // iteration will append). All allocation happens here;
                // decode itself never allocates.
                let existing = self.kv.tokens_of(id);
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let logical = self.requests[&id].logical_context;
                let delta =
                    (logical + Tokens(1)).saturating_sub(existing);
                if delta > Tokens::ZERO {
                    // Fresh full materializations route through the
                    // prefix cache: `cached` leading tokens are already
                    // materialized in shared blocks, so prefill starts
                    // at the first uncached token.
                    let cached = self.allocate_admitted(id, delta);
                    if cached > Tokens::ZERO {
                        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                        let req = self.requests.get_mut(&id).unwrap();
                        req.pending_materialize = req
                            .pending_materialize
                            .saturating_sub(cached);
                        req.context = req
                            .logical_context
                            .saturating_sub(req.pending_materialize);
                        if req.pending_materialize == Tokens::ZERO {
                            // Fully-cached recompute: no prefill chunk
                            // will run, so clear the flag here (the
                            // chunk-commit path can't).
                            req.recomputing = false;
                        }
                    }
                }
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let req = self.requests.get_mut(&id).unwrap();
                req.was_scheduled = true;
                req.starvation_cnt = 0;
                if req.first_scheduled_at.is_none() {
                    req.first_scheduled_at = Some(now);
                }
                if self.cfg.compose.async_swap && self.swap.contains(id) {
                    // Begin the background swap-in: device blocks are
                    // charged from now, the batch keeps decoding, and
                    // the request rejoins once the transfer lands.
                    // Parked context whose cached blocks the allocation
                    // above re-attached skips the transfer outright.
                    let (tokens, stall) = self
                        .book_swap_in(id)
                        // lamps-lint: allow(panic) swap-out recorded the parked context for this id
                        .expect("parked context");
                    self.metrics.swap_overlap_us += stall.0;
                    self.transfers.begin(id, TransferDir::SwapIn, tokens,
                                         now + stall);
                    still_waiting.push(id);
                } else {
                    // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                    let req = self.requests.get_mut(&id).unwrap();
                    req.phase = Phase::Running;
                    admitted.push(id);
                }
            } else {
                still_waiting.push(id);
            }
        }

        // Starvation accounting for the left-behind (Algorithm 1 lines
        // 22-31): increment, promote at threshold, sticky until finish.
        // Transfer-pinned requests are progressing, not starving.
        if let Some(threshold) = self.cfg.starvation_threshold {
            for id in &still_waiting {
                if self.transfers.contains(*id) {
                    continue;
                }
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let req = self.requests.get_mut(id).unwrap();
                if !req.starving {
                    req.starvation_cnt += 1;
                    if req.starvation_cnt >= threshold {
                        req.starving = true;
                        req.starvation_cnt = 0;
                    }
                }
            }
        }

        self.waiting = still_waiting;
        self.running.extend(admitted);
    }

    /// Immediate memory check: context + 1 token of headroom must fit.
    /// Mirrors admit()'s allocation delta exactly — in particular, a
    /// request whose async swap-in already reserved `logical + 1` tokens
    /// needs nothing more.
    fn fits_memory(&self, id: RequestId) -> bool {
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = &self.requests[&id];
        let existing = self.kv.tokens_of(id);
        let needed = (req.logical_context + Tokens(1))
            .saturating_sub(existing);
        self.kv.can_fit(id, needed)
    }

    /// Allocate `delta` tokens for a just-admitted request. A *fresh
    /// full materialization* (no live blocks, the entire logical context
    /// still owed — a new prompt, a post-Discard recompute, or a
    /// post-preemption recompute) walks the prefix cache and returns the
    /// leading tokens served by cache hits. A *swap-in restore* also
    /// walks the cache, but its hits stash a residency credit
    /// (`Request::restore_resident`) instead: they shrink the PCIe
    /// transfer, not the prefill — the shared blocks *are* the leading
    /// part of the allocation, so nothing is held twice, memory
    /// pressure cannot reclaim them mid-restore, and the terminal free
    /// purges them like any other attached private content. Every other
    /// shape (growth, Preserve resume) allocates plainly; both returns
    /// are zero there.
    fn allocate_admitted(&mut self, id: RequestId, delta: Tokens)
                         -> Tokens {
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = &self.requests[&id];
        if self.prefix_cache_active()
            && self.swap.contains(id)
            && self.kv.tokens_of(id) == Tokens::ZERO
        {
            let parked = self
                .swap
                .parked_tokens(id)
                // lamps-lint: allow(panic) fits_memory/contains checked in this scope
                .expect("checked contains");
            let chain = Self::chain_upto(&mut self.chain_memo, &req.spec,
                                         self.kv.block_size(), parked);
            let cached = self
                .kv
                .allocate_prefixed(id, delta, chain)
                // lamps-lint: allow(panic) fits_memory/contains checked in this scope
                .expect("fits_memory held");
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let req = self.requests.get_mut(&id).expect("checked above");
            req.restore_resident = cached.min(parked);
            return Tokens::ZERO;
        }
        let fresh_full = self.prefix_cache_active()
            && self.kv.tokens_of(id) == Tokens::ZERO
            && req.pending_materialize == req.logical_context
            && req.logical_context.0 >= self.kv.block_size()
            && !self.swap.contains(id);
        if !fresh_full {
            // lamps-lint: allow(panic) fits_memory/contains checked in this scope
            self.kv.allocate(id, delta).expect("fits_memory held");
            return Tokens::ZERO;
        }
        let chain = Self::chain_upto(&mut self.chain_memo, &req.spec,
                                     self.kv.block_size(),
                                     req.logical_context);
        self.kv
            .allocate_prefixed(id, delta, chain)
            // lamps-lint: allow(panic) fits_memory/contains checked in this scope
            .expect("fits_memory held")
    }

    /// Publish the materialized full blocks of `id`'s live context into
    /// the prefix cache (no-op when disabled), making them hittable by
    /// other requests with the same prompt and by this request's own
    /// post-Discard/post-preemption recompute. Safe mid-materialization:
    /// only content-complete blocks below `context` are registered.
    /// Full blocks of `id`'s context holding cross-request-shareable
    /// prompt content. Everything past the prompt (generated tokens,
    /// API responses) — and all of a content-less synthetic prompt —
    /// is keyed per-request and dies with the request, so terminal
    /// frees purge it from the cache instead of retaining garbage.
    fn shareable_prompt_blocks(&self, id: RequestId) -> u64 {
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = &self.requests[&id];
        if req.spec.prompt.is_empty() {
            return 0;
        }
        req.spec.prompt_tokens.0.min(req.logical_context.0)
            / self.kv.block_size()
    }

    /// Terminal free (finish / drop): retain only shareable prompt
    /// blocks in the prefix cache. Registered content no longer attached
    /// to the live allocation — e.g. blocks published at a Swap
    /// encounter whose request then dropped before restoring — would
    /// survive the allocation-walk purge as permanently-unhittable
    /// garbage, so the request's private chain tail is purged explicitly
    /// as well (a no-op for anything pinned by another holder).
    fn free_terminal(&mut self, id: RequestId) {
        let retain = self.shareable_prompt_blocks(id);
        if self.kv.contains(id) {
            self.kv
                .free_discarding_private(id, retain)
                // lamps-lint: allow(panic) fits_memory/contains checked in this scope
                .expect("terminal free");
        }
        if self.prefix_cache_active() {
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let req = &self.requests[&id];
            let chain = Self::chain_upto(&mut self.chain_memo, &req.spec,
                                         self.kv.block_size(),
                                         req.logical_context);
            self.kv.purge_chain_tail(chain, retain);
        }
        // The request is terminal: its chain can never be asked for
        // again at a longer prefix.
        self.chain_memo.remove(&id);
    }

    fn register_prefix_of(&mut self, id: RequestId) {
        if !self.prefix_cache_active() {
            return;
        }
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = &self.requests[&id];
        let ctx = req.context;
        if ctx.0 < self.kv.block_size() {
            return;
        }
        let chain = Self::chain_upto(&mut self.chain_memo, &req.spec,
                                     self.kv.block_size(), ctx);
        self.kv.register_prefix(id, ctx, chain);
    }

    /// Clairvoyant reservation: every in-flight Preserve/Swap API request
    /// must be able to resume at its predicted return time.
    fn fits_reservation(&self, candidate: RequestId,
                        admitted: &[RequestId], now: Micros) -> bool {
        if !self.cfg.admission_lookahead || self.pred_return.is_empty() {
            return true;
        }
        let budget = self.kv.capacity().0;
        for (&p_id, &t_ret) in &self.pred_return {
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let p = &self.requests[&p_id];
            let Phase::ApiWait { strategy, .. } = p.phase else {
                continue;
            };
            let resume_need = match strategy {
                HandlingStrategy::Preserve => {
                    // Held context stays allocated; needs the response +
                    // one-token headroom on top.
                    p.context.0
                        // lamps-lint: allow(panic) segment index is bounded by the spec's call list
                        + p.predictions[p.segment].response_tokens.0
                        + 1
                }
                HandlingStrategy::Swap => {
                    p.logical_context.0
                        // lamps-lint: allow(panic) segment index is bounded by the spec's call list
                        + p.predictions[p.segment].response_tokens.0
                        + 1
                }
                HandlingStrategy::Discard => continue,
            };
            let mut projected = resume_need;
            // Other preserve-held API waiters keep their memory.
            for &o_id in self.pred_return.keys() {
                if o_id == p_id {
                    continue;
                }
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let o = &self.requests[&o_id];
                if let Phase::ApiWait {
                    strategy: HandlingStrategy::Preserve, ..
                } = o.phase
                {
                    projected += o.context.0;
                }
            }
            for &q_id in self.running.iter().chain(admitted) {
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                projected += self.projected_mem(&self.requests[&q_id],
                                                now, t_ret);
            }
            projected +=
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                self.projected_mem(&self.requests[&candidate], now, t_ret);
            if projected > budget {
                return false;
            }
        }
        true
    }

    /// Predicted device memory of `q` at future time `t` (token slots),
    /// assuming it is (or stays) admitted from `now`.
    fn projected_mem(&self, q: &Request, now: Micros, t: Micros) -> u64 {
        if t <= now {
            return q.logical_context.0 + 1;
        }
        let t_iter = self.t_iter_ema.max(1.0);
        let mat_us = self
            .cfg
            .cost
            .prefill_time(q.pending_materialize)
            .0 as f64;
        let avail_us = (t - now).0 as f64 - mat_us;
        let decoded = (avail_us / t_iter).floor().max(0.0) as u64;
        // lamps-lint: allow(panic) index clamped to the predictions length just above
        let pred = &q.predictions[q.segment.min(q.predictions.len() - 1)];
        let seg_remaining = pred
            .decode_tokens
            .0
            .saturating_sub(q.segment_generated.0);
        if decoded < seg_remaining {
            q.logical_context.0 + 1 + decoded
        } else {
            // Past its (predicted) API boundary by then.
            let ctx_at_api = q.logical_context.0 + seg_remaining;
            match q.handling.get(q.segment) {
                Some(HandlingStrategy::Preserve) => ctx_at_api,
                Some(_) => 0,
                None => 0, // final segment: finished and freed
            }
        }
    }

    /// Land finished background swap transfers (async mode): a swap-in
    /// makes the restored context live; a swap-out releases the device
    /// blocks it was draining from.
    fn complete_transfers(&mut self, now: Micros) {
        if self.transfers.is_empty() {
            return;
        }
        for t in self.transfers.pop_completed(now) {
            match t.dir {
                TransferDir::SwapIn => {
                    if let Some(req) = self.requests.get_mut(&t.id) {
                        req.context = t.tokens;
                    }
                }
                TransferDir::SwapOut => {
                    if self.kv.contains(t.id) {
                        // lamps-lint: allow(panic) fits_memory/contains checked in this scope
                        self.kv.free(t.id).expect("swap-out drain free");
                    }
                }
            }
        }
    }

    /// Phase 1 — **compose**: build the iteration plan from the running
    /// set (already in priority order) under the token budget. Pure
    /// projection of request state; see [`crate::coordinator::batch`].
    fn compose_iteration(&self) -> IterationPlan {
        let items: Vec<ComposeItem> = self
            .running
            .iter()
            .map(|id| {
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let req = &self.requests[id];
                ComposeItem {
                    id: *id,
                    pending: req.pending_materialize,
                    logical_context: req.logical_context,
                    // Async restores run in the TransferQueue and are
                    // intercepted at admission; only the synchronous
                    // path surfaces here.
                    needs_swap_in: self.swap.contains(*id),
                }
            })
            .collect();
        batch::compose(&self.effective_compose(), &items)
    }

    /// The composer knobs for this iteration: the static config, with
    /// the chunk size derived from the profiled t_iter EMA when
    /// autotuning (`--prefill-chunk auto`) is on.
    fn effective_compose(&self) -> ComposeConfig {
        let mut compose = self.cfg.compose;
        if compose.auto_chunk {
            compose.prefill_chunk = self.auto_prefill_chunk();
        }
        compose
    }

    /// Chunk-size autotuning target: one chunk's forward time ≈ one
    /// decode iteration (the t_iter EMA), so a co-batched recompute
    /// never stalls decodes for more than about twice an iteration.
    /// Clamped to [16, 8192] tokens (a sub-16-token chunk is all
    /// per-chunk overhead); a free-prefill cost model falls back to
    /// whole-context materialization, where chunking cannot matter.
    fn auto_prefill_chunk(&self) -> Option<u64> {
        let per_token = self.cfg.cost.prefill_per_token_us;
        if per_token <= 0.0 {
            return None;
        }
        Some(((self.t_iter_ema / per_token).round() as u64)
            .clamp(16, 8192))
    }

    /// Phases 2+3 — **execute** the plan on the backend and **commit**
    /// the results. With `ComposeConfig::default()` (one whole-context
    /// chunk per request, decode in the same round) this reproduces the
    /// legacy materialize-then-decode loop time-step for time-step.
    fn execute_and_commit(&mut self, plan: IterationPlan) {
        // Materialization chunks: swap restores + prefill segments, in
        // batch priority order. Prefill still blocks the round
        // (vLLM-style prefill priority) but only for its chunk.
        for chunk in &plan.prefill {
            let id = chunk.id;
            let mut elapsed = Micros::ZERO;
            if chunk.swap_in {
                // Parked context whose cached blocks the admission
                // allocation re-attached skips the synchronous transfer
                // (and its batch stall) too.
                if let Some((tokens, stall)) = self.book_swap_in(id) {
                    self.metrics.swap_stall_us += stall.0;
                    elapsed += stall;
                    // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                    self.requests.get_mut(&id).unwrap().context = tokens;
                }
            }
            if chunk.tokens > Tokens::ZERO {
                let (prompt, total_after) = {
                    // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                    let req = self.requests.get_mut(&id).unwrap();
                    if req.segment > 0
                        && req.pending_materialize == req.logical_context
                    {
                        // Post-Discard recompute starting over (wasted
                        // work accounting).
                        req.recomputing = true;
                    }
                    let after = req
                        .logical_context
                        .saturating_sub(req.pending_materialize)
                        + chunk.tokens;
                    (req.spec.prompt.clone(), after)
                };
                let t = self
                    .backend
                    .materialize(id, &prompt, total_after, chunk.tokens);
                elapsed += t;
                self.metrics.tokens_prefilled += chunk.tokens.0;
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                if self.requests[&id].recomputing {
                    self.metrics.tokens_recomputed += chunk.tokens.0;
                }
            }
            if elapsed > Micros::ZERO {
                self.metrics.materialize_us += elapsed.0;
                self.clock.advance(elapsed);
            }
            // Commit the chunk: advance the materialization cursor,
            // keeping `context = logical_context - pending_materialize`.
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let req = self.requests.get_mut(&id).unwrap();
            req.pending_materialize =
                req.pending_materialize.saturating_sub(chunk.tokens);
            req.context = req
                .logical_context
                .saturating_sub(req.pending_materialize);
            if req.pending_materialize == Tokens::ZERO {
                req.recomputing = false;
            }
            let finished_materialize = req.pending_materialize
                == Tokens::ZERO
                && chunk.tokens > Tokens::ZERO;
            if finished_materialize {
                // Freshly completed context: publish its full blocks
                // for prefix reuse by identical prompts and by this
                // request's own later recomputes.
                self.register_prefix_of(id);
            }
        }

        if plan.decode.is_empty() {
            // All budget went to prefill this round; decode resumes next
            // iteration.
            return;
        }
        let elapsed = self.backend.decode(&plan.decode);
        let now = self.clock.advance(elapsed);

        // Profiling EMAs for the ranking inputs.
        self.t_iter_ema = 0.9 * self.t_iter_ema + 0.1 * elapsed.0 as f64;
        if plan.decode.len() > 1 {
            let total: u64 = plan.decode.iter().map(|s| s.ctx.0).sum();
            let c_other = plan
                .decode
                .iter()
                .map(|s| (total - s.ctx.0) as f64)
                .sum::<f64>()
                / plan.decode.len() as f64;
            self.c_other_ema = 0.95 * self.c_other_ema + 0.05 * c_other;
        }

        // Commit decode: consume the admission-reserved headroom slot —
        // each decoded request's new token was pre-allocated in admit().
        let decode_ids: Vec<RequestId> =
            plan.decode.iter().map(|s| s.id).collect();
        for id in &decode_ids {
            let first = {
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let req = self.requests.get_mut(id).unwrap();
                debug_assert!(self.kv.tokens_of(*id)
                                  >= req.context + Tokens(1),
                              "admission must have reserved the headroom \
                               ({id}: tokens_of={}, context={})",
                              self.kv.tokens_of(*id).0, req.context.0);
                req.context += Tokens(1);
                req.logical_context += Tokens(1);
                req.segment_generated += Tokens(1);
                let first = req.first_token_at.is_none();
                if first {
                    req.first_token_at = Some(now);
                }
                first
            };
            self.metrics.tokens_decoded += 1;
            if first {
                self.metrics.on_first_token(*id, now);
                self.push_event(EngineEvent::FirstToken {
                    id: *id,
                    at: now,
                });
            }
            self.push_event(EngineEvent::Tokens { id: *id, chunk: 1 });
        }

        // Route segment boundaries: API encounters and completions.
        let mut leaving: Vec<RequestId> = Vec::new();
        for id in decode_ids {
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let req = &self.requests[&id];
            if req.segment_remaining() > Tokens::ZERO {
                continue;
            }
            if req.at_api_segment() {
                self.encounter_api(id, now);
            } else {
                self.finish(id, now);
            }
            leaving.push(id);
        }
        self.running.retain(|id| !leaving.contains(id));

        // Context-cap guard for finite backends (PJRT max_seq).
        if let Some(cap) = self.backend.max_context() {
            let ids: Vec<RequestId> = self.running.clone();
            for id in ids {
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                if self.requests[&id].logical_context.0 >= cap {
                    self.finish(id, now);
                    self.running.retain(|r| *r != id);
                }
            }
        }
    }

    /// Lowest-priority *paused* request still holding device memory —
    /// the victim when memory pressure blocks all progress. Requests
    /// with an in-flight transfer are untouchable (their blocks are
    /// mid-copy).
    fn pick_preemption_victim(&self) -> Option<RequestId> {
        self.waiting
            .iter()
            .filter(|id| {
                self.kv.tokens_of(**id) > Tokens::ZERO
                    && !self.transfers.contains(**id)
            })
            .max_by(|a, b| {
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let ra = &self.requests[*a];
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let rb = &self.requests[*b];
                ra.cached_score
                    .cmp(&rb.cached_score)
                    .then(ra.spec.id.cmp(&rb.spec.id))
            })
            .copied()
    }

    /// vLLM recompute-style preemption: drop device state. The victim
    /// stays wherever it is queued (or is re-queued by the caller).
    fn preempt_state(&mut self, id: RequestId, now: Micros) {
        debug_assert!(!self.transfers.contains(id),
                      "{id} preempted mid-transfer");
        // Keep the victim's full blocks hittable: its recompute on
        // re-admission then skips the cached prefix.
        self.register_prefix_of(id);
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = self.requests.get_mut(&id).unwrap();
        req.phase = Phase::Waiting;
        req.pending_materialize = req.logical_context;
        req.context = Tokens::ZERO;
        // Same semantics the chunk-time heuristic derives (recompute
        // accounting only past segment 0), but robust to the prefix
        // cache discounting `pending_materialize` at re-admission.
        req.recomputing = req.segment > 0;
        if self.cfg.requeue_as_new {
            req.queue_key = now;
        }
        req.score_iteration = u64::MAX;
        if self.kv.contains(id) {
            // lamps-lint: allow(panic) fits_memory/contains checked in this scope
            self.kv.free(id).expect("preempt free");
        }
        self.backend.release(id);
        self.metrics.preemptions += 1;
    }

    /// Preempt + ensure the victim is in the waiting queue (idle-path
    /// deadlock breaking; never duplicates entries).
    fn preempt(&mut self, id: RequestId, now: Micros) {
        self.preempt_state(id, now);
        if !self.waiting.contains(&id) {
            self.waiting.push(id);
        }
    }

    /// The request just hit its segment's API call (Algorithm 1 lines
    /// 34-44).
    fn encounter_api(&mut self, id: RequestId, now: Micros) {
        let (seg, duration, pred_duration, own_ctx) = {
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let req = &self.requests[&id];
            let seg = req.segment;
            // lamps-lint: allow(panic) segment index is bounded by the spec's call list
            let call = &req.spec.api_calls[seg];
            // lamps-lint: allow(panic) segment index is bounded by the spec's call list
            let raw = req.predictions[seg]
                .api_duration
                .unwrap_or(call.duration);
            (seg,
             call.duration,
             // Re-prediction at the encounter: the submit-time estimate
             // is refreshed against the current class estimator before
             // the strategy choice, the reservation plan, and the
             // ApiStarted event consume it (identity in static mode).
             self.duration_model.revise(call.api_type, raw),
             req.context)
        };
        // INFERCEPT decides here, with live batch context.
        let strategy = match self.cfg.handling {
            HandlingPolicy::MinWasteAtApi => {
                let c_other: u64 = self
                    .running
                    .iter()
                    .filter(|r| **r != id)
                    // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                    .map(|r| self.requests[r].context.0)
                    .sum();
                let inp = WasteInputs {
                    ctx: own_ctx,
                    api_duration: pred_duration,
                    c_other: Tokens(c_other),
                    cached: self.cached_recompute_estimate(own_ctx),
                };
                select_strategy(&inp, &self.cfg.cost)
            }
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            _ => self.requests[&id].handling[seg],
        };
        {
            // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
            let req = self.requests.get_mut(&id).unwrap();
            // lamps-lint: allow(panic) segment index is bounded by the spec's call list
            req.handling[seg] = strategy;
            if self.duration_model.is_learned() {
                // Persist the refreshed estimate so the return site's
                // outcome accounting measures the error of what the
                // scheduler actually planned with.
                if let Some(pred) = req.predictions.get_mut(seg) {
                    if pred.api_duration.is_some() {
                        pred.api_duration = Some(pred_duration);
                    }
                }
            }
            req.starvation_cnt = 0; // §4.4 reset on API encounter
        }

        match strategy {
            HandlingStrategy::Preserve => {
                // lamps-lint: allow(panic) fixed-size strategy_counts array indexed by constant
                self.metrics.strategy_counts[0] += 1;
            }
            HandlingStrategy::Discard => {
                // lamps-lint: allow(panic) fixed-size strategy_counts array indexed by constant
                self.metrics.strategy_counts[1] += 1;
                // Publish the full blocks before dropping them: the
                // freed shared blocks stay reclaimable-cached, so the
                // post-API recompute re-pins them instead of
                // recomputing (the cache's headline saving).
                self.register_prefix_of(id);
                if self.kv.contains(id) {
                    // lamps-lint: allow(panic) fits_memory/contains checked in this scope
                    self.kv.free(id).expect("discard free");
                }
                self.backend.release(id);
            }
            HandlingStrategy::Swap => {
                // lamps-lint: allow(panic) fixed-size strategy_counts array indexed by constant
                self.metrics.strategy_counts[2] += 1;
                // Publish the full blocks before parking: the freed
                // device blocks stay reclaimable-cached, so the swap-in
                // restore can skip the PCIe transfer for whatever is
                // still resident when the call returns.
                self.register_prefix_of(id);
                // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
                let ctx = self.requests[&id].context;
                if self.cfg.compose.async_swap {
                    // Background transfer: the batch keeps decoding;
                    // device blocks stay charged until the copy drains.
                    match self.swap.swap_out(id, ctx, &self.cfg.cost) {
                        Some(t_book) => {
                            let t_backend = self.backend.swap_out(id, ctx);
                            let stall = t_book.max(t_backend);
                            self.metrics.swap_overlap_us += stall.0;
                            self.transfers.begin(
                                id, TransferDir::SwapOut, ctx,
                                self.clock.now() + stall);
                        }
                        None => {
                            // Swap space refused (full): nothing was
                            // parked, so the KV must stay resident —
                            // degrade to Preserve rather than lose the
                            // context. Unreachable with the unbounded
                            // host space the engine provisions.
                        }
                    }
                } else {
                    let t_book =
                        self.swap.swap_out(id, ctx, &self.cfg.cost);
                    let t_backend = self.backend.swap_out(id, ctx);
                    // Eqn (3): the transfer stalls the whole batch.
                    let stall =
                        t_book.unwrap_or(Micros::ZERO).max(t_backend);
                    if stall > Micros::ZERO {
                        self.metrics.swap_stall_us += stall.0;
                        self.clock.advance(stall);
                    }
                    if self.kv.contains(id) {
                        // lamps-lint: allow(panic) fits_memory/contains checked in this scope
                        self.kv.free(id).expect("swap free");
                    }
                }
            }
        }

        // The simulated source knows the true return time (the sampled
        // duration); an external source parks the call with no deadline
        // — it fires only when the client posts a `tool_result`
        // ([`Engine::complete_api_call`]). Either way the request is
        // held under the strategy chosen from the *predicted* duration,
        // and the reservation lookahead plans with the prediction.
        let external = self.cfg.api_source == ApiSourceKind::External;
        let started = self.clock.now();
        let return_at = (!external).then(|| started + duration);
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = self.requests.get_mut(&id).unwrap();
        req.phase = Phase::ApiWait {
            strategy,
            return_at,
        };
        req.api_started_at = Some(started);
        self.api.begin(id, return_at, strategy);
        self.pred_return.insert(id, now + pred_duration);
        self.push_event(EngineEvent::ApiStarted {
            id,
            index: seg,
            strategy,
            predicted: pred_duration,
            external,
        });
    }

    fn finish(&mut self, id: RequestId, now: Micros) {
        // lamps-lint: allow(panic) live/queued ids are always in the request table (auditor-checked)
        let req = self.requests.get_mut(&id).unwrap();
        req.phase = Phase::Finished;
        req.finished_at = Some(now);
        self.transfers.cancel(id);
        self.live.remove(&id);
        self.free_terminal(id);
        self.swap.discard(id);
        self.backend.release(id);
        self.metrics.on_finished(id, now);
        self.push_event(EngineEvent::Finished { id, at: now });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CostModel, PrefixCacheConfig, SchedulerKind};
    use crate::core::request::{ApiCallSpec, ApiType};

    fn unit_cfg(scheduler: SchedulerKind, budget: u64) -> SystemConfig {
        SystemConfig {
            scheduler,
            memory_budget: Tokens(budget),
            max_batch: 1,
            block_size: 1,
            starvation_threshold: None,
            cost: CostModel::unit(),
            ..SystemConfig::default()
        }
    }

    fn simple_spec(id: u64, arrival: u64, decode: u64) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: Micros(arrival),
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![],
            final_decode: Tokens(decode),
        }
    }

    fn api_spec(id: u64, pre: u64, api_units: u64, post: u64)
                -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(pre),
                api_type: ApiType::Qa,
                duration: Micros(api_units * 1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(post),
        }
    }

    #[test]
    fn single_request_runs_to_completion() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit(simple_spec(0, 0, 5));
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 5 decode iterations x 1 s
        assert_eq!(r.finished_at, Some(Micros(5_000_000)));
        assert_eq!(e.metrics.completed(), 1);
    }

    #[test]
    fn api_request_full_lifecycle() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Preserve]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 2 decode + 3 API + 1 decode = 6 units
        assert_eq!(r.finished_at, Some(Micros(6_000_000)));
    }

    #[test]
    fn discard_recompute_charges_time() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Discard]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        // 2 decode + 3 API + 2 recompute + 1 decode = 8 units
        assert_eq!(r.finished_at, Some(Micros(8_000_000)));
        assert_eq!(e.metrics.report().tokens_recomputed, 2);
    }

    #[test]
    fn memory_budget_serializes_requests() {
        // Budget of 6 with two requests of 5 tokens each: they cannot
        // decode concurrently even though max_batch would allow it.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 6);
        cfg.max_batch = 4;
        let mut e = Engine::simulated(cfg);
        e.submit(simple_spec(0, 0, 5));
        e.submit(simple_spec(1, 0, 5));
        e.run_until_idle(None);
        let r0 = e.request(RequestId(0)).unwrap();
        let r1 = e.request(RequestId(1)).unwrap();
        assert!(r0.is_finished() && r1.is_finished());
        // r0 finishes at 5 and frees; r1 runs 5..10 (it could start
        // around iteration 2 when 1 slot frees, but needs headroom; the
        // exact point depends on admission; completion must be >= 10
        // only if fully serialized, >= 7 otherwise).
        assert!(r1.finished_at.unwrap() >= Micros(7_000_000));
        assert_eq!(e.metrics.completed(), 2);
    }

    #[test]
    fn arrival_times_respected() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        let trace = Trace::new("t", 1.0, vec![
            simple_spec(0, 0, 2),
            simple_spec(1, 10_000_000, 2),
        ]);
        let report = e.run_trace(&trace);
        assert_eq!(report.completed, 2);
        let r1 = e.request(RequestId(1)).unwrap();
        // Arrives at 10 s, runs 2 iterations.
        assert_eq!(r1.finished_at, Some(Micros(12_000_000)));
        // TTFT for r1 is 1 iteration.
        assert_eq!(r1.first_token_at, Some(Micros(11_000_000)));
    }

    #[test]
    fn oversized_request_dropped_not_livelocked() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 4));
        e.submit(simple_spec(0, 0, 10)); // needs >4 eventually... admitted
        e.submit(RequestSpec {
            prompt_tokens: Tokens(10), // 10 + 1 > 4: dropped at submit
            ..simple_spec(1, 0, 1)
        });
        assert_eq!(e.dropped, vec![RequestId(1)]);
        e.run_until_idle(None);
        // r0 decodes but is preempted/self-preempted when it outgrows the
        // budget; eventually it cannot fit and gets preempted forever —
        // budget 4 caps context growth; our guard: requests whose context
        // exceeds capacity self-preempt and re-enter; they are finished
        // via preemption churn... ensure no hang and r0 completed or
        // dropped.
        let _ = e.request(RequestId(0));
    }

    #[test]
    fn swap_strategy_roundtrips_memory() {
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.cost.swap_per_token_us = 500_000.0; // 0.5 unit per token
        let mut e = Engine::simulated(cfg);
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Swap]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 2 decode + swap-out stall 1 (2 tok x 0.5) + 3 API
        // + swap-in 1 + 1 decode = 8 units
        assert_eq!(r.finished_at, Some(Micros(8_000_000)));
    }

    #[test]
    fn multi_api_segments() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        let spec = RequestSpec {
            id: RequestId(0),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![
                ApiCallSpec {
                    decode_before: Tokens(2),
                    api_type: ApiType::Math,
                    duration: Micros(1_000_000),
                    response_tokens: Tokens(3),
                },
                ApiCallSpec {
                    decode_before: Tokens(1),
                    api_type: ApiType::Math,
                    duration: Micros(2_000_000),
                    response_tokens: Tokens(0),
                },
            ],
            final_decode: Tokens(2),
        };
        e.submit_with_handling(spec, vec![HandlingStrategy::Preserve,
                                          HandlingStrategy::Preserve]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 2 dec + 1 api + 3 resp materialize + 1 dec + 2 api + 2 dec
        //   = 11 units
        assert_eq!(r.finished_at, Some(Micros(11_000_000)));
        // context: 2 + resp 3 + 1 + 2 = 8
        assert_eq!(r.logical_context, Tokens(8));
    }

    #[test]
    fn kv_freed_after_all_complete() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Lamps, 50));
        for i in 0..5 {
            e.submit(api_spec(i, 2, 2, 2));
        }
        e.run_until_idle(None);
        assert_eq!(e.metrics.completed(), 5);
        assert_eq!(e.kv_occupancy(), 0.0);
    }

    #[test]
    fn chunked_prefill_bounds_co_batched_stall() {
        // A 64-token prompt co-batched with a running decoder:
        // unchunked, its prefill stalls the decoder 64 token-times in a
        // single round; chunked at 8, no round may exceed one decode
        // plus one chunk's forward time (the acceptance bound).
        let mk = |chunk: Option<u64>| {
            let mut cfg = unit_cfg(SchedulerKind::Fcfs, 1000);
            cfg.max_batch = 4;
            cfg.cost = CostModel {
                decode_base: Micros(1_000),
                decode_per_ctx_token_us: 0.0,
                prefill_per_token_us: 1_000.0,
                swap_base_us: 0.0,
                swap_per_token_us: 0.0,
                rank_overhead_per_request_us: 0.0,
            };
            cfg.compose.prefill_chunk = chunk;
            let mut e = Engine::simulated(cfg);
            e.submit(simple_spec(0, 0, 100));
            e.submit(RequestSpec {
                prompt_tokens: Tokens(64),
                ..simple_spec(1, 0, 1)
            });
            let mut max_step = Micros::ZERO;
            loop {
                let before = e.now();
                if !e.step() {
                    break;
                }
                let d = e.now() - before;
                if d > max_step {
                    max_step = d;
                }
            }
            assert!(e.request(RequestId(0)).unwrap().is_finished());
            assert!(e.request(RequestId(1)).unwrap().is_finished());
            max_step
        };
        let unchunked = mk(None);
        let chunked = mk(Some(8));
        assert!(unchunked >= Micros(65_000),
                "unchunked worst round was {unchunked}");
        // decode 1 ms + one 8-token chunk (8 ms) = 9 ms ceiling.
        assert!(chunked <= Micros(9_000),
                "chunked worst round was {chunked}");
    }

    #[test]
    fn chunking_preserves_decode_totals() {
        let trace_decode: u64 = 5 + 3 + 1; // api_spec(0, 5, 2, 3) + extra
        let mk = |chunk: Option<u64>| {
            let mut cfg = unit_cfg(SchedulerKind::Lamps, 200);
            cfg.max_batch = 4;
            cfg.compose.prefill_chunk = chunk;
            let mut e = Engine::simulated(cfg);
            e.submit_with_handling(api_spec(0, 5, 2, 3),
                                   vec![HandlingStrategy::Discard]);
            e.submit(simple_spec(1, 0, 1));
            e.run_until_idle(None);
            assert_eq!(e.metrics.completed(), 2);
            assert_eq!(e.kv_occupancy(), 0.0);
            e.metrics.tokens_decoded
        };
        assert_eq!(mk(None), trace_decode);
        assert_eq!(mk(Some(2)), trace_decode);
    }

    #[test]
    fn async_swap_overlaps_and_does_not_stall() {
        // Sync semantics charge both transfers to the batch: 2 decode +
        // 1 swap-out stall + 3 API + 1 swap-in stall + 1 decode = 8
        // units (see swap_strategy_roundtrips_memory). Async, the
        // swap-out overlaps the API wait entirely and only the swap-in
        // transfer (1 unit, off the batch) remains on the critical
        // path: 2 + 3 + 1 + 1 = 7 units.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.cost.swap_per_token_us = 500_000.0;
        cfg.compose.async_swap = true;
        let mut e = Engine::simulated(cfg);
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Swap]);
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        assert_eq!(r.finished_at, Some(Micros(7_000_000)));
        assert_eq!(e.metrics.swap_stall_us, 0);
        assert_eq!(e.metrics.swap_overlap_us, 2_000_000);
        assert_eq!(e.kv_occupancy(), 0.0);
    }

    #[test]
    fn prefix_cache_makes_discard_recompute_cheap() {
        // prompt 8, decode 2, API 3 s (forced Discard), decode 1; unit
        // cost, block size 4. Uncached: 8 prefill + 2 decode + 3 API +
        // 10 recompute + 1 decode = 24 s. Cached: the 2 full blocks
        // (8 tokens) registered at the encounter survive the free, so
        // the recompute materializes only the 2-token tail:
        // 8 + 2 + 3 + 2 + 1 = 16 s.
        let run = |enabled: bool| {
            let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
            cfg.block_size = 4;
            if enabled {
                cfg.prefix_cache = PrefixCacheConfig::on();
            }
            let mut e = Engine::simulated(cfg);
            e.submit_with_handling(
                RequestSpec {
                    prompt_tokens: Tokens(8),
                    ..api_spec(0, 2, 3, 1)
                },
                vec![HandlingStrategy::Discard]);
            e.run_until_idle(None);
            assert!(e.request(RequestId(0)).unwrap().is_finished());
            e
        };
        let cold = run(false);
        assert_eq!(cold.request(RequestId(0)).unwrap().finished_at,
                   Some(Micros(24_000_000)));
        assert_eq!(cold.metrics.prefix_hit_tokens, 0);
        assert_eq!(cold.metrics.tokens_prefilled, 18);
        assert_eq!(cold.metrics.tokens_recomputed, 10);

        let warm = run(true);
        assert_eq!(warm.request(RequestId(0)).unwrap().finished_at,
                   Some(Micros(16_000_000)));
        assert_eq!(warm.metrics.prefix_hit_tokens, 8);
        assert_eq!(warm.metrics.tokens_prefilled, 10);
        // The uncached 2-token tail still counts as recompute waste.
        assert_eq!(warm.metrics.tokens_recomputed, 2);
        assert!(warm.metrics.blocks_allocated
                    < cold.metrics.blocks_allocated);
    }

    #[test]
    fn prefix_cache_serves_swap_restore_without_transfer() {
        // prompt 8, 2 pre-API decodes, 3 s API under forced Swap, 1
        // final decode; block size 4, swap cost 0.5 s/token. Cold: 8
        // prefill + 2 decode + 5 swap-out (10 tok) + 3 API + 5 swap-in
        // + 1 decode = 24 s. Warm: the 2 full blocks registered at the
        // swap encounter stay resident through the call, so the restore
        // transfers only the 2-token tail (1 s): 20 s total.
        let run = |enabled: bool| {
            let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
            cfg.block_size = 4;
            cfg.cost.swap_per_token_us = 500_000.0;
            if enabled {
                cfg.prefix_cache = PrefixCacheConfig::on();
            }
            let mut e = Engine::simulated(cfg);
            e.submit_with_handling(
                RequestSpec {
                    prompt_tokens: Tokens(8),
                    ..api_spec(0, 2, 3, 1)
                },
                vec![HandlingStrategy::Swap]);
            e.run_until_idle(None);
            assert!(e.request(RequestId(0)).unwrap().is_finished());
            e
        };
        let cold = run(false);
        assert_eq!(cold.request(RequestId(0)).unwrap().finished_at,
                   Some(Micros(24_000_000)));
        assert_eq!(cold.metrics.swap_restore_cached_tokens, 0);
        assert_eq!(cold.metrics.swap_stall_us, 10_000_000);

        let warm = run(true);
        assert_eq!(warm.request(RequestId(0)).unwrap().finished_at,
                   Some(Micros(20_000_000)));
        assert_eq!(warm.metrics.swap_restore_cached_tokens, 8);
        assert_eq!(warm.metrics.swap_stall_us, 6_000_000);
    }

    #[test]
    fn prefix_cache_discounts_async_swap_restore() {
        // Same shape as the sync test but with background transfers
        // (async_swap): cold, the swap-out (5 s) outlives the 3 s API
        // and the restore moves all 10 tokens (5 s): 8 prefill + 2
        // decode + 5 out + 5 in + 1 decode = 21 s. Warm, the 2 full
        // blocks registered at the encounter are pinned through the
        // restore window and only the 2-token tail transfers (1 s):
        // 17 s, with zero batch stall either way.
        let run = |enabled: bool| {
            let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
            cfg.block_size = 4;
            cfg.cost.swap_per_token_us = 500_000.0;
            cfg.compose.async_swap = true;
            if enabled {
                cfg.prefix_cache = PrefixCacheConfig::on();
            }
            let mut e = Engine::simulated(cfg);
            e.submit_with_handling(
                RequestSpec {
                    prompt_tokens: Tokens(8),
                    ..api_spec(0, 2, 3, 1)
                },
                vec![HandlingStrategy::Swap]);
            e.run_until_idle(None);
            assert!(e.request(RequestId(0)).unwrap().is_finished());
            assert_eq!(e.metrics.swap_stall_us, 0);
            e
        };
        let cold = run(false);
        assert_eq!(cold.request(RequestId(0)).unwrap().finished_at,
                   Some(Micros(21_000_000)));
        assert_eq!(cold.metrics.swap_restore_cached_tokens, 0);

        let warm = run(true);
        assert_eq!(warm.request(RequestId(0)).unwrap().finished_at,
                   Some(Micros(17_000_000)));
        assert_eq!(warm.metrics.swap_restore_cached_tokens, 8);
    }

    #[test]
    fn prefix_cache_shares_identical_prompts_across_requests() {
        // Two requests with the same 12-char prompt, the second arriving
        // after the first finished: its entire prompt is served from
        // cached blocks and prefill is skipped outright.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.block_size = 4;
        cfg.prefix_cache = PrefixCacheConfig::on();
        let mut e = Engine::simulated(cfg);
        let spec = |id: u64, arrival: u64| RequestSpec {
            prompt: "abcdabcdabcd".to_string(),
            prompt_tokens: Tokens(12),
            ..simple_spec(id, arrival, 2)
        };
        e.submit(spec(0, 0));
        e.enqueue(spec(1, 20_000_000));
        e.run_until_idle(None);
        // r0: 12 prefill + 2 decode = 14 s.
        assert_eq!(e.request(RequestId(0)).unwrap().finished_at,
                   Some(Micros(14_000_000)));
        // r1: all 3 full prompt blocks hit; decode starts immediately.
        assert_eq!(e.request(RequestId(1)).unwrap().finished_at,
                   Some(Micros(22_000_000)));
        assert_eq!(e.metrics.prefix_hit_tokens, 12);
        assert_eq!(e.metrics.tokens_prefilled, 12, "prompt prefilled once");
    }

    #[test]
    fn prefix_cache_never_aliases_contentless_prompts() {
        // Synthetic traces (empty prompt text) must not share blocks
        // across requests no matter how similar their shapes are.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.block_size = 4;
        cfg.prefix_cache = PrefixCacheConfig::on();
        let mut e = Engine::simulated(cfg);
        for (id, arrival) in [(0u64, 0u64), (1, 20_000_000)] {
            e.enqueue(RequestSpec {
                prompt_tokens: Tokens(8),
                ..simple_spec(id, arrival, 1)
            });
        }
        e.run_until_idle(None);
        assert_eq!(e.metrics.completed(), 2);
        assert_eq!(e.metrics.prefix_hit_tokens, 0,
                   "no fabricated cross-request sharing");
        assert_eq!(e.metrics.tokens_prefilled, 16);
    }

    #[test]
    fn starving_promotion_survives_api_return() {
        // §4.4 parity: the `starving` promotion is sticky until
        // completion. A request promoted while queued behind a hog,
        // which then hits its API under Discard or Swap, must come back
        // from the call still promoted (an API return never demotes)
        // with its starvation counter sitting at the encounter-time
        // reset — regression for the fleet runs where the re-admission
        // happens on a replica mid-run.
        for strategy in [HandlingStrategy::Discard,
                         HandlingStrategy::Swap] {
            let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
            cfg.starvation_threshold = Some(2);
            let mut e = Engine::simulated(cfg);
            e.submit(simple_spec(0, 0, 8)); // hog: FCFS runs id 0 first
            e.submit_with_handling(api_spec(1, 2, 3, 1),
                                   vec![strategy]);
            // Drive manually to pin the mid-run state at the API call.
            while !e.request(RequestId(1)).unwrap().in_api_wait() {
                assert!(e.step(), "B must reach its API call");
            }
            let b = e.request(RequestId(1)).unwrap();
            assert!(b.starving,
                    "B must have been promoted before its API \
                     ({strategy:?})");
            assert_eq!(b.starvation_cnt, 0,
                       "§4.4 reset at the encounter ({strategy:?})");
            e.run_until_idle(None);
            let b = e.request(RequestId(1)).unwrap();
            assert!(b.is_finished(), "{strategy:?}");
            assert!(b.starving,
                    "the promotion must survive the {strategy:?} \
                     re-admission");
            assert_eq!(b.starvation_cnt, 0, "{strategy:?}");
            assert!(e.request(RequestId(0)).unwrap().is_finished());
            assert_eq!(e.metrics.completed(), 2, "{strategy:?}");
        }
    }

    #[test]
    fn adopt_restores_starvation_state_and_serves() {
        // The admission re-queue hands a withdrawn request to a sibling
        // via `adopt`: a §4.4 promotion (or partial progress toward
        // one) must survive the move instead of restarting from zero.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.starvation_threshold = Some(50);
        let mut e = Engine::simulated(cfg);
        e.adopt(WithdrawnRequest {
            spec: simple_spec(3, 0, 2),
            predictions: vec![SegmentPrediction {
                decode_tokens: Tokens(2),
                api_duration: None,
                response_tokens: Tokens(0),
            }],
            handling: vec![],
            starvation_cnt: 7,
            starving: true,
        });
        {
            let r = e.request(RequestId(3)).unwrap();
            assert!(r.starving, "promotion carried over");
            assert_eq!(r.starvation_cnt, 7, "counter carried over");
        }
        e.run_until_idle(None);
        let r = e.request(RequestId(3)).unwrap();
        assert!(r.is_finished());
        assert!(r.starving, "sticky until completion");
    }

    #[test]
    fn withdraw_waiting_removes_all_trace_and_refuses_ran() {
        // Withdrawal (the owner side of the admission re-queue) must
        // erase the request everywhere — queue, table, metrics — and
        // refuse requests that ever ran or hold replica-local state.
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit(simple_spec(0, 0, 3));
        e.submit(simple_spec(1, 0, 3));
        let w =
            e.withdraw_waiting(RequestId(1)).expect("never scheduled");
        assert_eq!(w.spec.id, RequestId(1));
        assert_eq!((w.starvation_cnt, w.starving), (0, false));
        assert_eq!(w.predictions.len(), w.spec.num_segments(),
                   "admission-time predictions cross the move");
        assert!(e.request(RequestId(1)).is_none(), "no table entry left");
        assert!(e.withdraw_waiting(RequestId(1)).is_none(), "gone");
        e.run_until_idle(None);
        // Only request 0 remains anywhere in the report.
        assert_eq!(e.metrics.report().submitted, 1);
        assert_eq!(e.metrics.completed(), 1);
        // A request that ran is not withdrawable (its KV and progress
        // are replica-local).
        assert!(e.withdraw_waiting(RequestId(0)).is_none());
    }

    #[test]
    fn external_api_call_parks_until_client_resolves() {
        // `--api-source external`: the engine parks the request with no
        // deadline — time alone can never finish it — until the client
        // posts the tool result, which also carries the true response
        // length. The predicted duration (oracle: the spec's 3 s) is
        // what the strategy choice and reservation planned with; the
        // actual park time (7 s) only becomes known at resolution, and
        // the gap lands in the error histogram.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.api_source = crate::config::ApiSourceKind::External;
        let mut e = Engine::simulated(cfg);
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Swap]);
        while !e.request(RequestId(0)).unwrap().in_api_wait() {
            assert!(e.step(), "must reach the API call");
        }
        assert_eq!(e.now(), Micros(2_000_000));
        assert_eq!(e.api.external_in_flight(), 1);
        // No deadline anywhere: stepping reports idle, not progress.
        assert!(!e.step(),
                "an unresolved external call is not a steppable event");
        assert!(e.has_live_work(),
                "...but the engine still owes the request");
        // The client answers 7 s later with a 2-token tool result
        // (the spec said 0 — the client's answer wins).
        e.advance_clock_to(Micros(9_000_000));
        e.complete_api_call(RequestId(0), 0, Tokens(2)).unwrap();
        e.run_until_idle(None);
        let r = e.request(RequestId(0)).unwrap();
        assert!(r.is_finished());
        // 2 decode + 7 parked + 2 response materialize + 1 decode.
        assert_eq!(r.finished_at, Some(Micros(12_000_000)));
        assert_eq!(r.logical_context, Tokens(5),
                   "2 decoded + 2 response + 1 final");
        // Predicted 3 s vs actual 7 s: relative error 4/3 → the
        // (100%, 200%] bucket.
        assert_eq!(e.metrics.api_calls_completed, 1);
        assert_eq!(e.metrics.api_pred_err_hist[4], 1);
        assert_eq!(e.metrics.api_pred_err_hist.iter().sum::<u64>(), 1);
        assert_eq!(e.metrics.api_pred_abs_err_us, 4_000_000);
    }

    #[test]
    fn complete_api_call_validates_target() {
        // Unknown ids, simulated calls, and wrong indices are protocol
        // errors, never routed returns.
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Preserve]);
        while !e.request(RequestId(0)).unwrap().in_api_wait() {
            assert!(e.step());
        }
        assert!(e.complete_api_call(RequestId(9), 0, Tokens(1)).is_err(),
                "unknown request");
        assert!(e.complete_api_call(RequestId(0), 0, Tokens(1)).is_err(),
                "a simulated call is not externally resolvable");
        e.run_until_idle(None);
        assert!(e.request(RequestId(0)).unwrap().is_finished(),
                "the simulated return still fires normally");
        assert!(e.complete_api_call(RequestId(0), 0, Tokens(1)).is_err(),
                "finished request is not in ApiWait");

        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.api_source = crate::config::ApiSourceKind::External;
        let mut e = Engine::simulated(cfg);
        e.submit_with_handling(api_spec(1, 1, 3, 1),
                               vec![HandlingStrategy::Preserve]);
        while !e.request(RequestId(1)).unwrap().in_api_wait() {
            assert!(e.step());
        }
        assert!(e.complete_api_call(RequestId(1), 1, Tokens(0)).is_err(),
                "parked on call 0, not 1");
        e.complete_api_call(RequestId(1), 0, Tokens(0)).unwrap();
        assert!(e.complete_api_call(RequestId(1), 0, Tokens(0)).is_err(),
                "a return fires exactly once");
        e.run_until_idle(None);
        assert!(e.request(RequestId(1)).unwrap().is_finished());
    }

    #[test]
    fn abort_external_call_frees_everything() {
        // The disconnect/timeout backstop: a Preserve-parked external
        // call pins KV blocks that only the client's answer would
        // release; aborting it must drop the request terminally, free
        // the memory for siblings, and journal the reason.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 100);
        cfg.api_source = crate::config::ApiSourceKind::External;
        let mut e = Engine::simulated(cfg);
        e.enable_events();
        e.submit_with_handling(
            RequestSpec {
                prompt_tokens: Tokens(8),
                ..api_spec(0, 2, 3, 1)
            },
            vec![HandlingStrategy::Preserve]);
        while !e.request(RequestId(0)).unwrap().in_api_wait() {
            assert!(e.step());
        }
        assert!(e.kv_occupancy() > 0.0, "Preserve holds KV while parked");
        // Not abortable: wrong id, and (below) non-external calls.
        assert!(!e.abort_external_call(RequestId(9), "x".to_string()));
        assert!(e.abort_external_call(
            RequestId(0), "client disconnected".to_string()));
        assert!(!e.abort_external_call(RequestId(0), "x".to_string()),
                "an abort fires exactly once");
        assert_eq!(e.kv_occupancy(), 0.0, "all holdings freed");
        assert_eq!(e.dropped, vec![RequestId(0)]);
        assert!(!e.has_live_work(), "nothing left in flight");
        assert!(e.drain_events().iter().any(|ev| matches!(
            ev,
            EngineEvent::Dropped { id, reason }
                if *id == RequestId(0)
                    && reason.contains("disconnected"))));
        // A simulated call is never abortable this way.
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.submit_with_handling(api_spec(1, 1, 3, 1),
                               vec![HandlingStrategy::Preserve]);
        while !e.request(RequestId(1)).unwrap().in_api_wait() {
            assert!(e.step());
        }
        assert!(!e.abort_external_call(RequestId(1), "x".to_string()));
        e.run_until_idle(None);
        assert!(e.request(RequestId(1)).unwrap().is_finished());
    }

    #[test]
    fn event_journal_records_lifecycle_in_causal_order() {
        use EngineEvent as E;
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 100));
        e.enable_events();
        e.submit_with_handling(api_spec(0, 2, 3, 1),
                               vec![HandlingStrategy::Preserve]);
        e.run_until_idle(None);
        let id = RequestId(0);
        assert_eq!(e.drain_events(), vec![
            E::FirstToken { id, at: Micros(1_000_000) },
            E::Tokens { id, chunk: 2 },
            E::ApiStarted {
                id,
                index: 0,
                strategy: HandlingStrategy::Preserve,
                predicted: Micros(3_000_000),
                external: false,
            },
            E::ApiCompleted {
                id,
                index: 0,
                actual: Micros(3_000_000),
            },
            E::Tokens { id, chunk: 1 },
            E::Finished { id, at: Micros(6_000_000) },
        ]);
        assert!(e.drain_events().is_empty(), "drain takes everything");
    }

    #[test]
    fn events_are_off_by_default_and_observation_free() {
        let run = |events: bool| {
            let mut e =
                Engine::simulated(unit_cfg(SchedulerKind::Lamps, 50));
            if events {
                e.enable_events();
            }
            for i in 0..5 {
                e.submit(api_spec(i, 2, 2, 2));
            }
            e.run_until_idle(None);
            (e.drain_events().len(), e.metrics.report().to_json(true))
        };
        let (n_off, off) = run(false);
        let (n_on, on) = run(true);
        assert_eq!(n_off, 0, "journal must stay empty unless armed");
        assert!(n_on > 0, "armed journal must record");
        assert_eq!(off, on, "observation must not perturb the run");
    }

    #[test]
    fn fail_fast_drop_journals_a_reason() {
        let mut e = Engine::simulated(unit_cfg(SchedulerKind::Fcfs, 4));
        e.enable_events();
        e.submit(RequestSpec {
            prompt_tokens: Tokens(10),
            ..simple_spec(0, 0, 1)
        });
        let evs = e.drain_events();
        assert_eq!(evs.len(), 1);
        let EngineEvent::Dropped { id, reason } = &evs[0] else {
            panic!("expected Dropped, got {evs:?}");
        };
        assert_eq!(*id, RequestId(0));
        assert!(reason.contains("capacity"), "{reason}");
    }

    #[test]
    fn auto_chunk_bounds_stall_from_t_iter_ema() {
        // Same shape as chunked_prefill_bounds_co_batched_stall, but
        // the chunk is derived from the profiled t_iter EMA: 1 ms
        // iterations over 1 ms-per-token prefill target a 1-token
        // chunk, clamped to the 16-token floor — so no round may
        // exceed one decode (1 ms) plus one 16-token chunk (16 ms),
        // against 65 ms unchunked.
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 1000);
        cfg.max_batch = 4;
        cfg.cost = CostModel {
            decode_base: Micros(1_000),
            decode_per_ctx_token_us: 0.0,
            prefill_per_token_us: 1_000.0,
            swap_base_us: 0.0,
            swap_per_token_us: 0.0,
            rank_overhead_per_request_us: 0.0,
        };
        cfg.compose.auto_chunk = true;
        let mut e = Engine::simulated(cfg);
        e.submit(simple_spec(0, 0, 100));
        e.submit(RequestSpec {
            prompt_tokens: Tokens(64),
            ..simple_spec(1, 0, 1)
        });
        let mut max_step = Micros::ZERO;
        loop {
            let before = e.now();
            if !e.step() {
                break;
            }
            let d = e.now() - before;
            if d > max_step {
                max_step = d;
            }
        }
        assert!(e.request(RequestId(0)).unwrap().is_finished());
        assert!(e.request(RequestId(1)).unwrap().is_finished());
        assert!(max_step <= Micros(17_000),
                "auto-chunked worst round was {max_step}");
    }

    #[test]
    fn token_budget_defers_prefill_but_completes() {
        let mut cfg = unit_cfg(SchedulerKind::Fcfs, 500);
        cfg.max_batch = 8;
        cfg.compose.max_batch_tokens = Some(16);
        cfg.compose.prefill_chunk = Some(8);
        let mut e = Engine::simulated(cfg);
        for i in 0..3 {
            e.submit(RequestSpec {
                prompt_tokens: Tokens(40),
                ..simple_spec(i, 0, 2)
            });
        }
        e.run_until_idle(None);
        assert_eq!(e.metrics.completed(), 3);
        assert_eq!(e.kv_occupancy(), 0.0);
        assert_eq!(e.metrics.tokens_decoded, 6);
    }
}
