//! In-flight API call tracking: the simulated external-API substrate
//! (DESIGN.md §2 — real augmentation services are replaced by their
//! published latency distributions; the true per-call duration is sampled
//! by the workload generator and carried in the spec).
//!
//! Keeps a min-heap of (return_at, request) plus per-strategy membership
//! (Algorithm 1's PQueue / DQueue / SQueue).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::core::request::HandlingStrategy;
use crate::core::types::{Micros, RequestId};

#[derive(Debug, Default)]
pub struct ApiExecutor {
    heap: BinaryHeap<Reverse<(Micros, RequestId)>>,
    /// Counts per strategy (PQueue/DQueue/SQueue sizes, for metrics).
    preserve: usize,
    discard: usize,
    swap: usize,
}

impl ApiExecutor {
    pub fn new() -> ApiExecutor {
        ApiExecutor::default()
    }

    /// Begin an API call for `id`, returning at `return_at`, held under
    /// `strategy`.
    pub fn begin(&mut self, id: RequestId, return_at: Micros,
                 strategy: HandlingStrategy) {
        self.heap.push(Reverse((return_at, id)));
        match strategy {
            HandlingStrategy::Preserve => self.preserve += 1,
            HandlingStrategy::Discard => self.discard += 1,
            HandlingStrategy::Swap => self.swap += 1,
        }
    }

    /// Earliest pending return time.
    pub fn next_return(&self) -> Option<Micros> {
        self.heap.peek().map(|Reverse((t, _))| *t)
    }

    /// Pop every call that has returned by `now`.
    pub fn drain_returned(&mut self, now: Micros,
                          mut on_return: impl FnMut(RequestId)) {
        while let Some(Reverse((t, _))) = self.heap.peek() {
            if *t > now {
                break;
            }
            let Reverse((_, id)) = self.heap.pop().unwrap();
            on_return(id);
        }
    }

    /// Caller must tell us which strategy the drained request was held
    /// under so queue counts stay accurate.
    pub fn note_returned(&mut self, strategy: HandlingStrategy) {
        match strategy {
            HandlingStrategy::Preserve => self.preserve -= 1,
            HandlingStrategy::Discard => self.discard -= 1,
            HandlingStrategy::Swap => self.swap -= 1,
        }
    }

    pub fn in_flight(&self) -> usize {
        self.heap.len()
    }

    pub fn queue_sizes(&self) -> (usize, usize, usize) {
        (self.preserve, self.discard, self.swap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_in_time_order() {
        let mut ex = ApiExecutor::new();
        ex.begin(RequestId(1), Micros(300), HandlingStrategy::Preserve);
        ex.begin(RequestId(2), Micros(100), HandlingStrategy::Discard);
        ex.begin(RequestId(3), Micros(200), HandlingStrategy::Swap);
        assert_eq!(ex.next_return(), Some(Micros(100)));
        let mut order = Vec::new();
        ex.drain_returned(Micros(250), |id| order.push(id));
        assert_eq!(order, vec![RequestId(2), RequestId(3)]);
        assert_eq!(ex.in_flight(), 1);
        assert_eq!(ex.next_return(), Some(Micros(300)));
    }

    #[test]
    fn queue_counts() {
        let mut ex = ApiExecutor::new();
        ex.begin(RequestId(1), Micros(10), HandlingStrategy::Preserve);
        ex.begin(RequestId(2), Micros(20), HandlingStrategy::Preserve);
        ex.begin(RequestId(3), Micros(30), HandlingStrategy::Swap);
        assert_eq!(ex.queue_sizes(), (2, 0, 1));
        ex.drain_returned(Micros(15), |_| {});
        ex.note_returned(HandlingStrategy::Preserve);
        assert_eq!(ex.queue_sizes(), (1, 0, 1));
    }

    #[test]
    fn empty_is_idle() {
        let mut ex = ApiExecutor::new();
        assert_eq!(ex.next_return(), None);
        let mut called = false;
        ex.drain_returned(Micros(1_000_000), |_| called = true);
        assert!(!called);
    }
}
