//! In-flight API call tracking — the engine-side half of the
//! [`ApiSource`](crate::config::ApiSourceKind) seam.
//!
//! Two kinds of call coexist:
//! - **Simulated** (DESIGN.md §2 — real augmentation services replaced
//!   by their published latency distributions): the true per-call
//!   duration is sampled by the workload generator, so the call carries
//!   a known deadline and sits in a min-heap of `(return_at, request)`.
//! - **External**: the *client* runs the tool, so nobody knows the
//!   return time. The call sits in an externally-resolvable set until
//!   [`ApiExecutor::resolve_external`] fires it (driven by a
//!   `tool_result` wire frame). `next_return` never covers these —
//!   idle-jump logic must not assume the earliest heap deadline bounds
//!   the wait.
//!
//! Per-strategy membership counts (Algorithm 1's PQueue / DQueue /
//! SQueue) span both kinds.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use crate::core::request::HandlingStrategy;
use crate::core::types::{Micros, RequestId};

#[derive(Debug, Default)]
pub struct ApiExecutor {
    heap: BinaryHeap<Reverse<(Micros, RequestId)>>,
    /// Calls with no known deadline, resolved only by the client
    /// (`--api-source external`).
    external: HashSet<RequestId>,
    /// Counts per strategy (PQueue/DQueue/SQueue sizes, for metrics).
    preserve: usize,
    discard: usize,
    swap: usize,
}

impl ApiExecutor {
    pub fn new() -> ApiExecutor {
        ApiExecutor::default()
    }

    /// Begin an API call for `id`, held under `strategy`. A
    /// `Some(return_at)` deadline is a simulated call (heap); `None`
    /// parks it in the externally-resolvable set until
    /// [`ApiExecutor::resolve_external`].
    pub fn begin(&mut self, id: RequestId, return_at: Option<Micros>,
                 strategy: HandlingStrategy) {
        match return_at {
            Some(t) => {
                self.heap.push(Reverse((t, id)));
            }
            None => {
                self.external.insert(id);
            }
        }
        match strategy {
            HandlingStrategy::Preserve => self.preserve += 1,
            HandlingStrategy::Discard => self.discard += 1,
            HandlingStrategy::Swap => self.swap += 1,
        }
    }

    /// Earliest pending *simulated* return time. Externally-resolved
    /// calls have no deadline and never surface here — with
    /// `external_in_flight() > 0` this being `None` (or far off) does
    /// **not** bound how soon work may arrive.
    pub fn next_return(&self) -> Option<Micros> {
        self.heap.peek().map(|Reverse((t, _))| *t)
    }

    /// Pop every simulated call that has returned by `now`.
    pub fn drain_returned(&mut self, now: Micros,
                          mut on_return: impl FnMut(RequestId)) {
        while self
            .heap
            .peek()
            .is_some_and(|Reverse((t, _))| *t <= now)
        {
            let Some(Reverse((_, id))) = self.heap.pop() else { break };
            on_return(id);
        }
    }

    /// Fire an externally-resolved call's return (the client's
    /// `tool_result` arrived). Returns false if `id` has no pending
    /// external call — the caller must treat that as a protocol error,
    /// not route a return.
    pub fn resolve_external(&mut self, id: RequestId) -> bool {
        self.external.remove(&id)
    }

    /// Is `id` parked as an externally-resolved call?
    pub fn is_external(&self, id: RequestId) -> bool {
        self.external.contains(&id)
    }

    /// Every call currently parked in the externally-resolvable set
    /// (the timeout sweep's scan list — it must see orphaned requests
    /// whose session is already gone, so it cannot be driven off any
    /// session map).
    pub fn external_ids(&self) -> Vec<RequestId> {
        self.external.iter().copied().collect()
    }

    /// Caller must tell us which strategy the drained request was held
    /// under so queue counts stay accurate.
    pub fn note_returned(&mut self, strategy: HandlingStrategy) {
        match strategy {
            HandlingStrategy::Preserve => self.preserve -= 1,
            HandlingStrategy::Discard => self.discard -= 1,
            HandlingStrategy::Swap => self.swap -= 1,
        }
    }

    pub fn in_flight(&self) -> usize {
        self.heap.len() + self.external.len()
    }

    /// Externally-resolvable calls currently parked.
    pub fn external_in_flight(&self) -> usize {
        self.external.len()
    }

    pub fn queue_sizes(&self) -> (usize, usize, usize) {
        (self.preserve, self.discard, self.swap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_in_time_order() {
        let mut ex = ApiExecutor::new();
        ex.begin(RequestId(1), Some(Micros(300)),
                 HandlingStrategy::Preserve);
        ex.begin(RequestId(2), Some(Micros(100)),
                 HandlingStrategy::Discard);
        ex.begin(RequestId(3), Some(Micros(200)), HandlingStrategy::Swap);
        assert_eq!(ex.next_return(), Some(Micros(100)));
        let mut order = Vec::new();
        ex.drain_returned(Micros(250), |id| order.push(id));
        assert_eq!(order, vec![RequestId(2), RequestId(3)]);
        assert_eq!(ex.in_flight(), 1);
        assert_eq!(ex.next_return(), Some(Micros(300)));
    }

    #[test]
    fn queue_counts() {
        let mut ex = ApiExecutor::new();
        ex.begin(RequestId(1), Some(Micros(10)),
                 HandlingStrategy::Preserve);
        ex.begin(RequestId(2), Some(Micros(20)),
                 HandlingStrategy::Preserve);
        ex.begin(RequestId(3), Some(Micros(30)), HandlingStrategy::Swap);
        assert_eq!(ex.queue_sizes(), (2, 0, 1));
        ex.drain_returned(Micros(15), |_| {});
        ex.note_returned(HandlingStrategy::Preserve);
        assert_eq!(ex.queue_sizes(), (1, 0, 1));
    }

    #[test]
    fn empty_is_idle() {
        let mut ex = ApiExecutor::new();
        assert_eq!(ex.next_return(), None);
        let mut called = false;
        ex.drain_returned(Micros(1_000_000), |_| called = true);
        assert!(!called);
    }

    #[test]
    fn external_calls_have_no_deadline_and_resolve_once() {
        let mut ex = ApiExecutor::new();
        ex.begin(RequestId(7), None, HandlingStrategy::Swap);
        ex.begin(RequestId(8), Some(Micros(500)),
                 HandlingStrategy::Preserve);
        // The heap deadline does not cover the external call.
        assert_eq!(ex.next_return(), Some(Micros(500)));
        assert_eq!(ex.in_flight(), 2);
        assert_eq!(ex.external_in_flight(), 1);
        assert!(ex.is_external(RequestId(7)));
        assert!(!ex.is_external(RequestId(8)));
        // Time passing never fires it...
        let mut fired = Vec::new();
        ex.drain_returned(Micros(1_000_000_000), |id| fired.push(id));
        assert_eq!(fired, vec![RequestId(8)]);
        // ...only resolution does, and exactly once.
        assert!(ex.resolve_external(RequestId(7)));
        ex.note_returned(HandlingStrategy::Swap);
        assert!(!ex.resolve_external(RequestId(7)), "second fire refused");
        assert_eq!(ex.in_flight(), 0);
        assert_eq!(ex.external_in_flight(), 0);
    }

    #[test]
    fn resolve_unknown_id_refused() {
        let mut ex = ApiExecutor::new();
        ex.begin(RequestId(1), Some(Micros(10)),
                 HandlingStrategy::Preserve);
        // A simulated call is not externally resolvable.
        assert!(!ex.resolve_external(RequestId(1)));
        assert!(!ex.resolve_external(RequestId(99)));
        assert_eq!(ex.in_flight(), 1);
    }
}
