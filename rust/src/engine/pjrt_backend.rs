//! Real-compute backend: token generation through the AOT-compiled HLO
//! executables on the PJRT CPU client.
//!
//! Each live request owns a compact per-request KV buffer
//! (L, S, H, D) host-side plus its token history. For every prefill /
//! decode call the backend packs up to `B` requests into the executable's
//! fixed-shape batch tensors and merges the updated slices back. Elapsed
//! times are measured wall-clock, so the engine's metrics reflect real
//! compute.
//!
//! Control lengths (segment boundaries, API trigger points) remain
//! spec-driven so traces stay comparable with the simulator; the token
//! *values* are the model's real greedy outputs and are retrievable via
//! [`PjrtBackend::generated_tokens`].

use std::collections::HashMap;
use std::time::Instant;

use crate::core::types::{Micros, RequestId, Tokens};
use crate::engine::backend::{Backend, DecodeSlot};
use crate::runtime::ModelRuntime;
use crate::util::tokenizer;

/// Filler token used when a request's logical context outgrows its known
/// token history (synthetic API-response tokens).
const FILLER_TOKEN: i32 = 5;

struct RequestState {
    /// Token ids whose KV entries are materialized (history[..kv_len]).
    history: Vec<i32>,
    /// Compact (L, S, H, D) caches.
    k: Vec<f32>,
    v: Vec<f32>,
    /// Tokens of `history` covered by the caches.
    kv_len: usize,
    /// Model-generated tokens (for inspection).
    generated: Vec<i32>,
    /// Next token to feed the decoder.
    last_token: i32,
}

pub struct PjrtBackend {
    model: ModelRuntime,
    states: HashMap<RequestId, RequestState>,
    /// Generated-token histories of released (finished) requests, kept so
    /// callers can fetch outputs after completion.
    finished: HashMap<RequestId, Vec<i32>>,
    max_context_margin: u64,
}

impl PjrtBackend {
    pub fn new(model: ModelRuntime) -> PjrtBackend {
        PjrtBackend {
            model,
            states: HashMap::new(),
            finished: HashMap::new(),
            max_context_margin: 2,
        }
    }

    pub fn model(&self) -> &ModelRuntime {
        &self.model
    }

    /// Real token ids the model produced for `id` so far (live or
    /// finished).
    pub fn generated_tokens(&self, id: RequestId) -> Option<&[i32]> {
        self.states
            .get(&id)
            .map(|s| s.generated.as_slice())
            .or_else(|| self.finished.get(&id).map(|v| v.as_slice()))
    }

    fn state_entry(&mut self, id: RequestId) -> &mut RequestState {
        let model = &self.model;
        // Reclaim any generated history parked by a previous release
        // (Discard drops device state, not the token record).
        let parked = self.finished.remove(&id).unwrap_or_default();
        self.states.entry(id).or_insert_with(|| RequestState {
            history: Vec::new(),
            k: model.zero_kv_slot(),
            v: model.zero_kv_slot(),
            kv_len: 0,
            generated: parked,
            last_token: tokenizer::BOS_ID,
        })
    }
}

impl Backend for PjrtBackend {
    fn slot_capacity(&self) -> Option<usize> {
        Some(self.model.meta.batch)
    }

    fn max_context(&self) -> Option<u64> {
        Some(self.model.meta.max_seq as u64 - self.max_context_margin)
    }

    /// Per-request state is created by `materialize` (fixed executable
    /// slots, whole-history re-prefill); decoding a sequence this
    /// backend never materialized would panic. The engine therefore
    /// must not skip prefill on prefix-cache hits here.
    fn supports_prefix_reuse(&self) -> bool {
        false
    }

    fn materialize(&mut self, id: RequestId, prompt: &str,
                   total_ctx: Tokens, _increment: Tokens) -> Micros {
        let ctx = total_ctx;
        // lamps-lint: allow(wall-clock) real PJRT step timing is the measurement, not the clock
        let start = Instant::now();
        let max_seq = self.model.meta.max_seq;
        {
            let state = self.state_entry(id);
            // (Re)build the token history to the requested context size:
            // prompt tokens, then whatever the model generated, then
            // filler standing in for API-response tokens.
            let mut history: Vec<i32> = Vec::new();
            if !prompt.is_empty() {
                let n = tokenizer::valid_len(prompt, max_seq);
                // lamps-lint: allow(panic) valid_len bounds n to the encoded length
                history.extend(&tokenizer::encode(prompt, max_seq)[..n]);
            }
            let mut gen_iter = state.generated.iter().copied();
            while history.len() < ctx.0 as usize {
                history.push(gen_iter.next().unwrap_or(FILLER_TOKEN));
            }
            history.truncate((ctx.0 as usize).min(max_seq));
            state.history = history;
        }

        // Pack into slot 0 of the batch and prefill.
        let b = self.model.meta.batch;
        let mut tokens = vec![tokenizer::PAD_ID; b * max_seq];
        let mut lengths = vec![0i32; b];
        // lamps-lint: allow(panic) materialize creates the state entry for every live id
        let state = &self.states[&id];
        let n = state.history.len().max(1);
        let mut history = state.history.clone();
        if history.is_empty() {
            history.push(tokenizer::BOS_ID);
        }
        // lamps-lint: allow(panic) n <= history.len() and tokens spans batch * max_seq
        tokens[..n].copy_from_slice(&history[..n]);
        // lamps-lint: allow(panic) batch size is at least one slot
        lengths[0] = n as i32;
        let result = self
            .model
            .run_prefill(&tokens, &lengths)
            // lamps-lint: allow(panic) a failed PJRT execution is unrecoverable on this backend
            .expect("prefill execution");
        // lamps-lint: allow(panic) materialize creates the state entry for every live id
        let state = self.states.get_mut(&id).unwrap();
        state.k = self.model.extract_slot(&result.k, 0);
        state.v = self.model.extract_slot(&result.v, 0);
        state.kv_len = n;
        // lamps-lint: allow(panic) run_prefill returns one next-token per slot
        state.last_token = result.next_tokens[0];
        Micros(start.elapsed().as_micros() as u64)
    }

    fn decode(&mut self, batch: &[DecodeSlot]) -> Micros {
        if batch.is_empty() {
            return Micros::ZERO;
        }
        // lamps-lint: allow(wall-clock) real PJRT step timing is the measurement, not the clock
        let start = Instant::now();
        let b = self.model.meta.batch;
        assert!(batch.len() <= b, "engine must respect slot_capacity");

        let mut token = vec![tokenizer::PAD_ID; b];
        let mut pos = vec![0i32; b];
        let mut k = self.model.zero_kv();
        let mut v = self.model.zero_kv();
        for (slot, ds) in batch.iter().enumerate() {
            // lamps-lint: allow(panic) materialize creates the state entry for every live id
            let state = &self.states[&ds.id];
            // lamps-lint: allow(panic) slot < batch.len() <= b by the assert above
            token[slot] = state.last_token;
            // lamps-lint: allow(panic) slot < batch.len() <= b by the assert above
            pos[slot] =
                (state.kv_len as i32).min(self.model.meta.max_seq as i32 - 1);
            self.model.insert_slot(&mut k, slot, &state.k);
            self.model.insert_slot(&mut v, slot, &state.v);
        }
        let result = self
            .model
            .run_decode(&token, &pos, &k, &v)
            // lamps-lint: allow(panic) a failed PJRT execution is unrecoverable on this backend
            .expect("decode execution");
        for (slot, ds) in batch.iter().enumerate() {
            let new_k = self.model.extract_slot(&result.k, slot);
            let new_v = self.model.extract_slot(&result.v, slot);
            // lamps-lint: allow(panic) materialize creates the state entry for every live id
            let state = self.states.get_mut(&ds.id).unwrap();
            state.k = new_k;
            state.v = new_v;
            // lamps-lint: allow(panic) run_decode returns one next-token per slot
            let tok = result.next_tokens[slot];
            state.history.push(state.last_token);
            state.kv_len = (state.kv_len + 1).min(self.model.meta.max_seq);
            state.generated.push(tok);
            state.last_token = tok;
        }
        Micros(start.elapsed().as_micros() as u64)
    }

    fn swap_out(&mut self, _id: RequestId, _ctx: Tokens) -> Micros {
        // KV already lives host-side in this CPU deployment; the "swap"
        // is a bookkeeping move. A GPU/TPU deployment would transfer the
        // compact buffers here.
        Micros::ZERO
    }

    fn swap_in(&mut self, _id: RequestId, _ctx: Tokens) -> Micros {
        Micros::ZERO
    }

    fn release(&mut self, id: RequestId) {
        if let Some(state) = self.states.remove(&id) {
            if !state.generated.is_empty() {
                self.finished
                    .entry(id)
                    .or_default()
                    .extend(state.generated);
            }
        }
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

impl ModelRuntime {
    /// Compact per-request KV buffer (L, S, H, D), zeroed.
    pub fn zero_kv_slot(&self) -> Vec<f32> {
        vec![0.0; self.meta.n_layers * self.slot_stride()]
    }
}
