//! `lamps` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//! - `serve`        JSON-lines TCP serving on the real PJRT model backend
//! - `run`          run a dataset/trace through the simulator, print report
//! - `gen-workload` write a synthetic dataset to a JSON trace file
//! - `predict`      score a prompt with the AOT predictor
//! - `info`         artifact + runtime environment report
//!
//! Argument parsing is hand-rolled (`--key value` pairs) — the offline
//! vendor set has no clap.

use anyhow::Result;

use lamps::bench::{Dataset, ModelPreset};
use lamps::cluster::ReplicaSet;
use lamps::config::{ApiPredKind, ApiSourceKind, AuditMode,
                    AutoscaleConfig, NetModelKind, PlacementKind,
                    SystemConfig};
use lamps::core::types::Micros;
#[cfg(feature = "pjrt")]
use lamps::engine::pjrt_backend::PjrtBackend;
use lamps::engine::Engine;
#[cfg(feature = "pjrt")]
use lamps::predictor::opt_classifier::PjrtPredictor;
#[cfg(feature = "pjrt")]
use lamps::runtime::{ArtifactMeta, ModelRuntime, PredictorRuntime,
                     RuntimeClient};
use lamps::workload::Trace;

const USAGE: &str = "\
lamps — LAMPS: predictive scheduling for augmented-LLM serving

USAGE:
  lamps serve   [--addr 127.0.0.1:7070] [--model gptj-tiny]
                [--system lamps] [--artifacts artifacts]
                [--api-source sim|external]
                [--api-pred static|learned]
                [--replicas N]
                [--placement memory-over-time|prefix-affinity|
                             least-loaded|round-robin]
                [--max-batch-tokens N] [--prefill-chunk N|auto]
                [--async-swap]
                [--prefix-cache] [--prefix-cache-blocks N]
                [--shared-prefix] [--no-admission-requeue]
                [--net-model off|lan|wan] [--gossip-interval MS]
                [--staleness-budget MS] [--net-topk K]
                [--autoscale MIN:MAX]
                [--audit] [--placement-cache on|off]
  lamps run     [--dataset single-api|multi-api|toolbench|<trace.json>]
                [--system vllm|infercept|lamps|lamps-no-sched|sjf|sjf-total]
                [--model gptj-6b|vicuna-13b] [--rate 3.0]
                [--requests 500] [--seed 42] [--time-cap-secs N]
                [--api-pred static|learned]
                [--replicas N]
                [--placement memory-over-time|prefix-affinity|
                             least-loaded|round-robin]
                [--max-batch-tokens N] [--prefill-chunk N|auto]
                [--async-swap]
                [--prefix-cache] [--prefix-cache-blocks N]
                [--shared-prefix] [--no-admission-requeue]
                [--net-model off|lan|wan] [--gossip-interval MS]
                [--staleness-budget MS] [--net-topk K]
                [--autoscale MIN:MAX]
                [--audit] [--placement-cache on|off] [--timeline]
  lamps gen-workload --out trace.json [--dataset single-api] [--rate 3.0]
                [--requests 500] [--seed 42]
  lamps predict <prompt> [--artifacts artifacts]
  lamps info    [--artifacts artifacts]

WIRE PROTOCOL (serve; JSON lines over TCP, one frame per line):
  -> {\"type\":\"request\", \"prompt\":\"...\", \"output_tokens\":N,
      \"api_calls\":[{\"decode_before\":N, \"api_type\":\"qa\",
                      \"api_ms\":N, \"response_tokens\":N}, ...]}
     opens an event-streaming session; api_type is one of
     math|qa|ve|chatbot|image|tts|tool, api_ms defaults to the class's
     Table 2 mean, response_tokens to 4. A line with no \"type\" field
     is a legacy v1 one-shot request ({\"prompt\", \"output_tokens\",
     \"pre_api_tokens\", \"api_ms\"}) answered by one completion line.
  <- event frames, each with \"type\" and the session \"id\": queued,
     placed{replica}, rescued{from,to}, first_token, tokens{chunk},
     api_call_started{index,strategy,predicted_us,external},
     api_call_completed{index,actual_us}, finished{...completion...},
     dropped{reason}, error{error}.
  -> {\"type\":\"tool_result\", \"id\":N, \"index\":N,
      \"response_tokens\":N}
     resolves an externally-held call (--api-source external: the
     client runs the tool; the engine parks the request under the
     strategy chosen from the predicted duration until this arrives).
  -> {\"type\":\"cancel\", \"id\":N}
     reserved: parses today and is acknowledged with a session-scoped
     error frame while the session keeps streaming; teardown lands in
     a later revision.
  See examples/protocol_v2.ndjson for a worked transcript.

  --api-source sim (default) simulates API durations server-side and
  is byte-identical to the pre-session engine; external hands every
  API call to the client. --api-pred static (default) feeds the
  scheduler raw per-call duration estimates and is byte-identical to
  the pre-seam engine; learned revises every estimate through
  per-API-class online estimators (EWMA mean + windowed quantiles,
  updated from observed outcomes) that blend toward a conservative
  class quantile when observed prediction error runs hot, and reports
  the estimator state as api_pred_model in the metrics JSON. --prefill-chunk auto derives the chunk size
  from the profiled decode-iteration time (target: chunk forward time
  = one decode iteration). --replicas N dispatches across N engine
  replicas (one modeled GPU each); --placement picks how arrivals are
  placed: memory-over-time (default; the LAMPS rank integral steers
  placement), prefix-affinity (the integral with its prefill leg
  discounted on replicas already holding the arrival's prompt prefix —
  pair with --prefix-cache and --shared-prefix), least-loaded, or
  round-robin. --shared-prefix maintains the fleet-level hash→replica
  prefix index those discounts come from. A request memory-rejected by
  its owner before first run is re-queued once to the best sibling
  unless --no-admission-requeue. With --replicas 1 the single-engine
  path runs unchanged. --net-model off (default) keeps the fleet on
  the exact sequential coordination path, byte-identical to the
  network-less engine; lan|wan arms a deterministic simulated network
  (seeded per-link delays) that gossip-lags the shared prefix index
  on the --gossip-interval cadence (ms; default 5) and feeds
  placement/rescue from bounded-staleness per-replica load digests
  (--staleness-budget ms, default 50; --net-topk shortlist width,
  default 4) — a stale steer costs a measured re-prefill
  (stale_steer_* metrics), never an error. --autoscale MIN:MAX (needs
  a modeled network) drives an elastic replica count between the
  bounds: parked replicas warm up under backlog with their prefix
  cache pre-seeded from the busiest sibling, and idle replicas drain
  and decommission when pressure falls. --audit re-checks the engine/fleet invariants
  (block conservation, prefix refcounts, queue order, event
  causality) after every step and aborts on the first violation —
  always on in debug builds, opt-in here for release builds.
  --placement-cache off disables the epoch-keyed placement-score cache
  (each engine memoizes its memory-over-time load aggregate between
  mutations; placement decisions are byte-identical either way, so off
  exists only as an escape hatch and for A/B benchmarking).
";

/// Tiny `--key value` argument map (no clap in the offline vendor set).
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags: next token missing or another --flag
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_dataset(name: &str) -> Option<Dataset> {
    match name {
        "single-api" => Some(Dataset::SingleApi),
        "multi-api" => Some(Dataset::MultiApi),
        "toolbench" => Some(Dataset::ToolBench),
        _ => None,
    }
}

fn parse_model(name: &str) -> ModelPreset {
    match name {
        "vicuna-13b" => ModelPreset::Vicuna13b,
        _ => ModelPreset::GptJ6b,
    }
}

/// Apply the batch-composer flags (`--max-batch-tokens`,
/// `--prefill-chunk [N|auto]`, `--async-swap`) to a config.
fn apply_compose_flags(cfg: &mut SystemConfig, args: &Args) {
    if let Some(budget) = args.flags.get("max-batch-tokens") {
        cfg.compose.max_batch_tokens = budget.parse().ok();
    }
    if let Some(chunk) = args.flags.get("prefill-chunk") {
        if chunk == "auto" {
            // Derive the chunk from the profiled t_iter EMA each
            // iteration (chunk forward time ≈ one decode iteration).
            cfg.compose.auto_chunk = true;
        } else {
            match chunk.parse() {
                Ok(n) => cfg.compose.prefill_chunk = Some(n),
                Err(_) => eprintln!(
                    "lamps: ignoring unparseable --prefill-chunk \
                     '{chunk}' (expected a token count or 'auto')"),
            }
        }
    }
    if args.has("async-swap") {
        cfg.compose.async_swap = true;
    }
}

/// Apply `--api-source sim|external`. External means the client runs
/// every API call and posts `tool_result` frames back, so it is only
/// meaningful under `serve` — `run` has no client to resolve the calls
/// and rejects it.
fn apply_api_source_flag(cfg: &mut SystemConfig, args: &Args,
                         serving: bool) -> Result<()> {
    if let Some(name) = args.flags.get("api-source") {
        let kind = ApiSourceKind::parse(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown api source '{name}' (expected sim or external)")
        })?;
        if kind == ApiSourceKind::External && !serving {
            anyhow::bail!(
                "--api-source external needs a client to resolve tool \
                 calls; it is only available under `lamps serve`");
        }
        cfg.api_source = kind;
    }
    Ok(())
}

/// Apply `--api-pred static|learned`: the API-duration seam mode
/// (static = pass-through, byte-identical to the pre-seam engine;
/// learned = per-class online estimators revising every estimate).
fn apply_api_pred_flag(cfg: &mut SystemConfig, args: &Args)
                       -> Result<()> {
    if let Some(name) = args.flags.get("api-pred") {
        cfg.api_pred = ApiPredKind::parse(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown api pred mode '{name}' (expected static or \
                 learned)")
        })?;
    }
    Ok(())
}

/// Apply the multi-replica flags: `--replicas N` sizes the
/// [`ReplicaSet`]; `--placement` picks the cross-replica placement
/// policy (memory-over-time by default); `--shared-prefix` maintains
/// the fleet-level prefix index prefix-affinity placement probes;
/// `--no-admission-requeue` disables the one-shot sibling re-queue of
/// memory-rejected arrivals.
fn apply_replica_flags(cfg: &mut SystemConfig, args: &Args)
                       -> Result<()> {
    cfg.replicas = args.get_usize("replicas", cfg.replicas).max(1);
    if let Some(name) = args.flags.get("placement") {
        cfg.placement = PlacementKind::parse(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown placement '{name}' (expected memory-over-time, \
                 prefix-affinity, least-loaded, or round-robin)")
        })?;
    }
    if args.has("shared-prefix") {
        cfg.shared_prefix = true;
    }
    if args.has("no-admission-requeue") {
        cfg.admission_requeue = false;
    }
    if args.has("audit") {
        cfg.audit = AuditMode::On;
    }
    if let Some(mode) = args.flags.get("placement-cache") {
        cfg.placement_cache = match mode.as_str() {
            "on" | "true" => true,
            "off" | "false" => false,
            other => anyhow::bail!(
                "unknown --placement-cache '{other}' (expected on or \
                 off)"),
        };
    }
    Ok(())
}

/// Apply the modeled-network flags (`--net-model off|lan|wan`,
/// `--gossip-interval MS`, `--staleness-budget MS`, `--net-topk K`,
/// `--autoscale MIN:MAX`). Off — the default — keeps the fleet on the
/// exact sequential coordination path; the knobs are accepted but
/// inert then, except `--autoscale`, which requires a modeled network
/// and is rejected without one.
fn apply_net_flags(cfg: &mut SystemConfig, args: &Args) -> Result<()> {
    if let Some(name) = args.flags.get("net-model") {
        cfg.net.model = NetModelKind::parse(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown net model '{name}' (expected off, lan, or wan)")
        })?;
    }
    if let Some(ms) = args.flags.get("gossip-interval") {
        let ms: u64 = ms.parse().map_err(|_| {
            anyhow::anyhow!("unparseable --gossip-interval '{ms}' \
                             (expected milliseconds)")
        })?;
        cfg.net.gossip_interval = Micros(ms.saturating_mul(1_000).max(1));
    }
    if let Some(ms) = args.flags.get("staleness-budget") {
        let ms: u64 = ms.parse().map_err(|_| {
            anyhow::anyhow!("unparseable --staleness-budget '{ms}' \
                             (expected milliseconds)")
        })?;
        cfg.net.staleness_budget =
            Micros(ms.saturating_mul(1_000).max(1));
    }
    if let Some(k) = args.flags.get("net-topk") {
        let k: usize = k.parse().map_err(|_| {
            anyhow::anyhow!("unparseable --net-topk '{k}' (expected a \
                             replica count)")
        })?;
        cfg.net.topk = k.max(1);
    }
    if let Some(spec) = args.flags.get("autoscale") {
        let scale = AutoscaleConfig::parse(spec).ok_or_else(|| {
            anyhow::anyhow!(
                "unparseable --autoscale '{spec}' (expected MIN:MAX \
                 with 1 <= MIN <= MAX)")
        })?;
        if cfg.net.model == NetModelKind::Off {
            anyhow::bail!(
                "--autoscale needs a modeled network; pass \
                 --net-model lan|wan");
        }
        cfg.net.autoscale = Some(scale);
    }
    Ok(())
}

/// Apply the KV prefix-cache flags: `--prefix-cache` turns refcounted
/// prefix block sharing on (off by default ⇒ legacy behavior);
/// `--prefix-cache-blocks N` caps the zero-ref cached blocks retained
/// after frees (default: retain all, reclaimed under memory pressure).
fn apply_prefix_flags(cfg: &mut SystemConfig, args: &Args) {
    if args.has("prefix-cache") {
        cfg.prefix_cache.enabled = true;
    }
    if let Some(blocks) = args.flags.get("prefix-cache-blocks") {
        match blocks.parse() {
            Ok(n) => {
                cfg.prefix_cache.enabled = true;
                cfg.prefix_cache.cache_blocks = Some(n);
            }
            Err(_) => eprintln!(
                "lamps: ignoring unparseable --prefix-cache-blocks \
                 '{blocks}' (expected a block count)"),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(command) = argv.first() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    let args = Args::parse(&argv[1..]);
    match command.as_str() {
        "serve" => serve(&args),
        "run" => run(&args),
        "gen-workload" => gen_workload(&args),
        "predict" => predict(&args),
        "info" => info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn serve(_args: &Args) -> Result<()> {
    anyhow::bail!("this binary was built without the `pjrt` feature; \
                   `serve` needs the PJRT runtime (rebuild with default \
                   features)")
}

#[cfg(feature = "pjrt")]
fn serve(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7070");
    let model = args.get("model", "gptj-tiny");
    let system = args.get("system", "lamps");
    let artifacts = args.get("artifacts", "artifacts");

    // Validate artifacts up front (nice errors before the thread starts).
    let meta = ArtifactMeta::load(artifacts)?;
    meta.model(model)?;
    let mut base_cfg = SystemConfig::preset(system)
        .ok_or_else(|| anyhow::anyhow!("unknown system preset {system}"))?;
    apply_compose_flags(&mut base_cfg, args);
    apply_prefix_flags(&mut base_cfg, args);
    apply_replica_flags(&mut base_cfg, args)?;
    apply_net_flags(&mut base_cfg, args)?;
    apply_api_source_flag(&mut base_cfg, args, true)?;
    apply_api_pred_flag(&mut base_cfg, args)?;
    eprintln!(
        "lamps: {} replica(s), {} placement (score cache {}), \
         api-source {}, api-pred {}, audit {} ({})",
        base_cfg.replicas, base_cfg.placement.label(),
        if base_cfg.placement_cache { "on" } else { "off" },
        base_cfg.api_source.label(), base_cfg.api_pred.label(),
        base_cfg.audit.label(),
        if base_cfg.audit.enabled() { "active" } else { "inactive" });
    if base_cfg.net.armed(base_cfg.replicas) {
        eprintln!(
            "lamps: net-model {} (gossip every {}ms, staleness budget \
             {}ms, top-{} shortlist{})",
            base_cfg.net.model.label(),
            base_cfg.net.gossip_interval.0 / 1_000,
            base_cfg.net.staleness_budget.0 / 1_000,
            base_cfg.net.topk,
            match base_cfg.net.autoscale {
                Some(s) => format!(", autoscale {}:{}", s.min, s.max),
                None => String::new(),
            });
    }

    // PJRT handles are not Send: build them inside the engine thread.
    // Each replica loads its own model runtime (one modeled device).
    let model_name = model.to_string();
    let artifacts_dir = artifacts.to_string();
    let (handle, _join) = lamps::server::spawn_replicated(move || {
        let meta = ArtifactMeta::load(&artifacts_dir).expect("artifacts");
        let client = RuntimeClient::cpu().expect("PJRT client");
        let mut cfg = base_cfg;
        let mut parts: Vec<lamps::server::ReplicaParts> = Vec::new();
        for _ in 0..cfg.replicas.max(1) {
            let model_rt = ModelRuntime::load(&client, &meta, &model_name)
                .expect("model artifacts");
            let pred_rt =
                PredictorRuntime::load(&client, &meta).expect("predictor");
            // Real backend: budget = what the fixed-shape executables
            // hold (per replica).
            cfg.memory_budget = lamps::core::types::Tokens(
                (model_rt.meta.batch * model_rt.meta.max_seq) as u64);
            cfg.max_batch = model_rt.meta.batch;
            cfg.block_size = 16;
            let backend = Box::new(PjrtBackend::new(model_rt));
            let predictor = Box::new(PjrtPredictor::new(pred_rt));
            parts.push((
                backend as Box<dyn lamps::engine::backend::Backend>,
                predictor as Box<dyn lamps::predictor::Predictor>,
            ));
        }
        (cfg, parts)
    });
    lamps::server::serve_tcp(handle, addr)
}

fn run(args: &Args) -> Result<()> {
    let dataset = args.get("dataset", "single-api");
    let system = args.get("system", "lamps");
    let model = args.get("model", "gptj-6b");
    let rate = args.get_f64("rate", 3.0);
    let requests = args.get_usize("requests", 500);
    let seed = args.get_u64("seed", 42);

    let trace = if dataset.ends_with(".json") {
        Trace::load_json(dataset)?
    } else {
        parse_dataset(dataset)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?
            .generate(requests, rate, seed)
    };
    let mut cfg = SystemConfig::preset(system)
        .ok_or_else(|| anyhow::anyhow!("unknown system preset {system}"))?;
    cfg.cost = parse_model(model).cost();
    cfg.seed = seed;
    if let Some(budget) = args.flags.get("budget") {
        cfg.memory_budget =
            lamps::core::types::Tokens(budget.parse().unwrap_or(44_000));
    }
    if let Some(batch) = args.flags.get("max-batch") {
        cfg.max_batch = batch.parse().unwrap_or(cfg.max_batch);
    }
    if args.has("no-lookahead") {
        cfg.admission_lookahead = false;
    }
    apply_compose_flags(&mut cfg, args);
    apply_prefix_flags(&mut cfg, args);
    apply_replica_flags(&mut cfg, args)?;
    apply_net_flags(&mut cfg, args)?;
    apply_api_source_flag(&mut cfg, args, false)?;
    apply_api_pred_flag(&mut cfg, args)?;
    if cfg.audit.enabled() {
        eprintln!("lamps: invariant auditor active (audit {})",
                  cfg.audit.label());
    }
    let cap = args
        .flags
        .get("time-cap-secs")
        .and_then(|s| s.parse::<f64>().ok())
        .map(Micros::from_secs_f64);
    let replicas = cfg.replicas;
    let placement = cfg.placement;
    let report = if replicas > 1 {
        let mut set = ReplicaSet::simulated(cfg);
        set.set_record_timeline(args.has("timeline"));
        let fleet = set.run_trace_limited(&trace, cap);
        println!("{}", fleet.to_json(args.has("timeline")));
        fleet.fleet
    } else {
        let mut engine = Engine::simulated(cfg);
        engine.record_timeline = args.has("timeline");
        let report = engine.run_trace_limited(&trace, cap);
        println!("{}", report.to_json(args.has("timeline")));
        report
    };
    eprintln!(
        "\n{} on {} ({} reqs @ {}/s, {} replica(s), {} placement): \
         latency mean {:.3}s p99 {:.3}s | \
         ttft mean {:.3}s p99 {:.3}s | throughput {:.3} r/s | \
         {} completed, {} preemptions",
        system, trace.name, trace.len(), trace.rate,
        replicas, placement.label(),
        report.latency.mean_secs(), report.latency.p99_secs(),
        report.ttft.mean_secs(), report.ttft.p99_secs(),
        report.throughput_rps, report.completed, report.preemptions);
    Ok(())
}

fn gen_workload(args: &Args) -> Result<()> {
    let dataset = args.get("dataset", "single-api");
    let rate = args.get_f64("rate", 3.0);
    let requests = args.get_usize("requests", 500);
    let seed = args.get_u64("seed", 42);
    let out = args
        .flags
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out is required"))?;
    let trace = parse_dataset(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?
        .generate(requests, rate, seed);
    trace.save_json(out)?;
    eprintln!("wrote {} requests to {out}", trace.len());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn predict(_args: &Args) -> Result<()> {
    anyhow::bail!("this binary was built without the `pjrt` feature; \
                   `predict` needs the PJRT runtime")
}

#[cfg(feature = "pjrt")]
fn predict(args: &Args) -> Result<()> {
    let prompt = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: lamps predict <prompt>"))?;
    let artifacts = args.get("artifacts", "artifacts");
    let meta = ArtifactMeta::load(artifacts)?;
    let client = RuntimeClient::cpu()?;
    let pred = PredictorRuntime::load(&client, &meta)?;
    let bin = pred.predict_bin(prompt)?;
    println!("bin {} (~{} tokens)", bin, pred.bin_to_tokens(bin));
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn info(_args: &Args) -> Result<()> {
    anyhow::bail!("this binary was built without the `pjrt` feature; \
                   `info` needs the PJRT runtime")
}

#[cfg(feature = "pjrt")]
fn info(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts", "artifacts");
    let meta = ArtifactMeta::load(artifacts)?;
    let client = RuntimeClient::cpu()?;
    println!("platform: {} ({} devices)", client.platform(),
             client.device_count());
    println!("artifacts: {}", meta.dir.display());
    let mut names: Vec<_> = meta.models.keys().collect();
    names.sort();
    for name in names {
        let m = &meta.models[name];
        println!("  model {name}: {}L x {}H x {}d, seq {}, batch {}, \
                  {} B/token KV",
                 m.n_layers, m.n_heads, m.head_dim, m.max_seq, m.batch,
                 m.kv_bytes_per_token);
    }
    println!("  predictor: {} bins x {} tokens, acc5 {:.3}, acc15 {:.3}, \
              MAE {:.2} words",
             meta.predictor.num_bins, meta.predictor.bin_width,
             meta.predictor.acc5, meta.predictor.acc15,
             meta.predictor.mae_words);
    Ok(())
}
