//! The request model: immutable workload spec + mutable serving state.
//!
//! A request's life (paper Fig. 1): prefill the prompt, decode until the
//! first API call fires, wait for the API under a *handling strategy*
//! (Preserve / Discard / Swap), resume, ... repeat per API call ...,
//! decode the final segment, finish. Multi-API requests are segmented and
//! re-enter scheduling after every API call (paper §4.2 "Multi-API").

use crate::coordinator::scheduler::Score;
use crate::core::types::{Micros, RequestId, Tokens};

/// External-augmentation classes with distinct latency profiles
/// (paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ApiType {
    /// Arithmetic (ToolkenGPT-style); ~90 us.
    Math,
    /// Knowledge-base question answering; ~0.69 s.
    Qa,
    /// Embodied virtual environment (ALFWorld); ~0.09 s.
    Ve,
    /// Multi-turn chatbot self-call; ~28.6 s.
    Chatbot,
    /// Image generation (DALL-E-style); ~20.0 s.
    Image,
    /// Text-to-speech; ~17.2 s.
    Tts,
    /// ToolBench real-world API, 49 categories collapsed to one latency
    /// class in the paper's Table 2; the payload is the category index.
    Tool(u8),
}

impl ApiType {
    /// Stable label used in traces, logs, and figure outputs.
    pub fn label(&self) -> &'static str {
        match self {
            ApiType::Math => "math",
            ApiType::Qa => "qa",
            ApiType::Ve => "ve",
            ApiType::Chatbot => "chatbot",
            ApiType::Image => "image",
            ApiType::Tts => "tts",
            ApiType::Tool(_) => "tool",
        }
    }

    /// Parse a wire/CLI label back into a class (`Tool` collapses to
    /// category 0 — the wire protocol does not carry the category).
    pub fn parse(label: &str) -> Option<ApiType> {
        Some(match label {
            "math" => ApiType::Math,
            "qa" => ApiType::Qa,
            "ve" => ApiType::Ve,
            "chatbot" => ApiType::Chatbot,
            "image" => ApiType::Image,
            "tts" => ApiType::Tts,
            "tool" => ApiType::Tool(0),
            _ => return None,
        })
    }
}

/// How a request's KV cache is handled while it waits on an API call
/// (paper §1: the three strategies, and §4.2: LAMPS picks one *before*
/// the request runs, from predictions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HandlingStrategy {
    /// Keep the KV cache resident for the whole API call.
    Preserve,
    /// Free the cache at API start; recompute the context on return.
    Discard,
    /// Offload to CPU memory at API start; reload on return.
    Swap,
}

impl HandlingStrategy {
    pub const ALL: [HandlingStrategy; 3] = [
        HandlingStrategy::Preserve,
        HandlingStrategy::Discard,
        HandlingStrategy::Swap,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            HandlingStrategy::Preserve => "preserve",
            HandlingStrategy::Discard => "discard",
            HandlingStrategy::Swap => "swap",
        }
    }
}

/// One API call within a request.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiCallSpec {
    /// Decode tokens generated in this segment before the call fires.
    pub decode_before: Tokens,
    pub api_type: ApiType,
    /// True call duration (the generator knows it; predictors estimate it).
    pub duration: Micros,
    /// Tokens the API response appends to the context on return.
    pub response_tokens: Tokens,
}

/// Immutable description of a request, produced by a workload generator or
/// parsed from a trace file.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestSpec {
    pub id: RequestId,
    pub arrival: Micros,
    /// Prompt text; used by the PJRT predictor/tokenizer path. May be empty
    /// for purely synthetic traces (the oracle predictor does not need it).
    pub prompt: String,
    pub prompt_tokens: Tokens,
    /// API calls in order; between call `i-1` and call `i` the model decodes
    /// `api_calls[i].decode_before` tokens.
    pub api_calls: Vec<ApiCallSpec>,
    /// Decode tokens in the final (post-last-API) segment.
    pub final_decode: Tokens,
}

impl RequestSpec {
    /// Total model-generated tokens across all segments.
    pub fn total_decode(&self) -> Tokens {
        self.api_calls.iter().map(|c| c.decode_before).sum::<Tokens>()
            + self.final_decode
    }

    /// Total time spent inside API calls.
    pub fn total_api_time(&self) -> Micros {
        self.api_calls.iter().map(|c| c.duration).sum()
    }

    /// Number of segments (= api_calls + 1 final).
    pub fn num_segments(&self) -> usize {
        self.api_calls.len() + 1
    }

    /// Decode tokens in segment `seg`.
    pub fn segment_decode(&self, seg: usize) -> Tokens {
        if seg < self.api_calls.len() {
            self.api_calls[seg].decode_before
        } else {
            self.final_decode
        }
    }

    /// Context size (prompt + generated + API responses) at the *end* of
    /// segment `seg`, before any handling strategy frees memory.
    pub fn context_at_end_of_segment(&self, seg: usize) -> Tokens {
        let mut ctx = self.prompt_tokens;
        for (i, call) in self.api_calls.iter().enumerate() {
            if i > seg {
                break;
            }
            ctx += call.decode_before;
            if i < seg {
                ctx += call.response_tokens;
            }
        }
        if seg >= self.api_calls.len() {
            ctx += self.final_decode;
        }
        ctx
    }
}

/// Predicted properties of one segment (paper §4.2: pre-API output length
/// from the prompt predictor; API duration + response length from the
/// per-class historical table).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SegmentPrediction {
    /// Predicted decode tokens before the segment's API (or before finish,
    /// for the final segment).
    pub decode_tokens: Tokens,
    /// Predicted API duration; `None` for the final segment.
    pub api_duration: Option<Micros>,
    /// Predicted API response length.
    pub response_tokens: Tokens,
}

/// Where a request currently is in the serving pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    /// In the waiting queue; `needs_prefill` tokens of context must be
    /// (re)materialized before decode can proceed (prompt tokens for new
    /// requests; full context for discarded ones; zero after Preserve).
    Waiting,
    /// Member of the current running batch.
    Running,
    /// Blocked on an API call, held under `strategy`. `return_at` is
    /// the simulated source's known deadline; `None` marks an
    /// externally-resolved call whose return time nobody knows — it
    /// fires only when the client posts a `tool_result`
    /// (`Engine::complete_api_call`).
    ApiWait {
        strategy: HandlingStrategy,
        return_at: Option<Micros>,
    },
    Finished,
}

/// A request in flight: spec + predictions + mutable serving state.
///
/// Invariants maintained by the engine:
/// - `context` equals the KV tokens charged to this request in the block
///   manager whenever `phase` is `Running` or `ApiWait{Preserve}`.
/// - `segment < spec.num_segments()` unless `phase == Finished`.
#[derive(Debug, Clone)]
pub struct Request {
    pub spec: RequestSpec,
    /// One prediction per segment (len = num_segments()).
    pub predictions: Vec<SegmentPrediction>,
    /// Strategy assigned per API call (len = api_calls.len()). Assigned at
    /// admission by LAMPS; at API-encounter time by the INFERCEPT baseline.
    pub handling: Vec<HandlingStrategy>,

    // ---- mutable serving state ----
    pub phase: Phase,
    /// Current segment index.
    pub segment: usize,
    /// Tokens decoded so far within the current segment.
    pub segment_generated: Tokens,
    /// Context tokens whose KV entries are *live on the device* right now.
    pub context: Tokens,
    /// Context tokens that exist logically (survive Discard) — what must be
    /// rematerialized by a recompute.
    pub logical_context: Tokens,
    /// Prefill / recompute / swap-in work still owed before decode resumes,
    /// in tokens of context to materialize. The engine maintains
    /// `context = logical_context - pending_materialize` for admitted
    /// requests, so a chunked prefill that pauses mid-way leaves an
    /// accurate picture of what is live.
    pub pending_materialize: Tokens,
    /// The materialization in progress is a post-Discard recompute
    /// (wasted-work accounting); set when it starts, cleared when
    /// `pending_materialize` drains.
    pub recomputing: bool,
    /// Leading tokens of a pending swap-in restore already served by
    /// prefix-cache blocks attached to the re-admission allocation (no
    /// PCIe transfer needed for them). Set when the restore's blocks
    /// are allocated, consumed when the transfer is booked.
    pub restore_resident: Tokens,
    /// FCFS ordering key. Starts at `spec.arrival`; vLLM-style systems
    /// treat a request returning from an API as a *new* job (paper §1,
    /// §6.2), so the engine bumps this to the return time whenever the
    /// request re-enters the waiting queue after an API call.
    pub queue_key: Micros,
    /// True once the request has been scheduled at least once — starvation
    /// tracking only activates then (paper §4.4).
    pub was_scheduled: bool,
    pub starvation_cnt: u32,
    /// Promoted-to-head flag; sticky until completion (paper §4.4).
    pub starving: bool,
    /// When the in-flight API call started (set at the encounter,
    /// cleared when the return is routed) — what an externally-resolved
    /// call's *actual* duration is measured from.
    pub api_started_at: Option<Micros>,

    // ---- metrics ----
    pub first_scheduled_at: Option<Micros>,
    pub first_token_at: Option<Micros>,
    pub finished_at: Option<Micros>,
    /// Cached scheduling score + the iteration it was computed on
    /// (selective score update, paper §4.3).
    pub cached_score: Score,
    pub score_iteration: u64,
}

impl Request {
    pub fn new(spec: RequestSpec, predictions: Vec<SegmentPrediction>,
               handling: Vec<HandlingStrategy>) -> Request {
        assert_eq!(predictions.len(), spec.num_segments(),
                   "one prediction per segment");
        assert_eq!(handling.len(), spec.api_calls.len(),
                   "one handling strategy per API call");
        let prompt_tokens = spec.prompt_tokens;
        let queue_key = spec.arrival;
        Request {
            spec,
            predictions,
            handling,
            queue_key,
            phase: Phase::Waiting,
            segment: 0,
            segment_generated: Tokens::ZERO,
            context: Tokens::ZERO,
            logical_context: prompt_tokens,
            pending_materialize: prompt_tokens,
            recomputing: false,
            restore_resident: Tokens::ZERO,
            was_scheduled: false,
            starvation_cnt: 0,
            starving: false,
            api_started_at: None,
            first_scheduled_at: None,
            first_token_at: None,
            finished_at: None,
            cached_score: Score::MAX,
            score_iteration: u64::MAX,
        }
    }

    pub fn id(&self) -> RequestId {
        self.spec.id
    }

    /// Tokens still to decode in the current segment.
    pub fn segment_remaining(&self) -> Tokens {
        self.spec
            .segment_decode(self.segment)
            .saturating_sub(self.segment_generated)
    }

    /// Is the current segment's next boundary an API call (vs. completion)?
    pub fn at_api_segment(&self) -> bool {
        self.segment < self.spec.api_calls.len()
    }

    /// The strategy assigned to the current segment's API call.
    pub fn current_handling(&self) -> Option<HandlingStrategy> {
        self.handling.get(self.segment).copied()
    }

    /// Device memory this request holds in the given phase (what the
    /// admission check and the KV manager charge).
    pub fn held_memory(&self) -> Tokens {
        match self.phase {
            Phase::Running => self.context,
            Phase::ApiWait { strategy: HandlingStrategy::Preserve, .. } => {
                self.context
            }
            // Discard/Swap free device memory during the call; Waiting
            // requests hold nothing until admitted.
            _ => Tokens::ZERO,
        }
    }

    /// Memory the request will need the moment it (re)starts decode:
    /// context to materialize plus one slot for the next token.
    pub fn admission_memory(&self) -> Tokens {
        self.logical_context + Tokens(1)
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.phase, Phase::Finished)
    }

    pub fn in_api_wait(&self) -> bool {
        matches!(self.phase, Phase::ApiWait { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_with_two_apis() -> RequestSpec {
        RequestSpec {
            id: RequestId(1),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(10),
            api_calls: vec![
                ApiCallSpec {
                    decode_before: Tokens(5),
                    api_type: ApiType::Math,
                    duration: Micros(100),
                    response_tokens: Tokens(3),
                },
                ApiCallSpec {
                    decode_before: Tokens(7),
                    api_type: ApiType::Image,
                    duration: Micros(2000),
                    response_tokens: Tokens(2),
                },
            ],
            final_decode: Tokens(4),
        }
    }

    #[test]
    fn totals() {
        let s = spec_with_two_apis();
        assert_eq!(s.total_decode(), Tokens(16));
        assert_eq!(s.total_api_time(), Micros(2100));
        assert_eq!(s.num_segments(), 3);
        assert_eq!(s.segment_decode(0), Tokens(5));
        assert_eq!(s.segment_decode(2), Tokens(4));
    }

    #[test]
    fn context_accumulates_responses() {
        let s = spec_with_two_apis();
        // end of seg 0: prompt 10 + 5 decoded
        assert_eq!(s.context_at_end_of_segment(0), Tokens(15));
        // end of seg 1: + resp 3 + 7 decoded
        assert_eq!(s.context_at_end_of_segment(1), Tokens(25));
        // end of seg 2: + resp 2 + 4 decoded
        assert_eq!(s.context_at_end_of_segment(2), Tokens(31));
    }

    fn dummy_predictions(spec: &RequestSpec) -> Vec<SegmentPrediction> {
        (0..spec.num_segments())
            .map(|i| SegmentPrediction {
                decode_tokens: spec.segment_decode(i),
                api_duration: spec.api_calls.get(i).map(|c| c.duration),
                response_tokens: spec
                    .api_calls
                    .get(i)
                    .map(|c| c.response_tokens)
                    .unwrap_or(Tokens::ZERO),
            })
            .collect()
    }

    #[test]
    fn new_request_state() {
        let s = spec_with_two_apis();
        let preds = dummy_predictions(&s);
        let r = Request::new(s, preds,
                             vec![HandlingStrategy::Preserve,
                                  HandlingStrategy::Discard]);
        assert_eq!(r.phase, Phase::Waiting);
        assert_eq!(r.pending_materialize, Tokens(10));
        assert_eq!(r.held_memory(), Tokens::ZERO);
        assert_eq!(r.admission_memory(), Tokens(11));
        assert!(r.at_api_segment());
        assert_eq!(r.current_handling(), Some(HandlingStrategy::Preserve));
    }

    #[test]
    fn held_memory_by_phase() {
        let s = spec_with_two_apis();
        let preds = dummy_predictions(&s);
        let mut r = Request::new(s, preds,
                                 vec![HandlingStrategy::Preserve,
                                      HandlingStrategy::Swap]);
        r.context = Tokens(15);
        r.phase = Phase::Running;
        assert_eq!(r.held_memory(), Tokens(15));
        r.phase = Phase::ApiWait {
            strategy: HandlingStrategy::Preserve,
            return_at: Some(Micros(10)),
        };
        assert_eq!(r.held_memory(), Tokens(15));
        r.phase = Phase::ApiWait {
            strategy: HandlingStrategy::Discard,
            return_at: Some(Micros(10)),
        };
        assert_eq!(r.held_memory(), Tokens::ZERO);
        r.phase = Phase::ApiWait {
            strategy: HandlingStrategy::Swap,
            return_at: None, // externally-resolved calls hold the same
        };
        assert_eq!(r.held_memory(), Tokens::ZERO);
    }

    #[test]
    fn api_type_label_parse_roundtrip() {
        for t in [ApiType::Math, ApiType::Qa, ApiType::Ve,
                  ApiType::Chatbot, ApiType::Image, ApiType::Tts,
                  ApiType::Tool(0)] {
            assert_eq!(ApiType::parse(t.label()), Some(t));
        }
        assert_eq!(ApiType::parse("tool"), Some(ApiType::Tool(0)));
        assert_eq!(ApiType::parse("nope"), None);
    }

    #[test]
    #[should_panic(expected = "one prediction per segment")]
    fn prediction_arity_checked() {
        let s = spec_with_two_apis();
        Request::new(s, vec![], vec![HandlingStrategy::Preserve,
                                     HandlingStrategy::Discard]);
    }
}
