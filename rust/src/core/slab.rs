//! Generational slab storage for hot per-request state.
//!
//! The engine's request table lives for the whole run but its entries
//! churn constantly (every submit allocates, every withdraw frees). A
//! plain `HashMap<RequestId, Request>` pays an allocator round-trip and
//! a rehash amortization for that churn; the [`Slab`] here recycles
//! fixed slots from a free list instead, so steady-state insert/remove
//! touches no allocator at all, and a stale key can never alias a
//! recycled slot (each slot carries a generation stamp that a lookup
//! must match).
//!
//! [`SlabMap`] layers the keyed lookup the engine actually wants on
//! top: a `HashMap<K, SlabKey>` index into the slab. It mirrors the
//! `HashMap` API surface the engine used (`get`/`get_mut`/`insert`/
//! `remove`/`keys`/`Index<&K>`), so swapping the backing store is a
//! type change, not a call-site rewrite. Values live contiguously in
//! the slab's slot vector — better locality for the O(live) rank sweep
//! than `HashMap`'s scattered buckets.

use std::collections::HashMap;
use std::hash::Hash;
use std::ops::Index;

/// Handle to one occupied slab slot. Stale after the slot is removed:
/// the generation stamp stops matching, and lookups return `None`
/// instead of aliasing whatever was recycled into the slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlabKey {
    index: u32,
    generation: u32,
}

#[derive(Debug, Clone)]
enum Slot<T> {
    Vacant { generation: u32, next_free: Option<u32> },
    Occupied { generation: u32, value: T },
}

/// A generational slab: O(1) insert/get/remove, slots recycled through
/// an intrusive free list, ABA protected by per-slot generations.
#[derive(Debug, Clone)]
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free_head: Option<u32>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Slab<T> {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab { slots: Vec::new(), free_head: None, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Store `value`, recycling a free slot when one exists (no
    /// allocation) and growing the slot vector otherwise.
    pub fn insert(&mut self, value: T) -> SlabKey {
        self.len += 1;
        match self.free_head {
            Some(at) => {
                let slot = &mut self.slots[at as usize];
                let (generation, next_free) = match slot {
                    Slot::Vacant { generation, next_free } => {
                        (*generation, *next_free)
                    }
                    Slot::Occupied { .. } => {
                        unreachable!("free list points at occupied slot")
                    }
                };
                self.free_head = next_free;
                *slot = Slot::Occupied { generation, value };
                SlabKey { index: at, generation }
            }
            None => {
                let index = self.slots.len() as u32;
                self.slots.push(Slot::Occupied { generation: 0, value });
                SlabKey { index, generation: 0 }
            }
        }
    }

    pub fn get(&self, key: SlabKey) -> Option<&T> {
        match self.slots.get(key.index as usize) {
            Some(Slot::Occupied { generation, value })
                if *generation == key.generation => Some(value),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: SlabKey) -> Option<&mut T> {
        match self.slots.get_mut(key.index as usize) {
            Some(Slot::Occupied { generation, value })
                if *generation == key.generation => Some(value),
            _ => None,
        }
    }

    /// Free the slot (pushed on the free list with a bumped generation,
    /// so `key` and any copy of it go stale immediately).
    pub fn remove(&mut self, key: SlabKey) -> Option<T> {
        let slot = self.slots.get_mut(key.index as usize)?;
        match slot {
            Slot::Occupied { generation, .. }
                if *generation == key.generation =>
            {
                let next = Slot::Vacant {
                    generation: key.generation.wrapping_add(1),
                    next_free: self.free_head,
                };
                let Slot::Occupied { value, .. } =
                    std::mem::replace(slot, next)
                else {
                    unreachable!("matched Occupied above");
                };
                self.free_head = Some(key.index);
                self.len -= 1;
                Some(value)
            }
            _ => None,
        }
    }
}

/// A keyed view over a [`Slab`]: `HashMap`-shaped API, slab-backed
/// value storage. The index maps each key to its live slab slot; the
/// values themselves never move through the `HashMap`, so entry churn
/// recycles slab slots instead of reallocating map buckets.
#[derive(Debug, Clone)]
pub struct SlabMap<K, V> {
    slab: Slab<V>,
    index: HashMap<K, SlabKey>,
}

impl<K: Eq + Hash + Copy, V> Default for SlabMap<K, V> {
    fn default() -> SlabMap<K, V> {
        SlabMap::new()
    }
}

impl<K: Eq + Hash + Copy, V> SlabMap<K, V> {
    pub fn new() -> SlabMap<K, V> {
        SlabMap { slab: Slab::new(), index: HashMap::new() }
    }

    pub fn len(&self) -> usize {
        self.slab.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slab.is_empty()
    }

    pub fn contains_key(&self, key: &K) -> bool {
        self.index.contains_key(key)
    }

    pub fn get(&self, key: &K) -> Option<&V> {
        self.index.get(key).and_then(|sk| self.slab.get(*sk))
    }

    pub fn get_mut(&mut self, key: &K) -> Option<&mut V> {
        match self.index.get(key) {
            Some(sk) => self.slab.get_mut(*sk),
            None => None,
        }
    }

    /// Insert, replacing (and returning) any value already under `key`.
    pub fn insert(&mut self, key: K, value: V) -> Option<V> {
        if let Some(sk) = self.index.get(&key) {
            if let Some(slot) = self.slab.get_mut(*sk) {
                return Some(std::mem::replace(slot, value));
            }
        }
        let sk = self.slab.insert(value);
        self.index.insert(key, sk);
        None
    }

    pub fn remove(&mut self, key: &K) -> Option<V> {
        let sk = self.index.remove(key)?;
        self.slab.remove(sk)
    }

    pub fn keys(&self) -> impl Iterator<Item = &K> + '_ {
        self.index.keys()
    }
}

impl<K: Eq + Hash + Copy, V> Index<&K> for SlabMap<K, V> {
    type Output = V;

    fn index(&self, key: &K) -> &V {
        self.get(key).expect("SlabMap: key not present")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slab_insert_get_remove_round_trip() {
        let mut s: Slab<String> = Slab::new();
        let a = s.insert("a".to_string());
        let b = s.insert("b".to_string());
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a).map(String::as_str), Some("a"));
        assert_eq!(s.get(b).map(String::as_str), Some("b"));
        assert_eq!(s.remove(a), Some("a".to_string()));
        assert_eq!(s.get(a), None);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn slab_recycles_slots_and_stales_old_keys() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        let b = s.insert(2);
        // The freed slot is recycled (no growth)...
        assert_eq!(b.index, a.index);
        assert_ne!(b.generation, a.generation);
        // ...and the stale key cannot alias the new tenant.
        assert_eq!(s.get(a), None);
        assert_eq!(s.remove(a), None);
        assert_eq!(s.get(b), Some(&2));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn slab_free_list_survives_interleaved_churn() {
        let mut s: Slab<usize> = Slab::new();
        let keys: Vec<SlabKey> = (0..8).map(|i| s.insert(i)).collect();
        for k in keys.iter().step_by(2) {
            s.remove(*k);
        }
        assert_eq!(s.len(), 4);
        // Refills reuse the four freed slots before growing.
        let grown_before = s.slots.len();
        for i in 100..104 {
            s.insert(i);
        }
        assert_eq!(s.slots.len(), grown_before);
        assert_eq!(s.len(), 8);
        // Odd originals are still intact.
        for (i, k) in keys.iter().enumerate() {
            if i % 2 == 1 {
                assert_eq!(s.get(*k), Some(&i));
            }
        }
    }

    #[test]
    fn slab_map_mirrors_hashmap_semantics() {
        let mut m: SlabMap<u64, String> = SlabMap::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(7, "seven".to_string()), None);
        assert_eq!(m.insert(9, "nine".to_string()), None);
        assert_eq!(m.len(), 2);
        assert!(m.contains_key(&7));
        assert_eq!(m.get(&7).map(String::as_str), Some("seven"));
        assert_eq!(m[&9], "nine");
        // Replacement returns the old value and does not grow.
        assert_eq!(m.insert(7, "SEVEN".to_string()),
                   Some("seven".to_string()));
        assert_eq!(m.len(), 2);
        assert_eq!(m[&7], "SEVEN");
        if let Some(v) = m.get_mut(&9) {
            v.push('!');
        }
        assert_eq!(m[&9], "nine!");
        assert_eq!(m.remove(&7), Some("SEVEN".to_string()));
        assert_eq!(m.get(&7), None);
        assert_eq!(m.remove(&7), None);
        let mut keys: Vec<u64> = m.keys().copied().collect();
        keys.sort_unstable();
        assert_eq!(keys, vec![9]);
    }

    #[test]
    fn slab_map_reinsert_after_remove_recycles() {
        let mut m: SlabMap<u64, u64> = SlabMap::new();
        for round in 0..10u64 {
            m.insert(1, round);
            assert_eq!(m[&1], round);
            assert_eq!(m.remove(&1), Some(round));
        }
        assert!(m.is_empty());
        // Ten rounds of churn, still exactly one slot.
        assert_eq!(m.slab.slots.len(), 1);
    }
}
