//! Core domain types shared by every subsystem: time/memory newtypes and
//! the request state machine.

pub mod request;
pub mod slab;
pub mod types;
