//! Time and memory newtypes.
//!
//! The whole stack accounts memory in **tokens** (the paper's unit: KV-cache
//! slots) and time in **integer microseconds**. Integer time keeps the
//! discrete-event simulator exactly reproducible; byte conversions happen
//! only at reporting boundaries via `kv_bytes_per_token`.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub, SubAssign};

/// A point in (virtual or wall) time, in microseconds since engine start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Micros(pub u64);

impl Micros {
    pub const ZERO: Micros = Micros(0);
    pub const MAX: Micros = Micros(u64::MAX);

    pub fn from_secs_f64(secs: f64) -> Micros {
        Micros((secs.max(0.0) * 1e6).round() as u64)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn saturating_sub(self, rhs: Micros) -> Micros {
        Micros(self.0.saturating_sub(rhs.0))
    }

    pub fn min(self, rhs: Micros) -> Micros {
        Micros(self.0.min(rhs.0))
    }

    pub fn max(self, rhs: Micros) -> Micros {
        Micros(self.0.max(rhs.0))
    }
}

impl Add for Micros {
    type Output = Micros;
    fn add(self, rhs: Micros) -> Micros {
        Micros(self.0 + rhs.0)
    }
}

impl AddAssign for Micros {
    fn add_assign(&mut self, rhs: Micros) {
        self.0 += rhs.0;
    }
}

impl Sub for Micros {
    type Output = Micros;
    fn sub(self, rhs: Micros) -> Micros {
        Micros(self.0 - rhs.0)
    }
}

impl SubAssign for Micros {
    fn sub_assign(&mut self, rhs: Micros) {
        self.0 -= rhs.0;
    }
}

impl Mul<u64> for Micros {
    type Output = Micros;
    fn mul(self, rhs: u64) -> Micros {
        Micros(self.0 * rhs)
    }
}

impl Div<u64> for Micros {
    type Output = Micros;
    fn div(self, rhs: u64) -> Micros {
        Micros(self.0 / rhs)
    }
}

impl Sum for Micros {
    fn sum<I: Iterator<Item = Micros>>(iter: I) -> Micros {
        Micros(iter.map(|m| m.0).sum())
    }
}

impl fmt::Display for Micros {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

/// A count of KV-cache token slots (the paper's memory unit).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Tokens(pub u64);

impl Tokens {
    pub const ZERO: Tokens = Tokens(0);

    pub fn as_u64(self) -> u64 {
        self.0
    }

    pub fn saturating_sub(self, rhs: Tokens) -> Tokens {
        Tokens(self.0.saturating_sub(rhs.0))
    }

    pub fn min(self, rhs: Tokens) -> Tokens {
        Tokens(self.0.min(rhs.0))
    }

    /// Bytes this many KV slots occupy for a model with the given
    /// per-token KV cost (eqns (1)-(3)'s constant M).
    pub fn bytes(self, kv_bytes_per_token: u64) -> u64 {
        self.0 * kv_bytes_per_token
    }
}

impl Add for Tokens {
    type Output = Tokens;
    fn add(self, rhs: Tokens) -> Tokens {
        Tokens(self.0 + rhs.0)
    }
}

impl AddAssign for Tokens {
    fn add_assign(&mut self, rhs: Tokens) {
        self.0 += rhs.0;
    }
}

impl Sub for Tokens {
    type Output = Tokens;
    fn sub(self, rhs: Tokens) -> Tokens {
        Tokens(self.0 - rhs.0)
    }
}

impl SubAssign for Tokens {
    fn sub_assign(&mut self, rhs: Tokens) {
        self.0 -= rhs.0;
    }
}

impl Sum for Tokens {
    fn sum<I: Iterator<Item = Tokens>>(iter: I) -> Tokens {
        Tokens(iter.map(|t| t.0).sum())
    }
}

impl fmt::Display for Tokens {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} tok", self.0)
    }
}

/// Unique, monotonically increasing request identifier. FCFS order is
/// defined by this id for same-arrival requests (paper §3.1's example).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u64);

impl fmt::Display for RequestId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "r{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micros_roundtrip() {
        let m = Micros::from_secs_f64(1.5);
        assert_eq!(m.0, 1_500_000);
        assert!((m.as_secs_f64() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn micros_arithmetic() {
        assert_eq!(Micros(3) + Micros(4), Micros(7));
        assert_eq!(Micros(10) - Micros(4), Micros(6));
        assert_eq!(Micros(10).saturating_sub(Micros(20)), Micros(0));
        assert_eq!(Micros(3) * 4, Micros(12));
        let total: Micros = [Micros(1), Micros(2)].into_iter().sum();
        assert_eq!(total, Micros(3));
    }

    #[test]
    fn tokens_bytes() {
        // gptj-tiny: 2 * 4 layers * 4 heads * 32 dim * 4 bytes = 4096 B/tok
        assert_eq!(Tokens(10).bytes(4096), 40_960);
    }

    #[test]
    fn negative_secs_clamped() {
        assert_eq!(Micros::from_secs_f64(-1.0), Micros::ZERO);
    }
}
