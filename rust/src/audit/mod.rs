//! Runtime invariant auditor: read-only re-derivation of the
//! correctness properties the scheduler's results rest on, checked
//! after every engine/fleet step when armed.
//!
//! Scheduling bugs here rarely crash — they silently skew ranks,
//! leak blocks, or reorder streams, and the run still prints a
//! plausible report. The auditor promotes the invariants that used to
//! live scattered across `tests/kv_properties.rs`,
//! `tests/replica_properties.rs` and `tests/session_events.rs` into
//! one reusable checker:
//!
//! - **Block conservation** — every device block is accounted exactly
//!   once across free list, private allocations, and prefix cache;
//!   gauges match recounts ([`crate::kv::BlockManager::check_invariants`]).
//! - **Prefix refcounts** — each cached block's refcount equals its
//!   holder count; zero-ref gauge and LRU agree.
//! - **Swap gauge** — host-parked tokens sum to the used gauge.
//! - **Queue order** — pending arrivals non-decreasing (engine), the
//!   fleet's shared admission queue strictly `(arrival, id)`-sorted.
//! - **Queue membership** — waiting/running disjoint, duplicate-free,
//!   and subsets of the live request table.
//! - **Clock monotonicity** — a step never moves time backwards.
//! - **Event causality** — per-request lifecycle streams obey
//!   `Queued ≤ Placed ≤ FirstToken ≤ terminal`, API calls pair up in
//!   index order and never nest, and nothing follows the terminal
//!   event ([`StreamState`]).
//! - **Fleet consistency** — the dispatch log covers every placed
//!   request exactly once on a valid replica, request tables are
//!   disjoint across replicas, and the shared prefix index is a
//!   subset of what is actually resident.
//!
//! Armed via [`crate::config::AuditMode`]: `--audit` (or
//! `LAMPS_AUDIT=on` for the benches) forces it on, and the `Auto`
//! default turns it on under `cfg(debug_assertions)` — so the whole
//! tier-1 test suite runs audited. Every check is observe-only: an
//! audited run's report is byte-identical to an unaudited one. A
//! violated invariant is a bug, and the engine treats it as fatal.

use std::collections::{HashMap, HashSet};
use std::fmt;

use crate::cluster::ReplicaSet;
use crate::core::types::{Micros, RequestId};
use crate::engine::{Engine, EngineEvent};

/// One violated invariant: which check tripped, and the recount that
/// disagrees. Construction implies a bug somewhere upstream — the
/// auditor itself never mutates what it measures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AuditError {
    /// Short check slug (`"kv"`, `"swap"`, `"clock"`, `"queue"`,
    /// `"stream"`, `"fleet"`).
    pub check: &'static str,
    pub detail: String,
}

impl fmt::Display for AuditError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "audit[{}]: {}", self.check, self.detail)
    }
}

impl std::error::Error for AuditError {}

fn fail(check: &'static str, detail: String) -> Result<(), AuditError> {
    Err(AuditError { check, detail })
}

// ----------------------------------------------------------------------
// Per-request lifecycle stream machine
// ----------------------------------------------------------------------

/// One observed lifecycle event, normalized across layers: the engine
/// journal ([`EngineEvent`], via [`from_engine_event`]) and the
/// serving frontend's session stream (`server::RequestEvent`) both
/// map onto it, so a single state machine checks either.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamEvent {
    /// Accepted into a queue (server-level; always the head event).
    Queued,
    /// Placed onto a replica (server-level; directly after `Queued`).
    Placed,
    /// Moved to a sibling replica by the admission re-queue. Only a
    /// request that never executed is relocatable, so a rescue must
    /// precede all progress.
    Rescued,
    /// First decoded token.
    FirstToken,
    /// Further decoded tokens (any chunk size).
    Tokens,
    /// API call `index` parked the request.
    ApiStarted { index: usize },
    /// API call `index` returned.
    ApiCompleted { index: usize },
    /// Terminal: served to completion (`finished`) or dropped.
    Terminal { finished: bool },
}

/// Per-request event-stream state: feed every event in delivery order
/// through [`StreamState::observe`] and any causality violation
/// surfaces as an [`AuditError`] at the exact event that broke it.
#[derive(Debug, Clone, Default)]
pub struct StreamState {
    /// 0 = no head events, 1 = after `Queued`, 2 = after `Placed`.
    head: u8,
    saw_first_token: bool,
    saw_tokens: bool,
    open_call: Option<usize>,
    next_call: usize,
    terminated: bool,
}

impl StreamState {
    /// Has the terminal event been observed? (The state is retained
    /// afterwards precisely so a late event can be caught.)
    pub fn terminated(&self) -> bool {
        self.terminated
    }

    /// Any evidence the request has started executing — after which
    /// it holds replica-local state and can no longer be rescued.
    fn progressed(&self) -> bool {
        self.saw_first_token
            || self.saw_tokens
            || self.next_call > 0
            || self.open_call.is_some()
    }

    /// Observe the next event of this request's stream.
    pub fn observe(&mut self, id: RequestId, ev: StreamEvent)
                   -> Result<(), AuditError> {
        if self.terminated {
            return fail("stream",
                        format!("{id}: {ev:?} after the terminal event"));
        }
        match ev {
            StreamEvent::Queued => {
                if self.head != 0 || self.progressed() {
                    return fail("stream",
                                format!("{id}: Queued not at stream head"));
                }
                self.head = 1;
            }
            StreamEvent::Placed => {
                if self.head > 1 || self.progressed() {
                    return fail(
                        "stream",
                        format!("{id}: Placed after head/progress \
                                 (head={})", self.head));
                }
                self.head = 2;
            }
            StreamEvent::Rescued => {
                if self.progressed() {
                    return fail("stream",
                                format!("{id}: Rescued after execution \
                                         started"));
                }
            }
            StreamEvent::FirstToken => {
                if self.saw_first_token || self.saw_tokens {
                    return fail("stream",
                                format!("{id}: duplicate/late FirstToken"));
                }
                if self.open_call.is_some() {
                    return fail("stream",
                                format!("{id}: FirstToken while parked on \
                                         an API call"));
                }
                self.saw_first_token = true;
            }
            StreamEvent::Tokens => {
                if !self.saw_first_token {
                    return fail("stream",
                                format!("{id}: Tokens before FirstToken"));
                }
                if self.open_call.is_some() {
                    return fail("stream",
                                format!("{id}: Tokens while parked on an \
                                         API call"));
                }
                self.saw_tokens = true;
            }
            StreamEvent::ApiStarted { index } => {
                if self.open_call.is_some() {
                    return fail("stream",
                                format!("{id}: nested API call {index}"));
                }
                if index != self.next_call {
                    return fail(
                        "stream",
                        format!("{id}: API call {index} started out of \
                                 order (expected {})", self.next_call));
                }
                self.open_call = Some(index);
            }
            StreamEvent::ApiCompleted { index } => {
                if self.open_call != Some(index) {
                    return fail(
                        "stream",
                        format!("{id}: API call {index} completed but \
                                 open call is {:?}", self.open_call));
                }
                self.open_call = None;
                self.next_call = index + 1;
            }
            StreamEvent::Terminal { finished } => {
                if finished && self.open_call.is_some() {
                    return fail(
                        "stream",
                        format!("{id}: finished with API call {:?} still \
                                 open", self.open_call));
                }
                self.terminated = true;
            }
        }
        Ok(())
    }
}

/// Check one complete (or partial) stream in delivery order,
/// returning the final state — the promoted core of the old
/// `session_events.rs` per-stream asserts, reused by those tests.
pub fn check_stream(id: RequestId,
                    events: impl IntoIterator<Item = StreamEvent>)
                    -> Result<StreamState, AuditError> {
    let mut state = StreamState::default();
    for ev in events {
        state.observe(id, ev)?;
    }
    Ok(state)
}

/// Normalize an engine journal entry onto the stream machine's
/// event alphabet.
pub fn from_engine_event(ev: &EngineEvent) -> (RequestId, StreamEvent) {
    match ev {
        EngineEvent::FirstToken { id, .. } => {
            (*id, StreamEvent::FirstToken)
        }
        EngineEvent::Tokens { id, .. } => (*id, StreamEvent::Tokens),
        EngineEvent::ApiStarted { id, index, .. } => {
            (*id, StreamEvent::ApiStarted { index: *index })
        }
        EngineEvent::ApiCompleted { id, index, .. } => {
            (*id, StreamEvent::ApiCompleted { index: *index })
        }
        EngineEvent::Finished { id, .. } => {
            (*id, StreamEvent::Terminal { finished: true })
        }
        EngineEvent::Dropped { id, .. } => {
            (*id, StreamEvent::Terminal { finished: false })
        }
    }
}

// ----------------------------------------------------------------------
// Engine auditor
// ----------------------------------------------------------------------

/// Per-engine auditor state: the last observed clock (monotonicity)
/// and one [`StreamState`] per request ever seen in the event journal
/// (causality). The structural checks re-derive everything else from
/// the engine on every call, so they carry no state at all.
#[derive(Debug, Default)]
pub struct EngineAuditor {
    last_now: Option<Micros>,
    streams: HashMap<RequestId, StreamState>,
}

impl EngineAuditor {
    pub fn new() -> EngineAuditor {
        EngineAuditor::default()
    }

    /// Feed one journaled lifecycle event through the owning
    /// request's stream machine. The engine calls this on *every*
    /// event — before the journal's arming gate — so causality is
    /// checked even in runs that never drain events.
    pub fn observe_event(&mut self, ev: &EngineEvent)
                         -> Result<(), AuditError> {
        let (id, sev) = from_engine_event(ev);
        self.streams.entry(id).or_default().observe(id, sev)
    }

    /// Full post-step structural check of one engine: clock
    /// monotonicity, KV block conservation and prefix refcounts, the
    /// swap gauge, pending-arrival order, and queue membership.
    pub fn check_engine(&mut self, engine: &Engine)
                        -> Result<(), AuditError> {
        let now = engine.now();
        if let Some(last) = self.last_now {
            if now < last {
                return fail("clock",
                            format!("clock moved backwards: {last} -> \
                                     {now}"));
            }
        }
        self.last_now = Some(now);

        engine
            .audit_kv()
            .check_invariants()
            .map_err(|detail| AuditError { check: "kv", detail })?;
        engine
            .audit_swap()
            .check_invariants()
            .map_err(|detail| AuditError { check: "swap", detail })?;

        let mut last_arrival: Option<Micros> = None;
        for (arrival, id) in engine.audit_pending() {
            if let Some(prev) = last_arrival {
                if arrival < prev {
                    return fail(
                        "queue",
                        format!("pending arrivals out of order at {id}: \
                                 {arrival} after {prev}"));
                }
            }
            last_arrival = Some(arrival);
        }

        let mut seen: HashSet<RequestId> = HashSet::new();
        let queues = [("waiting", engine.audit_waiting()),
                      ("running", engine.audit_running())];
        for (name, ids) in queues {
            for &id in ids {
                if !seen.insert(id) {
                    return fail(
                        "queue",
                        format!("{id} queued twice (second hit in \
                                 {name})"));
                }
                if engine.request(id).is_none() {
                    return fail("queue",
                                format!("{name} holds unknown {id}"));
                }
                if !engine.audit_live().contains(&id) {
                    return fail("queue",
                                format!("{name} holds non-live {id}"));
                }
            }
        }
        for &id in engine.audit_live() {
            if engine.request(id).is_none() {
                return fail("queue",
                            format!("live set holds unknown {id}"));
            }
        }
        Ok(())
    }
}

// ----------------------------------------------------------------------
// Fleet auditor
// ----------------------------------------------------------------------

/// Post-step structural check of a [`ReplicaSet`] (stateless — the
/// per-replica clocks and streams are audited by each engine's own
/// [`EngineAuditor`]): shared-queue order, dispatch-log shape and
/// coverage, cross-replica request disjointness, and the shared
/// prefix index staying a subset of what is resident — exactly with
/// `--net-model off`, within the gossip in-flight window when a
/// modeled network is armed (see the in-line comment below).
pub fn check_fleet(set: &ReplicaSet) -> Result<(), AuditError> {
    let n = set.len();

    let mut last: Option<(Micros, RequestId)> = None;
    for key in set.audit_pending() {
        if let Some(prev) = last {
            if key <= prev {
                return fail(
                    "fleet",
                    format!("shared queue not (arrival, id)-sorted: \
                             {key:?} after {prev:?}"));
            }
        }
        last = Some(key);
    }

    let mut owners: HashMap<RequestId, usize> = HashMap::new();
    for &(id, r) in set.assignments() {
        if r >= n {
            return fail("fleet",
                        format!("{id} assigned to replica {r} of {n}"));
        }
        if owners.insert(id, r).is_some() {
            return fail("fleet",
                        format!("{id} appears twice in the dispatch \
                                 log"));
        }
    }

    // Request tables disjoint across replicas, and every resident
    // request owned per the dispatch log.
    let mut resident_on: HashMap<RequestId, usize> = HashMap::new();
    for i in 0..n {
        for id in set.replica(i).audit_request_ids() {
            if let Some(j) = resident_on.insert(id, i) {
                return fail("fleet",
                            format!("{id} resident on replicas {j} and \
                                     {i}"));
            }
            if owners.get(&id) != Some(&i) {
                return fail(
                    "fleet",
                    format!("{id} resident on replica {i} but the \
                             dispatch log says {:?}", owners.get(&id)));
            }
        }
    }

    // Coverage: every placed request is findable on its owner — still
    // queued there, in its request table, or fail-fast dropped.
    for (&id, &r) in &owners {
        let e = set.replica(r);
        let known = e.request(id).is_some()
            || e.dropped.contains(&id)
            || e.audit_pending().any(|(_, pid)| pid == id);
        if !known {
            return fail("fleet",
                        format!("{id} assigned to replica {r} but not \
                                 found there"));
        }
    }

    // Shared prefix index ⊆ per-replica resident sets. With a modeled
    // network armed the mirror is eventually consistent, so the exact
    // subset check relaxes to a bounded one: a claimed-but-not-resident
    // entry is forgiven iff its removal delta is still in flight
    // (journaled but not yet gossip-delivered). At quiesce the fleet
    // flushes the network, the in-flight window empties, and the check
    // is exact again.
    if let Some(index) = set.shared_index() {
        let resident: Vec<Vec<crate::kv::prefix::BlockHash>> = (0..n)
            .map(|i| {
                let mut v = set.replica(i).resident_prefix_hashes();
                v.sort_unstable();
                v
            })
            .collect();
        for hash in index.hashes() {
            for r in index.replicas_of(hash) {
                if r >= n {
                    return fail(
                        "fleet",
                        format!("shared index maps {hash:?} to replica \
                                 {r} of {n}"));
                }
                if resident[r].binary_search(&hash).is_err() {
                    if set.net_state()
                          .is_some_and(|net| net.pending_removal(r, hash))
                    {
                        continue;
                    }
                    return fail(
                        "fleet",
                        format!("shared index claims {hash:?} on \
                                 replica {r}, but it is not resident \
                                 and no removal is in flight"));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id() -> RequestId {
        RequestId(7)
    }

    fn check(events: &[StreamEvent]) -> Result<StreamState, AuditError> {
        check_stream(id(), events.iter().copied())
    }

    #[test]
    fn well_formed_stream_passes() {
        let state = check(&[
            StreamEvent::Queued,
            StreamEvent::Placed,
            StreamEvent::Rescued,
            StreamEvent::FirstToken,
            StreamEvent::Tokens,
            StreamEvent::ApiStarted { index: 0 },
            StreamEvent::ApiCompleted { index: 0 },
            StreamEvent::Tokens,
            StreamEvent::ApiStarted { index: 1 },
            StreamEvent::ApiCompleted { index: 1 },
            StreamEvent::Terminal { finished: true },
        ])
        .unwrap();
        assert!(state.terminated());
    }

    #[test]
    fn engine_only_stream_needs_no_head_events() {
        // The engine journal has no Queued/Placed alphabet; a stream
        // may open directly with execution events.
        check(&[
            StreamEvent::FirstToken,
            StreamEvent::Tokens,
            StreamEvent::Terminal { finished: true },
        ])
        .unwrap();
        // Fail-fast drops terminate a stream that never started.
        check(&[StreamEvent::Terminal { finished: false }]).unwrap();
    }

    #[test]
    fn nothing_after_terminal() {
        let err = check(&[
            StreamEvent::Terminal { finished: false },
            StreamEvent::Tokens,
        ])
        .unwrap_err();
        assert_eq!(err.check, "stream");
        assert!(err.detail.contains("after the terminal"), "{err}");
        let err = check(&[
            StreamEvent::Terminal { finished: true },
            StreamEvent::Terminal { finished: true },
        ])
        .unwrap_err();
        assert!(err.detail.contains("after the terminal"), "{err}");
    }

    #[test]
    fn head_events_only_at_the_head() {
        assert!(check(&[StreamEvent::Queued, StreamEvent::Queued])
                    .is_err());
        assert!(check(&[
            StreamEvent::Queued,
            StreamEvent::Placed,
            StreamEvent::Placed,
        ])
        .is_err());
        assert!(check(&[
            StreamEvent::FirstToken,
            StreamEvent::Queued,
        ])
        .is_err());
    }

    #[test]
    fn rescue_must_precede_execution() {
        assert!(check(&[StreamEvent::FirstToken, StreamEvent::Rescued])
                    .is_err());
        assert!(check(&[
            StreamEvent::ApiStarted { index: 0 },
            StreamEvent::Rescued,
        ])
        .is_err());
        // Two rescues before any progress are legal (double re-queue).
        check(&[
            StreamEvent::Queued,
            StreamEvent::Placed,
            StreamEvent::Rescued,
            StreamEvent::Rescued,
            StreamEvent::Terminal { finished: false },
        ])
        .unwrap();
    }

    #[test]
    fn first_token_precedes_tokens_and_never_repeats() {
        let err = check(&[StreamEvent::Tokens]).unwrap_err();
        assert!(err.detail.contains("before FirstToken"), "{err}");
        assert!(check(&[
            StreamEvent::FirstToken,
            StreamEvent::FirstToken,
        ])
        .is_err());
        assert!(check(&[
            StreamEvent::FirstToken,
            StreamEvent::Tokens,
            StreamEvent::FirstToken,
        ])
        .is_err());
    }

    #[test]
    fn api_calls_pair_in_order_and_never_nest() {
        assert!(check(&[
            StreamEvent::ApiStarted { index: 0 },
            StreamEvent::ApiStarted { index: 1 },
        ])
        .is_err(), "nested call");
        assert!(check(&[StreamEvent::ApiStarted { index: 1 }]).is_err(),
                "out-of-order start");
        assert!(check(&[StreamEvent::ApiCompleted { index: 0 }])
                    .is_err(), "completion without a start");
        assert!(check(&[
            StreamEvent::ApiStarted { index: 0 },
            StreamEvent::ApiCompleted { index: 1 },
        ])
        .is_err(), "mismatched completion");
    }

    #[test]
    fn finishing_with_an_open_call_is_a_bug_but_dropping_is_not() {
        assert!(check(&[
            StreamEvent::ApiStarted { index: 0 },
            StreamEvent::Terminal { finished: true },
        ])
        .is_err());
        // An external call whose client vanished is aborted mid-call.
        check(&[
            StreamEvent::ApiStarted { index: 0 },
            StreamEvent::Terminal { finished: false },
        ])
        .unwrap();
    }

    #[test]
    fn engine_events_map_onto_the_machine() {
        use crate::core::request::HandlingStrategy;
        let events = [
            EngineEvent::FirstToken { id: id(), at: Micros(5) },
            EngineEvent::Tokens { id: id(), chunk: 3 },
            EngineEvent::ApiStarted {
                id: id(),
                index: 0,
                strategy: HandlingStrategy::Preserve,
                predicted: Micros(100),
                external: false,
            },
            EngineEvent::ApiCompleted {
                id: id(),
                index: 0,
                actual: Micros(90),
            },
            EngineEvent::Finished { id: id(), at: Micros(400) },
        ];
        let mut auditor = EngineAuditor::new();
        for ev in &events {
            auditor.observe_event(ev).unwrap();
        }
        let late = EngineEvent::Dropped {
            id: id(),
            reason: "late".to_string(),
        };
        let err = auditor.observe_event(&late).unwrap_err();
        assert_eq!(err.check, "stream");
    }

    #[test]
    fn audited_engine_run_stays_green_and_identical() {
        use crate::config::{AuditMode, CostModel, SystemConfig};
        use crate::core::request::RequestSpec;
        use crate::core::types::Tokens;
        use crate::workload::Trace;

        let spec = |i: u64| RequestSpec {
            id: RequestId(i),
            arrival: Micros(i * 1_000),
            prompt: String::new(),
            prompt_tokens: Tokens(4),
            api_calls: vec![],
            final_decode: Tokens(3),
        };
        let trace =
            Trace::new("t", 1.0, (0..6).map(spec).collect());
        let run = |mode: AuditMode| {
            let mut cfg = SystemConfig {
                memory_budget: Tokens(40),
                cost: CostModel::unit(),
                ..SystemConfig::default()
            };
            cfg.audit = mode;
            let mut engine = crate::engine::Engine::simulated(cfg);
            engine.run_trace(&trace).to_json(false)
        };
        assert_eq!(run(AuditMode::On), run(AuditMode::Off),
                   "the auditor must be observe-only");
    }
}
