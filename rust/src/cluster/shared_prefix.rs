//! Fleet-level shared prefix index (`--shared-prefix`): a cross-replica
//! map from content-chain [`BlockHash`] (the same hashes
//! [`crate::kv::prefix::content_chain`] gives the per-replica
//! `PrefixCache`) to the set of replicas whose *local* cache currently
//! holds that block resident.
//!
//! PR 3's fleet kept every replica's prefix cache private, so identical
//! prompts placed on different replicas re-prefilled from scratch —
//! exactly the memory-over-time waste the LAMPS rank integral is meant
//! to minimize, leaking at the placement layer. The index closes that
//! gap the way SGLang's RadixAttention motivates and Preble extends to
//! distributed placement: `--placement prefix-affinity` probes an
//! arrival's chain here, converts per-replica *consecutive leading*
//! hits into a cached-token credit, and discounts the prefill leg of
//! the arrival's fresh rank integral on the replicas that already hold
//! its prefix (see
//! [`crate::coordinator::ranking::memory_over_time_fresh_prefixed`]).
//!
//! **Synchronization.** Each replica's `PrefixCache` journals its
//! resident-set deltas ([`PrefixDelta`]: register / evict / purge); the
//! [`ReplicaSet`](super::ReplicaSet) drains the stepped replica's
//! journal after every step and feeds it through the
//! [`PrefixDeltaSink`] observer seam. With `--net-model off` (the
//! default) the fleet simulation is a sequential discrete-event loop
//! and the mirror is exact at every step boundary; with a modeled
//! network armed, the drained journal instead rides
//! [`cluster::net`](super::net) gossip and the mirror lags by up to a
//! gossip interval plus link delay (staleness costs a measured
//! re-prefill, never an error). The wall-clock serving frontend
//! drains on the exact schedule and may lag a step.
//!
//! The raw mutators [`SharedPrefixIndex::mirror_insert`] /
//! [`SharedPrefixIndex::mirror_remove`] exist for the
//! [`PrefixDeltaSink`] impl below and `cluster::net` delivery only —
//! lamps-lint rule `gossip-seam` bans them everywhere else, so no
//! code path can quietly mutate the mirror without going through the
//! journal → gossip pipeline.
//!
//! **Advisory only.** Nothing correctness-bearing reads the index: a
//! stale *present* entry merely places a request whose blocks were
//! evicted meanwhile (its admission walks the replica-local cache and
//! re-prefills the miss), and a stale *absent* entry merely misses a
//! steering opportunity. Disabled, the fleet is byte-identical to the
//! index-less PR 3 path (`tests/replica_properties.rs` pins both
//! properties).

use std::collections::HashMap;

use crate::kv::prefix::{BlockHash, PrefixDelta};

/// Replicas beyond this index are not tracked (the per-hash replica set
/// is a `u64` bitset). Untracked replicas simply never attract
/// prefix-affinity steering — advisory, not a correctness limit.
pub const MAX_TRACKED_REPLICAS: usize = 64;

/// Observer of one replica's prefix-cache resident-set deltas — the
/// seam through which [`ReplicaSet`](super::ReplicaSet) (or a test
/// double) mirrors per-replica journals into fleet-level state.
pub trait PrefixDeltaSink {
    fn on_delta(&mut self, replica: usize, delta: &PrefixDelta);
}

/// The fleet-wide hash → replica-set map. See the module docs.
#[derive(Debug, Clone, Default)]
pub struct SharedPrefixIndex {
    /// Bit `i` set ⇔ replica `i` reported the hash resident.
    map: HashMap<BlockHash, u64>,
}

impl SharedPrefixIndex {
    pub fn new() -> SharedPrefixIndex {
        SharedPrefixIndex::default()
    }

    /// Distinct hashes currently tracked.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Mark `hash` resident on `replica`.
    pub fn mirror_insert(&mut self, hash: BlockHash, replica: usize) {
        if replica >= MAX_TRACKED_REPLICAS {
            return;
        }
        *self.map.entry(hash).or_insert(0) |= 1 << replica;
    }

    /// Mark `hash` no longer resident on `replica`; the entry vanishes
    /// with its last holder (no entry survives a replica-local purge).
    pub fn mirror_remove(&mut self, hash: BlockHash, replica: usize) {
        if replica >= MAX_TRACKED_REPLICAS {
            return;
        }
        if let Some(mask) = self.map.get_mut(&hash) {
            *mask &= !(1u64 << replica);
            if *mask == 0 {
                self.map.remove(&hash);
            }
        }
    }

    /// Is `hash` recorded resident on `replica`?
    pub fn holds(&self, hash: BlockHash, replica: usize) -> bool {
        if replica >= MAX_TRACKED_REPLICAS {
            return false;
        }
        self.map
            .get(&hash)
            .is_some_and(|mask| mask & (1u64 << replica) != 0)
    }

    /// Replicas recorded holding `hash`, ascending.
    pub fn replicas_of(&self, hash: BlockHash) -> Vec<usize> {
        let Some(&mask) = self.map.get(&hash) else {
            return Vec::new();
        };
        (0..MAX_TRACKED_REPLICAS)
            .filter(|i| mask & (1u64 << i) != 0)
            .collect()
    }

    /// Every tracked hash, sorted (test/debug introspection).
    pub fn hashes(&self) -> Vec<BlockHash> {
        let mut hashes: Vec<BlockHash> = self.map.keys().copied().collect();
        hashes.sort_unstable();
        hashes
    }

    /// Per-replica cached-token credit of `chain`: for each of the
    /// first `replicas` replicas, how many **consecutive leading**
    /// chain blocks it holds resident, in tokens. Consecutive-only
    /// matches what `BlockManager::allocate_prefixed` can actually
    /// serve — the hash-consing property makes an interior hit behind a
    /// missing block unusable.
    pub fn cached_tokens_per_replica(&self, chain: &[BlockHash],
                                     block_size: u64, replicas: usize)
                                     -> Vec<u64> {
        let mut credit = vec![0u64; replicas];
        let tracked = replicas.min(MAX_TRACKED_REPLICAS);
        if tracked == 0 {
            return credit;
        }
        let mut alive: u64 = if tracked >= 64 {
            u64::MAX
        } else {
            (1u64 << tracked) - 1
        };
        for hash in chain {
            let Some(&mask) = self.map.get(hash) else {
                break;
            };
            alive &= mask;
            if alive == 0 {
                break;
            }
            for (i, c) in credit.iter_mut().enumerate().take(tracked) {
                if alive & (1u64 << i) != 0 {
                    *c += block_size;
                }
            }
        }
        credit
    }
}

impl PrefixDeltaSink for SharedPrefixIndex {
    fn on_delta(&mut self, replica: usize, delta: &PrefixDelta) {
        match *delta {
            PrefixDelta::Registered(hash) => self.mirror_insert(hash, replica),
            PrefixDelta::Removed(hash) => self.mirror_remove(hash, replica),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mirror_insert_remove_lifecycle() {
        let mut idx = SharedPrefixIndex::new();
        assert!(idx.is_empty());
        idx.mirror_insert(7, 0);
        idx.mirror_insert(7, 2);
        idx.mirror_insert(9, 1);
        assert_eq!(idx.len(), 2);
        assert!(idx.holds(7, 0) && idx.holds(7, 2) && !idx.holds(7, 1));
        assert_eq!(idx.replicas_of(7), vec![0, 2]);
        assert_eq!(idx.hashes(), vec![7, 9]);
        idx.mirror_remove(7, 0);
        assert_eq!(idx.replicas_of(7), vec![2]);
        // The entry vanishes with its last holder.
        idx.mirror_remove(7, 2);
        assert!(!idx.holds(7, 2));
        assert_eq!(idx.hashes(), vec![9]);
        // Removing an absent pair is a no-op.
        idx.mirror_remove(7, 2);
        idx.mirror_remove(42, 0);
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn sink_applies_journal_deltas() {
        let mut idx = SharedPrefixIndex::new();
        idx.on_delta(1, &PrefixDelta::Registered(5));
        idx.on_delta(3, &PrefixDelta::Registered(5));
        assert_eq!(idx.replicas_of(5), vec![1, 3]);
        idx.on_delta(1, &PrefixDelta::Removed(5));
        assert_eq!(idx.replicas_of(5), vec![3]);
        idx.on_delta(3, &PrefixDelta::Removed(5));
        assert!(idx.is_empty(), "no entry survives its last purge");
    }

    #[test]
    fn credit_counts_consecutive_leading_blocks_only() {
        let mut idx = SharedPrefixIndex::new();
        // Replica 0 holds blocks 0,1,2; replica 1 holds 0 and 2 (gap at
        // 1); replica 2 holds nothing of this chain.
        for h in [10, 11, 12] {
            idx.mirror_insert(h, 0);
        }
        idx.mirror_insert(10, 1);
        idx.mirror_insert(12, 1);
        let credit = idx.cached_tokens_per_replica(&[10, 11, 12], 16, 3);
        assert_eq!(credit, vec![48, 16, 0],
                   "an interior hit behind a gap is unusable");
        // A chain whose first block is unknown anywhere credits no one.
        assert_eq!(idx.cached_tokens_per_replica(&[99, 10], 16, 3),
                   vec![0, 0, 0]);
        // Empty chain, empty fleet: degenerate shapes stay sane.
        assert_eq!(idx.cached_tokens_per_replica(&[], 16, 3),
                   vec![0, 0, 0]);
        assert!(idx.cached_tokens_per_replica(&[10], 16, 0).is_empty());
    }

    #[test]
    fn untracked_replicas_are_ignored_not_errors() {
        let mut idx = SharedPrefixIndex::new();
        idx.mirror_insert(1, MAX_TRACKED_REPLICAS); // silently dropped
        assert!(idx.is_empty());
        idx.mirror_insert(1, 0);
        idx.mirror_remove(1, MAX_TRACKED_REPLICAS + 5); // no-op
        assert!(idx.holds(1, 0));
        assert!(!idx.holds(1, MAX_TRACKED_REPLICAS));
        // Credit for a fleet wider than the bitset: the tracked prefix
        // of replicas still gets credit, the rest get zero.
        let credit =
            idx.cached_tokens_per_replica(&[1], 4,
                                          MAX_TRACKED_REPLICAS + 2);
        assert_eq!(credit.len(), MAX_TRACKED_REPLICAS + 2);
        assert_eq!(credit[0], 4);
        assert_eq!(credit[MAX_TRACKED_REPLICAS], 0);
    }
}
