//! `cluster::net` — the modeled cross-replica network
//! (`--net-model`): every fleet-level signal that a real cluster
//! would carry over wires — shared-prefix index deltas, per-replica
//! load digests — rides a simulated network with per-link delays
//! here, instead of teleporting between replicas at step boundaries.
//!
//! # What it models
//!
//! - **Gossip-lagged prefix mirror.** Replicas buffer their
//!   [`PrefixDelta`] journals per publish window; every
//!   `--gossip-interval` the window is flushed as one delta batch
//!   onto the network. The fleet's [`SharedPrefixIndex`] therefore
//!   mirrors a *past* resident set: prefix-affinity placement can
//!   steer an arrival toward a replica that already evicted the
//!   prefix. That stale hit is measured (the `stale_steer_*` family
//!   in [`NetStats`]) and costs exactly one re-prefill — never an
//!   error, because the index has been advisory since PR 4.
//! - **Bounded-staleness load digests.** Each publish also carries a
//!   [`LoadDigest`] snapshot (memory-over-time score, live count,
//!   admission headroom). Placement and rescue read the digest table
//!   plus a top-k [`NetState::shortlist`] instead of probing every
//!   live engine, capping expensive per-arrival probes at O(k). A
//!   digest older than `--staleness-budget` (or never received) reads
//!   as "assume idle" — optimistic, corrected by the live probe or
//!   the adoption-time re-validation.
//! - **Elastic replica count.** With `--autoscale MIN:MAX`, digest
//!   pressure warms parked replicas up (prefix-cache pre-seeded from
//!   the busiest sibling) or drains active ones down on the gossip
//!   cadence.
//!
//! # Determinism contract
//!
//! The network is a deterministic discrete-event component: link
//! delays come from one seeded [`Rng`] stream (keyed off the system
//! seed), messages are delivered in `(deliver_at, send-sequence)`
//! order, and each sender's channel is FIFO (a later publish never
//! overtakes an earlier one, like a TCP stream) — so a fixed seed,
//! config, and trace replay the identical run, message for message.
//! No wall clock is read anywhere.
//!
//! # Eventual-consistency contract
//!
//! Mirror staleness is bounded by `gossip_interval + max link delay`
//! of live traffic: every delta a replica journals is published at
//! the next gossip tick and applied when its message lands. When
//! traffic quiesces (the fleet makes no more progress), the driver
//! calls [`NetState::flush`] and the mirror becomes *exact* — equal
//! to the union of live resident sets — which
//! `tests/replica_properties.rs` pins on randomized runs. Staleness
//! is never an error: a stale index claim survives in the mirror only
//! while its `Removed` delta is buffered or in flight, which is
//! exactly the window the relaxed auditor invariant
//! ([`crate::audit`]) forgives.

use std::cell::Cell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::config::NetConfig;
use crate::core::types::Micros;
use crate::engine::Engine;
use crate::kv::prefix::BlockHash;
use crate::kv::PrefixDelta;
use crate::metrics::NetStats;
use crate::util::Rng;

use super::shared_prefix::{PrefixDeltaSink, SharedPrefixIndex};

/// Elastic-fleet lifecycle state of one replica (`--autoscale`).
/// Without autoscale every replica is permanently [`Active`] and the
/// fleet behaves exactly as before this type existed.
///
/// [`Active`]: ReplicaState::Active
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Serving: placement and rescue may route work here.
    Active,
    /// Decommissioning: finishes its live work, attracts nothing new;
    /// parked once the drain completes.
    Draining,
    /// Decommissioned or not yet warmed up: holds no work and attracts
    /// none. Its clock still trails the fleet (idle-follow) so a
    /// parked replica never freezes the dispatch frontier.
    Parked,
}

/// One replica's periodically-published load snapshot — everything a
/// remote placement or rescue decision may know about it without a
/// live probe.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadDigest {
    /// Memory-over-time load aggregate at publish time
    /// ([`Engine::load_memory_over_time`]).
    pub score: f64,
    /// Live (unfinished + queued) request count at publish time.
    pub live: usize,
    /// Admission token headroom at publish time
    /// ([`Engine::digest_headroom`]) — what a rescue sweep may
    /// optimistically assume fits, before the live re-validation.
    pub headroom_tokens: u64,
    /// Publish timestamp; older than the staleness budget ⇒ the
    /// shortlist treats the replica as unknown.
    pub published_at: Micros,
}

/// A message on the simulated network.
enum Payload {
    /// One sender's gossip window of prefix-cache resident-set deltas.
    Deltas {
        from: usize,
        deltas: Vec<PrefixDelta>,
    },
    /// One sender's load snapshot.
    Digest { from: usize, digest: LoadDigest },
}

/// In-flight message: ordered by `(deliver_at, seq)` only — `seq` is
/// the global send sequence, so simultaneous deliveries stay in send
/// order and the heap order is total without comparing payloads.
struct Envelope {
    deliver_at: Micros,
    seq: u64,
    payload: Payload,
}

impl PartialEq for Envelope {
    fn eq(&self, other: &Envelope) -> bool {
        self.deliver_at == other.deliver_at && self.seq == other.seq
    }
}

impl Eq for Envelope {}

impl PartialOrd for Envelope {
    fn partial_cmp(&self, other: &Envelope) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Envelope {
    fn cmp(&self, other: &Envelope) -> std::cmp::Ordering {
        (self.deliver_at, self.seq).cmp(&(other.deliver_at, other.seq))
    }
}

/// The modeled network: per-link delays, the in-flight message heap,
/// per-replica gossip outboxes, and the digest table every bounded-
/// staleness decision reads. Owned by the
/// [`ReplicaSet`](crate::cluster::ReplicaSet) when
/// [`NetConfig::armed`] — a net-off fleet never constructs one.
pub struct NetState {
    cfg: NetConfig,
    rng: Rng,
    /// Global send sequence (total message order tiebreak).
    seq: u64,
    /// In-flight messages, min-heap by `(deliver_at, seq)`.
    inbox: BinaryHeap<Reverse<Envelope>>,
    /// Per-replica deltas journaled since that replica's last publish.
    outbox: Vec<Vec<PrefixDelta>>,
    /// Per-sender latest scheduled delivery: links are FIFO, so a new
    /// message never lands before an earlier one from the same sender.
    last_delivery: Vec<Micros>,
    /// Per-replica next publish tick.
    next_publish: Vec<Micros>,
    /// Next autoscale watermark evaluation (gossip cadence).
    next_scale_eval: Micros,
    /// Latest received digest per replica (`None` until one lands).
    digests: Vec<Option<LoadDigest>>,
    /// Per source replica: hashes with a `Removed` delta buffered or
    /// in flight (count, since a hash can churn repeatedly within one
    /// window). The audit relaxation's forgiveness set: an index
    /// claim without residency is legal exactly while its removal is
    /// still traveling.
    pending_removals: Vec<HashMap<BlockHash, usize>>,
    /// Fleet-visible stats (the `"net"` section of the fleet report).
    pub(crate) stats: NetStats,
    /// Live placement probes issued under bounded staleness —
    /// interior-mutable so probe paths stay `&self`; the
    /// `micro_fleet_scale` bench asserts O(topk) per arrival.
    probes: Cell<u64>,
}

impl NetState {
    pub fn new(cfg: NetConfig, replicas: usize, seed: u64) -> NetState {
        NetState {
            // Decorrelated from the workload generators' streams
            // (which also key off the system seed).
            rng: Rng::new(seed ^ 0x6e65_745f_6c61_6d70),
            seq: 0,
            inbox: BinaryHeap::new(),
            outbox: (0..replicas).map(|_| Vec::new()).collect(),
            last_delivery: vec![Micros::ZERO; replicas],
            next_publish: vec![Micros::ZERO; replicas],
            next_scale_eval: Micros::ZERO,
            digests: vec![None; replicas],
            pending_removals: (0..replicas).map(|_| HashMap::new())
                .collect(),
            stats: NetStats::default(),
            probes: Cell::new(0),
            cfg,
        }
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    /// Latest digest received from `replica`, if any ever landed.
    pub fn digest(&self, replica: usize) -> Option<&LoadDigest> {
        self.digests.get(replica).and_then(|d| d.as_ref())
    }

    /// Sample one one-way link delay from the seeded stream.
    fn link_delay(&mut self) -> Micros {
        match self.cfg.model.delay_bounds_us() {
            Some((lo, hi)) => Micros(self.rng.int_range(lo, hi)),
            None => Micros::ZERO,
        }
    }

    /// Put a message on the wire at `now`, preserving per-sender FIFO.
    fn send(&mut self, from: usize, now: Micros, payload: Payload) {
        let delay = self.link_delay();
        let mut at = now + delay;
        if let Some(last) = self.last_delivery.get_mut(from) {
            if at < *last {
                at = *last;
            }
            *last = at;
        }
        self.seq += 1;
        self.inbox.push(Reverse(Envelope {
            deliver_at: at,
            seq: self.seq,
            payload,
        }));
    }

    /// Buffer `replica`'s freshly-drained prefix journal into its
    /// gossip window (it rides the wire at the next publish tick).
    pub fn note_deltas(&mut self, replica: usize,
                       deltas: Vec<PrefixDelta>) {
        if deltas.is_empty() {
            return;
        }
        if let Some(pending) = self.pending_removals.get_mut(replica) {
            for delta in &deltas {
                if let PrefixDelta::Removed(h) = delta {
                    *pending.entry(*h).or_insert(0) += 1;
                }
            }
        }
        if let Some(out) = self.outbox.get_mut(replica) {
            out.extend(deltas);
        }
    }

    /// If `replica`'s publish tick is due at `now`, flush its gossip
    /// window and a fresh [`LoadDigest`] onto the network.
    pub fn publish_due(&mut self, replica: usize, now: Micros,
                       engine: &Engine) {
        match self.next_publish.get_mut(replica) {
            Some(t) if now >= *t => {
                *t = now + self.cfg.gossip_interval;
            }
            _ => return,
        }
        let window = match self.outbox.get_mut(replica) {
            Some(out) if !out.is_empty() => std::mem::take(out),
            _ => Vec::new(),
        };
        if !window.is_empty() {
            self.stats.gossip_deltas += window.len() as u64;
            self.send(replica, now, Payload::Deltas {
                from: replica,
                deltas: window,
            });
        }
        let digest = LoadDigest {
            score: engine.load_memory_over_time(),
            live: engine.live_load(),
            headroom_tokens: engine.digest_headroom().0,
            published_at: now,
        };
        self.stats.digest_publishes += 1;
        self.send(replica, now, Payload::Digest {
            from: replica,
            digest,
        });
    }

    /// Deliver every in-flight message due at or before `frontier`:
    /// delta batches land in the shared index (the sanctioned
    /// [`PrefixDeltaSink`] seam), digests refresh the table.
    pub fn deliver_until(&mut self, frontier: Micros,
                         mut index: Option<&mut SharedPrefixIndex>) {
        loop {
            match self.inbox.peek() {
                Some(Reverse(env)) if env.deliver_at <= frontier => {}
                _ => break,
            }
            let Some(Reverse(env)) = self.inbox.pop() else { break };
            self.stats.gossip_messages += 1;
            match env.payload {
                Payload::Deltas { from, deltas } => {
                    self.apply_deltas(from, &deltas,
                                      index.as_deref_mut());
                }
                Payload::Digest { from, digest } => {
                    if let Some(slot) = self.digests.get_mut(from) {
                        *slot = Some(digest);
                    }
                }
            }
        }
    }

    /// Land one delta batch: settle the pending-removal forgiveness
    /// counts and mirror into the index.
    fn apply_deltas(&mut self, from: usize, deltas: &[PrefixDelta],
                    index: Option<&mut SharedPrefixIndex>) {
        if let Some(pending) = self.pending_removals.get_mut(from) {
            for delta in deltas {
                if let PrefixDelta::Removed(h) = delta {
                    if let Some(cnt) = pending.get_mut(h) {
                        *cnt -= 1;
                        if *cnt == 0 {
                            pending.remove(h);
                        }
                    }
                }
            }
        }
        if let Some(ix) = index {
            for delta in deltas {
                ix.on_delta(from, delta);
            }
        }
    }

    /// Quiesce: deliver everything in flight and land every buffered
    /// gossip window immediately. Called when the fleet stops making
    /// progress — from here the mirror is exact (the
    /// eventual-consistency contract's convergence point).
    pub fn flush(&mut self, mut index: Option<&mut SharedPrefixIndex>) {
        self.deliver_until(Micros(u64::MAX), index.as_deref_mut());
        for from in 0..self.outbox.len() {
            let window = match self.outbox.get_mut(from) {
                Some(out) if !out.is_empty() => std::mem::take(out),
                _ => continue,
            };
            self.stats.gossip_deltas += window.len() as u64;
            self.stats.gossip_messages += 1;
            self.apply_deltas(from, &window, index.as_deref_mut());
        }
        for pending in &mut self.pending_removals {
            pending.clear();
        }
    }

    /// Is an index claim of `hash` on `replica` explainable by a
    /// removal still buffered or in flight? (The audit relaxation.)
    pub fn pending_removal(&self, replica: usize,
                           hash: BlockHash) -> bool {
        self.pending_removals
            .get(replica)
            .is_some_and(|m| m.contains_key(&hash))
    }

    /// The up-to-`topk` most attractive candidates by digest score
    /// (ascending — less load is more attractive), ties by index. A
    /// replica with no digest, or one older than the staleness
    /// budget, reads as most attractive (assume idle): optimism means
    /// a silent replica gets probed rather than forgotten, and the
    /// live probe (or rescue re-validation) corrects it. One O(n·k)
    /// insertion scan, one allocation.
    pub fn shortlist(&self, now: Micros, eligible: &[bool])
                     -> Vec<usize> {
        let k = self.cfg.topk.max(1);
        let mut best: Vec<(f64, usize)> = Vec::with_capacity(k + 1);
        for (i, ok) in eligible.iter().enumerate() {
            if !*ok {
                continue;
            }
            let score = match self.digest(i) {
                Some(d) if now
                    <= d.published_at + self.cfg.staleness_budget =>
                {
                    d.score
                }
                _ => f64::NEG_INFINITY,
            };
            let pos = best.partition_point(|&(s, j)| {
                s < score || (s == score && j < i)
            });
            if pos < k {
                best.insert(pos, (score, i));
                best.truncate(k);
            }
        }
        best.into_iter().map(|(_, i)| i).collect()
    }

    /// Count one live engine probe issued under bounded staleness
    /// (`&self`: probe paths are pure — the probe-purity contract).
    pub fn note_probe(&self) {
        self.probes.set(self.probes.get() + 1);
    }

    /// Total live probes issued so far (bench introspection: the O(k)
    /// per-arrival bound is asserted against this counter).
    pub fn probes_issued(&self) -> u64 {
        self.probes.get()
    }

    /// Is an autoscale watermark evaluation due at `now`? Consumes
    /// the tick (gossip cadence). Always false without `--autoscale`.
    pub fn autoscale_due(&mut self, now: Micros) -> bool {
        if self.cfg.autoscale.is_none() || now < self.next_scale_eval {
            return false;
        }
        self.next_scale_eval = now + self.cfg.gossip_interval;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetModelKind;

    fn lan_cfg() -> NetConfig {
        NetConfig {
            model: NetModelKind::Lan,
            ..NetConfig::default()
        }
    }

    fn digest_at(t: Micros) -> LoadDigest {
        LoadDigest {
            score: 1.0,
            live: 1,
            headroom_tokens: 100,
            published_at: t,
        }
    }

    #[test]
    fn link_delays_are_seeded_and_bounded() {
        let mut a = NetState::new(lan_cfg(), 4, 7);
        let mut b = NetState::new(lan_cfg(), 4, 7);
        let mut c = NetState::new(lan_cfg(), 4, 8);
        let (lo, hi) = NetModelKind::Lan.delay_bounds_us().unwrap();
        let da: Vec<u64> = (0..64).map(|_| a.link_delay().0).collect();
        let db: Vec<u64> = (0..64).map(|_| b.link_delay().0).collect();
        let dc: Vec<u64> = (0..64).map(|_| c.link_delay().0).collect();
        assert_eq!(da, db, "same seed, same delays");
        assert_ne!(da, dc, "different seed, different delays");
        assert!(da.iter().all(|&d| (lo..=hi).contains(&d)));
    }

    #[test]
    fn links_are_fifo_per_sender() {
        let mut net = NetState::new(lan_cfg(), 2, 3);
        // Many sends from one replica at increasing times: scheduled
        // deliveries must be non-decreasing even when a later send
        // samples a smaller delay.
        let mut last = Micros::ZERO;
        for k in 0..200u64 {
            net.send(0, Micros(k * 10), Payload::Digest {
                from: 0,
                digest: digest_at(Micros(k * 10)),
            });
            let at = net.last_delivery[0];
            assert!(at >= last, "send {k} reordered: {at:?} < {last:?}");
            last = at;
        }
    }

    #[test]
    fn deltas_apply_in_order_and_settle_pending_removals() {
        let mut net = NetState::new(lan_cfg(), 2, 5);
        let mut index = SharedPrefixIndex::new();
        let h = 42u64;
        net.note_deltas(0, vec![PrefixDelta::Registered(h)]);
        net.note_deltas(0, vec![PrefixDelta::Removed(h)]);
        assert!(net.pending_removal(0, h));
        // Nothing published yet: the mirror is empty.
        let mut e = Engine::simulated(
            crate::config::SystemConfig::default());
        net.publish_due(0, Micros::ZERO, &e);
        assert!(!index.holds(h, 0), "nothing delivered yet");
        net.deliver_until(Micros(u64::MAX), Some(&mut index));
        // Registered then Removed landed in order: net zero.
        assert!(!index.holds(h, 0));
        assert!(!net.pending_removal(0, h), "removal settled");
        // A register alone survives the trip.
        net.note_deltas(1, vec![PrefixDelta::Registered(h)]);
        net.publish_due(1, Micros(1), &e);
        net.deliver_until(Micros(u64::MAX), Some(&mut index));
        assert!(index.holds(h, 1));
        e.step();
    }

    #[test]
    fn flush_lands_unpublished_windows() {
        let mut net = NetState::new(lan_cfg(), 2, 5);
        let mut index = SharedPrefixIndex::new();
        net.note_deltas(1, vec![PrefixDelta::Registered(9)]);
        net.flush(Some(&mut index));
        assert!(index.holds(9, 1),
                "flush must land buffered windows without a publish");
        assert!(!net.pending_removal(1, 9));
    }

    #[test]
    fn shortlist_prefers_low_scores_and_assumes_unknown_idle() {
        let cfg = NetConfig {
            topk: 2,
            ..lan_cfg()
        };
        let mut net = NetState::new(cfg, 4, 1);
        let now = Micros(100_000);
        net.digests[0] = Some(LoadDigest {
            score: 5.0,
            ..digest_at(now)
        });
        net.digests[1] = Some(LoadDigest {
            score: 1.0,
            ..digest_at(now)
        });
        net.digests[2] = Some(LoadDigest {
            score: 3.0,
            ..digest_at(now)
        });
        net.digests[3] = Some(LoadDigest {
            score: 2.0,
            ..digest_at(now)
        });
        let all = vec![true; 4];
        assert_eq!(net.shortlist(now, &all), vec![1, 3]);
        // An over-budget-stale digest outranks everyone (assume idle).
        net.digests[0] = Some(LoadDigest {
            score: 5.0,
            ..digest_at(Micros::ZERO)
        });
        let now = Micros(10_000_000);
        assert_eq!(net.shortlist(now, &all), vec![0, 1]);
        // Ineligible (draining/parked) replicas never shortlist.
        let eligible = vec![false, true, true, true];
        assert_eq!(net.shortlist(now, &eligible), vec![1, 3]);
    }

    #[test]
    fn autoscale_ticks_only_when_configured() {
        let mut off = NetState::new(lan_cfg(), 2, 1);
        assert!(!off.autoscale_due(Micros(1_000_000)));
        let cfg = NetConfig {
            autoscale: Some(crate::config::AutoscaleConfig {
                min: 1,
                max: 2,
            }),
            ..lan_cfg()
        };
        let mut on = NetState::new(cfg, 2, 1);
        assert!(on.autoscale_due(Micros::ZERO));
        assert!(!on.autoscale_due(Micros(1)),
                "tick consumed until the next gossip interval");
        assert!(on.autoscale_due(Micros::ZERO
            + cfg.gossip_interval));
    }
}
