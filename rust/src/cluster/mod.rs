//! Multi-replica dispatch: a [`ReplicaSet`] owns N [`Engine`] replicas
//! (one modeled GPU each, with its own KV budget, swap space, and API
//! executor) behind one shared admission queue.
//!
//! **Placement.** Each arriving request is dispatched to exactly one
//! replica by a pluggable [`PlacementKind`] policy — least outstanding
//! memory-over-time (the LAMPS rank integral steering placement the same
//! way it steers ordering), least-loaded, or round-robin — and never
//! migrates: its KV blocks, swap traffic, and API returns all stay on
//! the owning replica (InferCept's locality argument: swapped state must
//! come back to the GPU that owns the KV layout).
//!
//! **Deterministic interleaving.** `ReplicaSet::step` always advances
//! the most-lagging replica (minimum virtual clock, ties by index), so a
//! fleet run is a deterministic discrete-event simulation no matter how
//! replica clocks drift apart. Idle replicas' clocks trail the fleet so
//! a parked replica never freezes the dispatch frontier, and every
//! replica sees the shared queue's next arrival as an idle-jump target
//! (`Engine::set_external_event`) — which is exactly what makes the
//! `replicas = 1` fleet reproduce the single-engine path byte for byte,
//! the refactor's safety rail (`tests/replica_properties.rs` asserts
//! it).
//!
//! **Fan-in.** Per-replica [`RunReport`]s are aggregated into a
//! fleet-wide report ([`RunReport::aggregate`]): counters sum, latency /
//! TTFT percentiles are rebuilt from the merged per-request samples, and
//! throughput is fleet completions over the latest replica end time.

use std::collections::VecDeque;

use crate::config::{PlacementKind, SystemConfig};
use crate::core::request::RequestSpec;
use crate::core::types::{Micros, RequestId};
use crate::engine::Engine;
use crate::metrics::RunReport;
use crate::workload::Trace;

/// Safety valve against scheduling livelock across the fleet (mirrors
/// the engine's own guard).
const MAX_FLEET_STEPS: u64 = 400_000_000;

/// Choose a replica for the next arrival under `policy`. `rr_next` is
/// the round-robin cursor (ignored by the other policies). Ties break
/// toward the lowest replica index, keeping placement deterministic.
/// Read-only over the replicas: probing a candidate never perturbs its
/// state.
///
/// Shared by the simulation driver below and the serving frontend's
/// wall-clock dispatch loop (`server::spawn_replicated`).
pub fn pick_replica(replicas: &[Engine], policy: PlacementKind,
                    rr_next: &mut usize) -> usize {
    if replicas.len() <= 1 {
        return 0;
    }
    match policy {
        PlacementKind::RoundRobin => {
            let r = *rr_next % replicas.len();
            *rr_next += 1;
            r
        }
        PlacementKind::LeastLoaded => replicas
            .iter()
            .enumerate()
            .min_by_key(|(i, e)| (e.live_load(), *i))
            .map(|(i, _)| i)
            .unwrap_or(0),
        PlacementKind::MemoryOverTime => {
            let mut best = 0usize;
            let mut best_load = f64::INFINITY;
            for (i, e) in replicas.iter().enumerate() {
                let load = e.load_memory_over_time();
                if load < best_load {
                    best = i;
                    best_load = load;
                }
            }
            best
        }
    }
}

/// Fleet-wide result of a multi-replica run: the aggregate plus each
/// replica's own report (per-replica stats are what expose placement
/// skew).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub fleet: RunReport,
    pub per_replica: Vec<RunReport>,
    pub placement: PlacementKind,
}

impl FleetReport {
    /// JSON rendering: the fleet aggregate plus per-replica reports.
    /// Timelines are per-replica gauges that do not compose into one
    /// fleet series, so `with_timeline` emits them on the per-replica
    /// reports (with one replica the fleet report *is* the replica's
    /// and carries its timeline directly).
    pub fn to_json(&self, with_timeline: bool) -> String {
        use crate::util::json::{self, Value};
        json::write(&json::obj(vec![
            ("replicas", json::num(self.per_replica.len() as f64)),
            ("placement", json::s(self.placement.label())),
            ("fleet", self.fleet.to_value(with_timeline)),
            ("per_replica",
             Value::Arr(self
                 .per_replica
                 .iter()
                 .map(|r| r.to_value(with_timeline))
                 .collect())),
        ]))
    }
}

/// N engines, one shared admission queue, a placement policy.
pub struct ReplicaSet {
    replicas: Vec<Engine>,
    policy: PlacementKind,
    /// Shared admission queue: arrival-sorted, not yet placed.
    pending: VecDeque<RequestSpec>,
    /// Dispatch log: every placed request and its owning replica.
    assignments: Vec<(RequestId, usize)>,
    rr_next: usize,
    steps: u64,
}

impl ReplicaSet {
    /// Simulated fleet: `cfg.replicas` copies of [`Engine::simulated`],
    /// each with the full per-GPU `memory_budget` and the same seed (the
    /// workload seed, not a per-replica identity).
    pub fn simulated(cfg: SystemConfig) -> ReplicaSet {
        assert!(cfg.replicas >= 1, "a fleet needs at least one replica");
        let policy = cfg.placement;
        let replicas = (0..cfg.replicas)
            .map(|_| Engine::simulated(cfg.clone()))
            .collect();
        ReplicaSet {
            replicas,
            policy,
            pending: VecDeque::new(),
            assignments: Vec::new(),
            rr_next: 0,
            steps: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn replica(&self, i: usize) -> &Engine {
        &self.replicas[i]
    }

    /// Every placed request with its owning replica, in dispatch order.
    pub fn assignments(&self) -> &[(RequestId, usize)] {
        &self.assignments
    }

    /// Fleet frontier: the minimum replica clock (the time up to which
    /// every replica's history is final).
    pub fn now(&self) -> Micros {
        self.replicas
            .iter()
            .map(|e| e.now())
            .min()
            .expect("non-empty fleet")
    }

    /// Record Fig 2 timeline points on every replica.
    pub fn set_record_timeline(&mut self, on: bool) {
        for e in &mut self.replicas {
            e.record_timeline = on;
        }
    }

    /// Queue a spec for arrival-time placement. Keeps the shared queue
    /// arrival-sorted (traces already are; the scan is O(1) for the
    /// common in-order append).
    pub fn enqueue(&mut self, spec: RequestSpec) {
        let key = (spec.arrival, spec.id);
        let mut idx = self.pending.len();
        while idx > 0 {
            let prev = &self.pending[idx - 1];
            if (prev.arrival, prev.id) <= key {
                break;
            }
            idx -= 1;
        }
        self.pending.insert(idx, spec);
    }

    /// Place every pending arrival that the fleet frontier has reached.
    fn dispatch_due(&mut self, frontier: Micros) {
        while self
            .pending
            .front()
            .is_some_and(|s| s.arrival <= frontier)
        {
            let spec = self.pending.pop_front().unwrap();
            let r = pick_replica(&self.replicas, self.policy,
                                 &mut self.rr_next);
            self.assignments.push((spec.id, r));
            self.replicas[r].enqueue(spec);
        }
    }

    /// One fleet round: dispatch due arrivals, then advance the
    /// most-lagging replica that can make progress (deterministic
    /// interleaving). Returns false when the whole fleet is idle with
    /// nothing pending.
    pub fn step(&mut self) -> bool {
        let next_arrival = self.pending.front().map(|s| s.arrival);
        let busy_min = self
            .replicas
            .iter()
            .filter(|e| e.has_live_work())
            .map(|e| e.now())
            .min();
        let Some(busy_now) = busy_min else {
            // Fully idle fleet: one jump round to the next arrival —
            // mirroring the single engine's idle jump exactly
            // (including time-cap semantics: the jump is its own round).
            let Some(t) = next_arrival else {
                return false;
            };
            for e in &mut self.replicas {
                e.advance_clock_to(t);
            }
            self.dispatch_due(t);
            return true;
        };
        // Idle replicas trail the fleet (toward the next arrival, but
        // never past the busy frontier) so a parked replica neither
        // freezes dispatch nor runs ahead of time it could still be
        // handed work for.
        let follow = match next_arrival {
            Some(t) => t.min(busy_now),
            None => busy_now,
        };
        for e in &mut self.replicas {
            if !e.has_live_work() {
                e.advance_clock_to(follow);
            }
        }
        let frontier = self.now();
        self.dispatch_due(frontier);
        // Every replica sees the next shared arrival as an idle-jump
        // target — the single-engine parity trick for the corner where
        // a replica has stuck waiters and no events of its own.
        let hint = self.pending.front().map(|s| s.arrival);
        for e in &mut self.replicas {
            e.set_external_event(hint);
        }
        let mut order: Vec<usize> = (0..self.replicas.len()).collect();
        order.sort_by_key(|&i| (self.replicas[i].now(), i));
        for i in order {
            if self.replicas[i].has_live_work() && self.replicas[i].step()
            {
                return true;
            }
        }
        // No replica progressed and (therefore) no arrivals remain: the
        // stuck remainder can never run (same termination the single
        // engine reaches).
        false
    }

    /// Drive the fleet until idle (or `time_cap` on the fleet frontier).
    pub fn run_until_idle(&mut self, time_cap: Option<Micros>) {
        while self.step() {
            if let Some(cap) = time_cap {
                if self.now() >= cap {
                    break;
                }
            }
            self.steps += 1;
            if self.steps >= MAX_FLEET_STEPS {
                panic!("fleet exceeded MAX_FLEET_STEPS — scheduling \
                        livelock?");
            }
        }
        for e in &mut self.replicas {
            e.finish_run();
        }
    }

    /// Run a trace to completion across the fleet and report.
    pub fn run_trace(&mut self, trace: &Trace) -> FleetReport {
        self.run_trace_limited(trace, None)
    }

    /// Run a trace, stopping at `time_cap` (fleet frontier) if given.
    pub fn run_trace_limited(&mut self, trace: &Trace,
                             time_cap: Option<Micros>) -> FleetReport {
        for spec in &trace.requests {
            self.enqueue(spec.clone());
        }
        self.run_until_idle(time_cap);
        self.fleet_report()
    }

    /// Per-replica reports plus the fleet aggregate. With one replica
    /// the fleet report *is* that replica's report — byte-identical to
    /// the single-engine path.
    pub fn fleet_report(&mut self) -> FleetReport {
        for e in &mut self.replicas {
            e.finish_run();
        }
        let per_replica: Vec<RunReport> = self
            .replicas
            .iter()
            .map(|e| e.metrics.report())
            .collect();
        let fleet = if per_replica.len() == 1 {
            per_replica[0].clone()
        } else {
            let mut latencies: Vec<Micros> = Vec::new();
            let mut ttfts: Vec<Micros> = Vec::new();
            for e in &self.replicas {
                for rec in e.metrics.records() {
                    if let Some(l) = rec.latency() {
                        latencies.push(l);
                    }
                    if let Some(t) = rec.ttft() {
                        ttfts.push(t);
                    }
                }
            }
            RunReport::aggregate(&per_replica, &latencies, &ttfts)
        };
        FleetReport {
            fleet,
            per_replica,
            placement: self.policy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CostModel, SchedulerKind};
    use crate::core::types::Tokens;

    fn unit_cfg(replicas: usize, placement: PlacementKind)
                -> SystemConfig {
        SystemConfig {
            scheduler: SchedulerKind::Fcfs,
            memory_budget: Tokens(100),
            max_batch: 4,
            block_size: 1,
            starvation_threshold: None,
            cost: CostModel::unit(),
            replicas,
            placement,
            ..SystemConfig::default()
        }
    }

    fn simple_spec(id: u64, arrival: u64, decode: u64) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: Micros(arrival),
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![],
            final_decode: Tokens(decode),
        }
    }

    #[test]
    fn round_robin_rotates_in_arrival_order() {
        let mut set =
            ReplicaSet::simulated(unit_cfg(3, PlacementKind::RoundRobin));
        let trace = Trace::new("t", 1.0, (0..7)
            .map(|i| simple_spec(i, i * 1000, 2))
            .collect());
        let report = set.run_trace(&trace);
        assert_eq!(report.fleet.completed, 7);
        let replicas: Vec<usize> =
            set.assignments().iter().map(|(_, r)| *r).collect();
        assert_eq!(replicas, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(report.per_replica.len(), 3);
        let per: usize =
            report.per_replica.iter().map(|r| r.completed).sum();
        assert_eq!(per, 7);
    }

    #[test]
    fn single_replica_matches_engine_run() {
        let trace = Trace::new("t", 1.0, vec![
            simple_spec(0, 0, 3),
            simple_spec(1, 500_000, 4),
            simple_spec(2, 9_000_000, 2),
        ]);
        let cfg = unit_cfg(1, PlacementKind::MemoryOverTime);
        let mut engine = Engine::simulated(cfg.clone());
        let solo = engine.run_trace(&trace);
        let mut set = ReplicaSet::simulated(cfg);
        let fleet = set.run_trace(&trace);
        assert_eq!(solo.to_json(true), fleet.fleet.to_json(true),
                   "replicas = 1 must be byte-identical");
    }

    #[test]
    fn memory_over_time_spreads_simultaneous_arrivals() {
        // Four equal simultaneous requests, four replicas: placement
        // load must include enqueued-but-unsubmitted arrivals, so each
        // replica gets exactly one (not all four piling onto replica 0).
        let mut set = ReplicaSet::simulated(
            unit_cfg(4, PlacementKind::MemoryOverTime));
        let trace = Trace::new("t", 1.0, (0..4)
            .map(|i| simple_spec(i, 0, 5))
            .collect());
        let report = set.run_trace(&trace);
        assert_eq!(report.fleet.completed, 4);
        let mut replicas: Vec<usize> =
            set.assignments().iter().map(|(_, r)| *r).collect();
        replicas.sort_unstable();
        assert_eq!(replicas, vec![0, 1, 2, 3]);
    }

    #[test]
    fn fleet_json_shape() {
        let mut set =
            ReplicaSet::simulated(unit_cfg(2, PlacementKind::LeastLoaded));
        let trace = Trace::new("t", 1.0, (0..4)
            .map(|i| simple_spec(i, i * 250_000, 2))
            .collect());
        let report = set.run_trace(&trace);
        let v = crate::util::json::parse(&report.to_json(false)).unwrap();
        assert_eq!(v.u64_field("replicas").unwrap(), 2);
        assert_eq!(v.str_field("placement").unwrap(), "least-loaded");
        assert_eq!(v.field("fleet").unwrap()
                       .u64_field("completed").unwrap(), 4);
        assert_eq!(v.field("per_replica").unwrap()
                       .as_arr().unwrap().len(), 2);
    }
}
