//! Multi-replica dispatch: a [`ReplicaSet`] owns N [`Engine`] replicas
//! (one modeled GPU each, with its own KV budget, swap space, and API
//! executor) behind one shared admission queue.
//!
//! **Placement.** Each arriving request is dispatched to exactly one
//! replica by a pluggable [`PlacementKind`] policy — least outstanding
//! memory-over-time (the LAMPS rank integral steering placement the same
//! way it steers ordering), least-loaded, or round-robin — and never
//! migrates: its KV blocks, swap traffic, and API returns all stay on
//! the owning replica (InferCept's locality argument: swapped state must
//! come back to the GPU that owns the KV layout).
//!
//! **Deterministic interleaving.** `ReplicaSet::step` always advances
//! the most-lagging replica (minimum virtual clock, ties by index), so a
//! fleet run is a deterministic discrete-event simulation no matter how
//! replica clocks drift apart. Idle replicas' clocks trail the fleet so
//! a parked replica never freezes the dispatch frontier, and every
//! replica sees the shared queue's next arrival as an idle-jump target
//! (`Engine::set_external_event`) — which is exactly what makes the
//! `replicas = 1` fleet reproduce the single-engine path byte for byte,
//! the refactor's safety rail (`tests/replica_properties.rs` asserts
//! it).
//!
//! **Fan-in.** Per-replica [`RunReport`]s are aggregated into a
//! fleet-wide report ([`RunReport::aggregate`]): counters sum, latency /
//! TTFT percentiles are rebuilt from the merged per-request samples, and
//! throughput is fleet completions over the latest replica end time.
//!
//! **Cross-replica prefix sharing** (`--shared-prefix`, see
//! [`shared_prefix`]): replicas journal their prefix-cache resident-set
//! deltas, the fleet mirrors them into a [`SharedPrefixIndex`], and
//! `--placement prefix-affinity` discounts the prefill leg of the
//! arrival's rank integral on replicas that already hold its prefix.
//!
//! **Placement-aware admission re-queue**
//! (`SystemConfig::admission_requeue`): a request memory-rejected by
//! its owner before it ever ran is re-queued once to the best sibling
//! with free KV instead of waiting out the owner's pressure.
//!
//! **Modeled network** (`--net-model`, see [`net`]): with a network
//! armed, cross-replica signals stop teleporting — prefix deltas ride
//! seeded-delay gossip (the mirror lags; a stale steer costs a
//! measured re-prefill), placement and rescue read bounded-staleness
//! load digests plus a top-k shortlist instead of probing every
//! replica live (O(k) probes per arrival), and `--autoscale` drains
//! or warms replicas on the gossip cadence. `--net-model off` (the
//! default) constructs none of it and stays byte-identical to the
//! exact-mirror fleet.

pub mod net;
pub mod shared_prefix;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};

use crate::config::{PlacementKind, SystemConfig};
use crate::core::request::RequestSpec;
use crate::core::types::{Micros, RequestId, Tokens};
use crate::engine::Engine;
use crate::kv::prefix;
use crate::metrics::{NetStats, RunReport, SharedPrefixStats};
use crate::workload::Trace;

pub use net::{LoadDigest, NetState, ReplicaState};
pub use shared_prefix::{PrefixDeltaSink, SharedPrefixIndex};

/// Safety valve against scheduling livelock across the fleet (mirrors
/// the engine's own guard).
const MAX_FLEET_STEPS: u64 = 400_000_000;

/// Blocks a warming replica pre-seeds from a sibling's resident set
/// (`--autoscale` warm-up): enough to carry the hot shared prefixes,
/// small enough that warm-up never floods a replica's free list.
const PRESEED_MAX_BLOCKS: u64 = 64;

/// One arrival's placement-time scratch state: the spec plus its
/// lazily-computed, computed-at-most-once prompt content chain.
///
/// Before this existed, `prefix_credits` hashed the prompt from
/// scratch on every probe — and the same arrival could be hashed again
/// by a rescue sweep and a third time by the owning engine at
/// admission. The scratch pins the one-shot contract: the chain is
/// computed on first use (never at all for policies that don't need
/// it), every later probe borrows it, and [`ArrivalScratch::into_chain`]
/// hands the finished chain to the chosen replica's memo
/// (`Engine::seed_chain`) so admission and registration extend it
/// instead of rehashing. Interior-mutable (`OnceCell`) so placement
/// probes stay `&`-only — the probe-purity lint's contract.
pub struct ArrivalScratch<'a> {
    spec: &'a RequestSpec,
    block_size: u64,
    chain: std::cell::OnceCell<Vec<prefix::BlockHash>>,
}

impl<'a> ArrivalScratch<'a> {
    /// Scratch for one arrival at the fleet's KV block size (clamped
    /// to 1 so a degenerate config cannot divide by zero).
    pub fn new(spec: &'a RequestSpec, block_size: u64)
               -> ArrivalScratch<'a> {
        ArrivalScratch {
            spec,
            block_size: block_size.max(1),
            chain: std::cell::OnceCell::new(),
        }
    }

    pub fn spec(&self) -> &RequestSpec {
        self.spec
    }

    /// The arrival's full-prompt content chain, hashed on first call
    /// and borrowed thereafter.
    fn chain(&self) -> &[prefix::BlockHash] {
        self.chain.get_or_init(|| {
            prefix::content_chain(self.spec, self.block_size,
                                  self.spec.prompt_tokens)
        })
    }

    /// Surrender the chain if any probe computed it (`None` means no
    /// probe needed hashing — nothing to seed). The caller forwards it
    /// to the placed replica's chain memo.
    pub fn into_chain(self) -> Option<Vec<prefix::BlockHash>> {
        self.chain.into_inner()
    }
}

/// Choose a replica for the next arrival under `policy`, returning the
/// chosen index and — for prefix-affinity placement — the cached-token
/// credit the choice was steered by (zero for every other policy, or
/// when no [`SharedPrefixIndex`] is supplied). `rr_next` is the
/// round-robin cursor (ignored by the other policies). Ties break
/// toward the lowest replica index, keeping placement deterministic.
/// Read-only over the replicas: probing a candidate never perturbs its
/// state. The arrival comes wrapped in an [`ArrivalScratch`] so its
/// prompt is hashed at most once across every probe of the placement
/// path.
///
/// Shared by the simulation driver below and the serving frontend's
/// wall-clock dispatch loop (`server::spawn_replicated`).
pub fn pick_replica(replicas: &[Engine], policy: PlacementKind,
                    rr_next: &mut usize, arrival: &ArrivalScratch<'_>,
                    shared: Option<&SharedPrefixIndex>)
                    -> (usize, Tokens) {
    if replicas.len() <= 1 {
        return (0, Tokens::ZERO);
    }
    match policy {
        PlacementKind::RoundRobin => {
            let r = *rr_next % replicas.len();
            *rr_next += 1;
            (r, Tokens::ZERO)
        }
        PlacementKind::LeastLoaded => (
            replicas
                .iter()
                .enumerate()
                .min_by_key(|(i, e)| (e.live_load(), *i))
                .map(|(i, _)| i)
                .unwrap_or(0),
            Tokens::ZERO,
        ),
        PlacementKind::MemoryOverTime => {
            let mut best = 0usize;
            let mut best_load = f64::INFINITY;
            for (i, e) in replicas.iter().enumerate() {
                let load = e.load_memory_over_time();
                if load < best_load {
                    best = i;
                    best_load = load;
                }
            }
            (best, Tokens::ZERO)
        }
        PlacementKind::PrefixAffinity => {
            // Probe the arrival's content chain against the fleet
            // index: each replica's consecutive leading resident blocks
            // become a cached-token credit that discounts the prefill
            // leg of the arrival's own rank integral on that replica —
            // the same memory-over-time objective, now seeing what each
            // replica already holds.
            let credits = prefix_credits(replicas, arrival, shared);
            let mut best = 0usize;
            let mut best_score = f64::INFINITY;
            for ((i, e), &credit) in
                replicas.iter().enumerate().zip(&credits)
            {
                let score = e.placement_score_prefixed(arrival.spec(),
                                                       Tokens(credit));
                if score < best_score {
                    best = i;
                    best_score = score;
                }
            }
            let credit = credits.get(best).copied().unwrap_or(0);
            (best, Tokens(credit))
        }
    }
}

/// Bounded-staleness variant of [`pick_replica`] (`--net-model`
/// armed): instead of probing every replica live, the choice reads
/// the gossip digest table and probes only the top-k
/// [`NetState::shortlist`] — plus, under prefix-affinity, the top-k
/// credit holders of the (stale) mirror, so a request's prefix home
/// stays probeable even when its load digest is mid-pack — capping
/// expensive per-arrival live probes at O(k) no matter the fleet
/// size. Ineligible (draining/parked) replicas are never chosen.
/// Ties break toward the lowest index, and every live probe is
/// counted via [`NetState::note_probe`] so the `micro_fleet_scale`
/// bench can assert the O(k) bound.
pub fn pick_replica_bounded(replicas: &[Engine], policy: PlacementKind,
                            rr_next: &mut usize,
                            arrival: &ArrivalScratch<'_>,
                            shared: Option<&SharedPrefixIndex>,
                            netstate: &NetState, now: Micros,
                            eligible: &[bool]) -> (usize, Tokens) {
    if replicas.len() <= 1 {
        return (0, Tokens::ZERO);
    }
    let n = replicas.len();
    let fallback = eligible.iter().position(|&ok| ok).unwrap_or(0);
    match policy {
        PlacementKind::RoundRobin => {
            // Rotate the cursor over eligible replicas only.
            for _ in 0..n {
                let r = *rr_next % n;
                *rr_next += 1;
                if eligible.get(r).copied().unwrap_or(false) {
                    return (r, Tokens::ZERO);
                }
            }
            (fallback, Tokens::ZERO)
        }
        PlacementKind::LeastLoaded => {
            let mut best: Option<(usize, usize)> = None;
            for i in netstate.shortlist(now, eligible) {
                let Some(e) = replicas.get(i) else { continue };
                netstate.note_probe();
                let load = e.live_load();
                if best.map_or(true, |(bl, bi)| (load, i) < (bl, bi)) {
                    best = Some((load, i));
                }
            }
            (best.map_or(fallback, |(_, i)| i), Tokens::ZERO)
        }
        PlacementKind::MemoryOverTime => {
            let mut best: Option<(f64, usize)> = None;
            for i in netstate.shortlist(now, eligible) {
                let Some(e) = replicas.get(i) else { continue };
                netstate.note_probe();
                let load = e.load_memory_over_time();
                let better = best.map_or(true, |(bs, bi)| {
                    load < bs || (load == bs && i < bi)
                });
                if better {
                    best = Some((load, i));
                }
            }
            (best.map_or(fallback, |(_, i)| i), Tokens::ZERO)
        }
        PlacementKind::PrefixAffinity => {
            let credits = prefix_credits(replicas, arrival, shared);
            let mut cands = netstate.shortlist(now, eligible);
            let k = netstate.config().topk.max(1);
            let mut holders: Vec<(u64, usize)> = credits
                .iter()
                .enumerate()
                .filter(|&(i, &c)| {
                    c > 0 && eligible.get(i).copied().unwrap_or(false)
                })
                .map(|(i, &c)| (c, i))
                .collect();
            holders.sort_unstable_by_key(|&(c, i)| (Reverse(c), i));
            for &(_, i) in holders.iter().take(k) {
                if !cands.contains(&i) {
                    cands.push(i);
                }
            }
            // Ascending index + strict < keeps ties deterministic.
            cands.sort_unstable();
            let mut best: Option<(f64, usize)> = None;
            for i in cands {
                let Some(e) = replicas.get(i) else { continue };
                let credit = credits.get(i).copied().unwrap_or(0);
                netstate.note_probe();
                let score = e.placement_score_prefixed(arrival.spec(),
                                                       Tokens(credit));
                if best.map_or(true, |(bs, _)| score < bs) {
                    best = Some((score, i));
                }
            }
            let r = best.map_or(fallback, |(_, i)| i);
            (r, Tokens(credits.get(r).copied().unwrap_or(0)))
        }
    }
}

/// Bounded-staleness rescue target choice: candidates are filtered and
/// scored on published load digests alone — optimistically, a replica
/// with no fresh digest reads as roomy and idle — so a sweep costs
/// O(replicas) cheap arithmetic and **zero** live probes. The caller
/// must re-validate the winner against the live engine
/// ([`Engine::can_fit_fresh_with`]) at adoption time: a stale digest
/// may say "fits" when reality will not.
fn pick_rescue_sibling_bounded(netstate: &NetState, owner: usize,
                               now: Micros, eligible: &[bool],
                               promised: &[u64], needed: u64)
                               -> Option<usize> {
    let budget = netstate.config().staleness_budget;
    let mut best: Option<(f64, usize)> = None;
    for (j, ok) in eligible.iter().enumerate() {
        if j == owner || !*ok {
            continue;
        }
        let fresh = netstate
            .digest(j)
            .filter(|d| now <= d.published_at + budget);
        let headroom = fresh.map_or(u64::MAX, |d| d.headroom_tokens);
        if headroom
            < needed + promised.get(j).copied().unwrap_or(0)
        {
            continue;
        }
        let score = fresh.map_or(f64::NEG_INFINITY, |d| d.score);
        // Ascending j + strict < keeps the lowest index on ties.
        if best.map_or(true, |(bs, _)| score < bs) {
            best = Some((score, j));
        }
    }
    best.map(|(_, j)| j)
}

/// Best sibling able to admit `spec` right now, excluding `owner` —
/// the admission re-queue's target choice. Scored like placement:
/// under prefix-affinity with a live index the sibling's score carries
/// the same prefill-leg discount for resident prefixes (so a rescue
/// never silently defeats steering — the request's prefix home wins
/// whenever it can admit); every other policy takes the least
/// outstanding memory-over-time. Ties to the lowest index. Siblings
/// that cannot admit the spec are skipped *before* any scoring, so in
/// the saturated-fleet case (everyone full) a call costs O(replicas)
/// cheap arithmetic, and the O(live) load probes run only when a
/// rescue is actually about to happen — at most once per request,
/// thanks to the caller's once-only guard.
///
/// `reserved[j]` tokens are already promised to replica `j` by earlier
/// moves of the same sweep (block-rounded), so one sweep cannot
/// overcommit a sibling whose adoptees hold no KV yet.
///
/// Returns the chosen sibling and its cached-token credit (zero
/// outside prefix-affinity).
pub fn pick_rescue_sibling(replicas: &[Engine], owner: usize,
                           arrival: &ArrivalScratch<'_>,
                           policy: PlacementKind,
                           shared: Option<&SharedPrefixIndex>,
                           reserved: &[u64])
                           -> Option<(usize, Tokens)> {
    // Admissibility first: in the saturated case nothing below runs —
    // no prompt hashing, no load sums.
    let fitting: Vec<usize> = replicas
        .iter()
        .enumerate()
        .filter(|&(j, e)| {
            j != owner
                && e.can_fit_fresh_with(
                    arrival.spec(),
                    Tokens(reserved.get(j).copied().unwrap_or(0)))
        })
        .map(|(j, _)| j)
        .collect();
    if fitting.is_empty() {
        return None;
    }
    let affinity = policy == PlacementKind::PrefixAffinity;
    let credits: Vec<u64> = if affinity {
        prefix_credits(replicas, arrival, shared)
    } else {
        vec![0; replicas.len()]
    };
    let mut best: Option<(f64, usize)> = None;
    for &j in &fitting {
        let Some(e) = replicas.get(j) else { continue };
        let credit = credits.get(j).copied().unwrap_or(0);
        let score = if affinity {
            e.placement_score_prefixed(arrival.spec(), Tokens(credit))
        } else {
            e.load_memory_over_time()
        };
        // Ascending j: strict < keeps the lowest index on ties.
        let better = match best {
            None => true,
            Some((bs, _)) => score < bs,
        };
        if better {
            best = Some((score, j));
        }
    }
    best.map(|(_, j)| {
        (j, Tokens(credits.get(j).copied().unwrap_or(0)))
    })
}

/// Per-replica cached-token credits of the arrival's prompt chain
/// against the shared index — the probe shared by prefix-affinity
/// placement and the rescue target choice. All zeros when no index is
/// supplied or it is empty (nothing is hashed in that case); otherwise
/// the chain is borrowed from the [`ArrivalScratch`], which hashes it
/// once per arrival no matter how many probes ask.
fn prefix_credits(replicas: &[Engine], arrival: &ArrivalScratch<'_>,
                  shared: Option<&SharedPrefixIndex>) -> Vec<u64> {
    match shared {
        Some(index) if !index.is_empty() => {
            index.cached_tokens_per_replica(arrival.chain(),
                                            arrival.block_size,
                                            replicas.len())
        }
        _ => vec![0; replicas.len()],
    }
}

/// One admission re-queue sweep over `owner`'s stranded requests — the
/// protocol core shared by the simulated fleet
/// ([`ReplicaSet::rescue_stranded`]) and the serving frontend: skip
/// ids already moved once (`requeued`), pick the target via
/// [`pick_rescue_sibling`], withdraw from the owner, adopt on the
/// target. Returns the moves made as `(id, target, credit)` so each
/// driver applies its own side effects (dispatch-log rewrite and
/// steering-stats re-booking vs. completion-watcher re-pointing).
pub fn rescue_stranded_on(replicas: &mut [Engine], owner: usize,
                          policy: PlacementKind,
                          shared: Option<&SharedPrefixIndex>,
                          requeued: &mut HashSet<RequestId>)
                          -> Vec<(RequestId, usize, Tokens)> {
    // lamps-lint: allow(panic) owner is a valid replica index by contract
    let stranded = replicas[owner].stranded_waiting();
    if stranded.is_empty() {
        return Vec::new();
    }
    let block_size =
        replicas.first().map_or(1, |e| e.cfg.block_size).max(1);
    // Tokens promised to each sibling: its own owed-but-unadmitted
    // backlog (covering adoptees of *previous* sweeps, which hold no
    // KV until admitted and are invisible to the block manager) plus
    // this sweep's earlier moves. Without the reservation, sweeps
    // could overcommit one sibling and burn later victims' once-only
    // guards on moves that leave them worse off. Block-rounded,
    // matching what admission will allocate.
    let mut promised: Vec<u64> = replicas
        .iter()
        .map(|e| e.owed_admission_tokens().0)
        .collect();
    let mut moves = Vec::new();
    for id in stranded {
        if requeued.contains(&id) {
            continue;
        }
        let (target, chain) = {
            // lamps-lint: allow(panic) owner is a valid replica index by contract
            let Some(req) = replicas[owner].request(id) else {
                continue;
            };
            let arrival = ArrivalScratch::new(&req.spec, block_size);
            let target = pick_rescue_sibling(replicas, owner, &arrival,
                                             policy, shared, &promised);
            (target, arrival.into_chain())
        };
        let Some((j, credit)) = target else {
            continue; // no sibling can admit it either — leave it
        };
        // lamps-lint: allow(panic) owner is a valid replica index by contract
        let Some(w) = replicas[owner].withdraw_waiting(id) else {
            continue;
        };
        if let Some(p) = promised.get_mut(j) {
            *p += (w.spec.prompt_tokens.0 + 1).div_ceil(block_size)
                * block_size;
        }
        requeued.insert(id);
        if let Some(chain) = chain {
            // The sweep already hashed the prompt for its probes — hand
            // the chain to the adopter so admission extends it in place.
            // lamps-lint: allow(panic) pick_rescue_sibling returns an in-range sibling
            replicas[j].seed_chain(id, block_size, chain);
        }
        // lamps-lint: allow(panic) pick_rescue_sibling returns an in-range sibling
        replicas[j].adopt(w);
        moves.push((id, j, credit));
    }
    moves
}

/// Fleet-wide result of a multi-replica run: the aggregate plus each
/// replica's own report (per-replica stats are what expose placement
/// skew).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub fleet: RunReport,
    pub per_replica: Vec<RunReport>,
    pub placement: PlacementKind,
    /// Shared prefix index stats — `Some` only when `--shared-prefix`
    /// was active, so the index-less fleet JSON (the PR 3 shape) stays
    /// byte-identical with the feature off.
    pub shared_prefix: Option<SharedPrefixStats>,
    /// Modeled-network stats — `Some` only when `--net-model` was
    /// armed, so the net-off fleet JSON stays byte-identical to the
    /// PR 9 shape (the same Option-gated-key discipline as
    /// `shared_prefix`).
    pub net: Option<NetStats>,
}

impl FleetReport {
    /// JSON rendering: the fleet aggregate plus per-replica reports.
    /// Timelines are per-replica gauges that do not compose into one
    /// fleet series, so `with_timeline` emits them on the per-replica
    /// reports (with one replica the fleet report *is* the replica's
    /// and carries its timeline directly).
    pub fn to_json(&self, with_timeline: bool) -> String {
        use crate::util::json::{self, Value};
        let mut pairs = vec![
            ("replicas", json::num(self.per_replica.len() as f64)),
            ("placement", json::s(self.placement.label())),
            ("fleet", self.fleet.to_value(with_timeline)),
            ("per_replica",
             Value::Arr(self
                 .per_replica
                 .iter()
                 .map(|r| r.to_value(with_timeline))
                 .collect())),
        ];
        if let Some(stats) = &self.shared_prefix {
            pairs.push(("shared_prefix", stats.to_value()));
        }
        if let Some(stats) = &self.net {
            pairs.push(("net", stats.to_value()));
        }
        json::write(&json::obj(pairs))
    }
}

/// N engines, one shared admission queue, a placement policy — plus,
/// under `--shared-prefix`, the fleet-level [`SharedPrefixIndex`] the
/// prefix-affinity placement probes.
pub struct ReplicaSet {
    replicas: Vec<Engine>,
    policy: PlacementKind,
    /// Shared admission queue: arrival-sorted, not yet placed.
    pending: VecDeque<RequestSpec>,
    /// Dispatch log: every placed request and its owning replica (a
    /// re-queued request's entry is rewritten to its final owner).
    assignments: Vec<(RequestId, usize)>,
    rr_next: usize,
    steps: u64,
    /// Fleet-wide hash → replica-set mirror of the per-replica prefix
    /// caches (`--shared-prefix`); `None` keeps the PR 3 path intact.
    shared: Option<SharedPrefixIndex>,
    /// Steering stats reported alongside the fleet report; `Some` iff
    /// `shared` is.
    shared_stats: Option<SharedPrefixStats>,
    /// Placement-aware admission re-queue enabled
    /// (`cfg.admission_requeue`, replicas > 1).
    requeue: bool,
    /// Requests already re-queued once — a second strandedness is
    /// genuine fleet-wide pressure, and bouncing would thrash.
    requeued: HashSet<RequestId>,
    /// Which replica each steered request was credited to (and for how
    /// many tokens), so a later rescue can re-book the stats against
    /// where the request actually ended up.
    steered_log: HashMap<RequestId, (usize, u64)>,
    /// Fleet-level invariant audit ([`crate::audit::check_fleet`])
    /// after every step, per `cfg.audit`. Observe-only; the
    /// per-replica engines additionally run their own auditors.
    audit: bool,
    /// The modeled network (`--net-model` armed, replicas > 1); `None`
    /// keeps every pre-net code path byte-identical.
    netstate: Option<NetState>,
    /// Per-replica elastic lifecycle state; all `Active` without
    /// `--autoscale`.
    states: Vec<ReplicaState>,
    /// Parallel to `states`: may placement/rescue route work to the
    /// replica? Rebuilt on every state transition so per-arrival reads
    /// allocate nothing.
    eligible: Vec<bool>,
    /// Requests whose bounded-staleness rescue was refused once at
    /// adoption-time re-validation (stale digest said "fits", the live
    /// engine said no). The refusal does not burn the once-only
    /// `requeued` guard — a second refusal does, so a request can
    /// never thrash between refusals forever.
    rescue_refused: HashSet<RequestId>,
    /// Clock-keyed min-heap over `(replica clock, index)` driving the
    /// most-lagging-first step order. Entries go stale when a clock
    /// advances and are lazily re-filed on pop, so a round that makes
    /// progress on the first candidate costs O(log n) instead of the
    /// old O(n log n) full sort — the 256-replica sweep fix. Exactly
    /// one entry per replica at all times.
    step_heap: BinaryHeap<Reverse<(Micros, usize)>>,
    /// Round stamp per replica: `step_seen[i] == step_round` ⇔ replica
    /// `i` already had its turn this round (heap dedup without a
    /// per-round allocation).
    step_seen: Vec<u64>,
    step_round: u64,
    /// Entries popped this round that must return to the heap at round
    /// end (already-seen or idle replicas) — a reusable buffer.
    step_deferred: Vec<(Micros, usize)>,
    /// Test-only switch back to the original full-sort step order; the
    /// equivalence test pins heap == scan, step for step.
    #[cfg(test)]
    legacy_scan: bool,
    /// Test-only journal of every replica index actually stepped, in
    /// order — what the heap/scan equivalence test compares.
    #[cfg(test)]
    stepped_log: Vec<usize>,
}

impl ReplicaSet {
    /// Simulated fleet: `cfg.replicas` copies of [`Engine::simulated`],
    /// each with the full per-GPU `memory_budget` and the same seed (the
    /// workload seed, not a per-replica identity).
    pub fn simulated(cfg: SystemConfig) -> ReplicaSet {
        assert!(cfg.replicas >= 1, "a fleet needs at least one replica");
        let policy = cfg.placement;
        let track_shared = cfg.shared_prefix && cfg.prefix_cache.enabled
            && cfg.replicas > 1;
        let requeue = cfg.admission_requeue && cfg.replicas > 1;
        let n = cfg.replicas;
        let replicas: Vec<Engine> = (0..n)
            .map(|_| Engine::simulated(cfg.clone()))
            .collect();
        let netstate = cfg
            .net
            .armed(n)
            .then(|| NetState::new(cfg.net, n, cfg.seed));
        // With autoscale, the fleet boots at the floor: the first
        // `min` replicas are active, the rest parked until digest
        // pressure warms them up. Otherwise everyone serves, always.
        let states: Vec<ReplicaState> = match (&netstate,
                                               cfg.net.autoscale) {
            (Some(_), Some(scale)) => (0..n)
                .map(|i| if i < scale.min.min(n) {
                    ReplicaState::Active
                } else {
                    ReplicaState::Parked
                })
                .collect(),
            _ => vec![ReplicaState::Active; n],
        };
        let eligible = states
            .iter()
            .map(|s| *s == ReplicaState::Active)
            .collect();
        let step_heap = replicas
            .iter()
            .enumerate()
            .map(|(i, e)| Reverse((e.now(), i)))
            .collect();
        ReplicaSet {
            replicas,
            policy,
            pending: VecDeque::new(),
            assignments: Vec::new(),
            rr_next: 0,
            steps: 0,
            shared: track_shared.then(SharedPrefixIndex::new),
            shared_stats: track_shared
                .then(|| SharedPrefixStats::new(n)),
            requeue,
            requeued: HashSet::new(),
            steered_log: HashMap::new(),
            audit: cfg.audit.enabled(),
            netstate,
            states,
            eligible,
            rescue_refused: HashSet::new(),
            step_heap,
            step_seen: vec![0; n],
            step_round: 0,
            step_deferred: Vec::new(),
            #[cfg(test)]
            legacy_scan: false,
            #[cfg(test)]
            stepped_log: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn replica(&self, i: usize) -> &Engine {
        // lamps-lint: allow(panic) Vec-style API — out-of-range is the caller's bug
        &self.replicas[i]
    }

    /// `(arrival, id)` of every spec still in the shared admission
    /// queue, in queue order (invariant-auditor tap).
    pub(crate) fn audit_pending(
        &self) -> impl Iterator<Item = (Micros, RequestId)> + '_ {
        self.pending.iter().map(|s| (s.arrival, s.id))
    }

    /// Every placed request with its owning replica, in dispatch order.
    /// A request the admission re-queue moved appears once, under its
    /// final owner.
    pub fn assignments(&self) -> &[(RequestId, usize)] {
        &self.assignments
    }

    /// The fleet-level shared prefix index, when `--shared-prefix` (and
    /// the per-replica prefix cache) is active.
    pub fn shared_index(&self) -> Option<&SharedPrefixIndex> {
        self.shared.as_ref()
    }

    /// Steering stats of the shared index (`Some` iff it is active).
    pub fn shared_stats(&self) -> Option<&SharedPrefixStats> {
        self.shared_stats.as_ref()
    }

    /// The modeled network, when `--net-model` is armed (the audit
    /// layer reads its pending-removal forgiveness set; tests and
    /// benches read its stats and probe counter).
    pub fn net_state(&self) -> Option<&NetState> {
        self.netstate.as_ref()
    }

    /// Per-replica elastic lifecycle states (all `Active` without
    /// `--autoscale`).
    pub fn replica_states(&self) -> &[ReplicaState] {
        &self.states
    }

    /// Fleet frontier: the minimum replica clock (the time up to which
    /// every replica's history is final).
    pub fn now(&self) -> Micros {
        self.replicas
            .iter()
            .map(|e| e.now())
            .min()
            // lamps-lint: allow(panic) the constructor asserts replicas >= 1
            .expect("non-empty fleet")
    }

    /// Record Fig 2 timeline points on every replica.
    pub fn set_record_timeline(&mut self, on: bool) {
        for e in &mut self.replicas {
            e.record_timeline = on;
        }
    }

    /// The replica currently responsible for `id` — consulted so
    /// externally-resolved API returns route to the request's *current*
    /// owner (the admission re-queue may have moved it after
    /// placement; a parked request itself never moves, because only
    /// never-scheduled requests are relocatable).
    pub fn owner_of(&self, id: RequestId) -> Option<usize> {
        self.replicas
            .iter()
            .position(|e| e.request(id).is_some())
    }

    /// Resolve an externally-held API call (`--api-source external`)
    /// on whichever replica owns the request — the fleet-level twin of
    /// [`Engine::complete_api_call`].
    pub fn complete_api_call(&mut self, id: RequestId, index: usize,
                             response_tokens: Tokens)
                             -> anyhow::Result<()> {
        let Some(owner) = self.owner_of(id) else {
            anyhow::bail!("unknown request {id}");
        };
        // lamps-lint: allow(panic) owner_of returns an in-range position
        self.replicas[owner].complete_api_call(id, index,
                                               response_tokens)
    }

    /// Queue a spec for arrival-time placement, keeping the shared
    /// queue arrival-sorted. `partition_point` binary search: O(log n)
    /// comparisons per insert even for the serve frontend's
    /// out-of-order submissions (the old backward scan degenerated to
    /// O(n²) total there), and equal keys land *after* their peers —
    /// the same stable order the scan produced. In-order trace appends
    /// still cost one comparison plus a tail push.
    pub fn enqueue(&mut self, spec: RequestSpec) {
        let key = (spec.arrival, spec.id);
        let idx = self
            .pending
            .partition_point(|s| (s.arrival, s.id) <= key);
        self.pending.insert(idx, spec);
    }

    /// Place every pending arrival that the fleet frontier has reached.
    fn dispatch_due(&mut self, frontier: Micros) {
        while self
            .pending
            .front()
            .is_some_and(|s| s.arrival <= frontier)
        {
            let Some(spec) = self.pending.pop_front() else { break };
            let block_size = self
                .replicas
                .first()
                .map_or(1, |e| e.cfg.block_size)
                .max(1);
            let arrival = ArrivalScratch::new(&spec, block_size);
            let (r, credit) = match self.netstate.as_ref() {
                Some(netstate) => pick_replica_bounded(
                    &self.replicas, self.policy, &mut self.rr_next,
                    &arrival, self.shared.as_ref(), netstate, frontier,
                    &self.eligible),
                None => pick_replica(&self.replicas, self.policy,
                                     &mut self.rr_next, &arrival,
                                     self.shared.as_ref()),
            };
            // Stale-steer accounting: a gossip-lagged credit may claim
            // blocks the chosen replica already evicted. Measure the
            // overclaim against what is actually resident — the tokens
            // the arrival will re-prefill instead of sharing. Never an
            // error: admission walks the live cache either way.
            if credit > Tokens::ZERO {
                if let Some(netstate) = self.netstate.as_mut() {
                    let actual = self
                        .replicas
                        .get(r)
                        .map_or(0, |e| {
                            e.cached_lead_tokens(arrival.chain())
                        });
                    netstate.stats
                        .note_stale_steer(
                            credit.0.saturating_sub(actual));
                }
            }
            if let Some(chain) = arrival.into_chain() {
                // Placement hashed the prompt once — seed the chosen
                // replica's memo so admission/registration extend it
                // instead of rehashing the same bytes.
                // lamps-lint: allow(panic) pick_replica returns an in-range index
                self.replicas[r].seed_chain(spec.id, block_size, chain);
            }
            // A spec submit would fail-fast drop (it can never fit an
            // empty replica) must not count as steering — the credit
            // will never be served.
            // lamps-lint: allow(panic) pick_replica returns an in-range index
            if self.replicas[r].fits_capacity(&spec) {
                if let Some(stats) = self.shared_stats.as_mut() {
                    stats.note(r, credit.0);
                    if credit > Tokens::ZERO {
                        self.steered_log.insert(spec.id, (r, credit.0));
                    }
                }
            }
            self.assignments.push((spec.id, r));
            // lamps-lint: allow(panic) pick_replica returns an in-range index
            self.replicas[r].enqueue(spec);
        }
    }

    /// Mirror replica `i`'s journaled prefix-cache resident-set deltas
    /// into the fleet index through the [`PrefixDeltaSink`] observer
    /// seam (no-op unless `--shared-prefix` armed the journals). With
    /// a modeled network armed, the deltas board the gossip outbox
    /// instead and reach the index only when their message lands — the
    /// mirror lags, which is the point.
    fn absorb_prefix_deltas(&mut self, i: usize) {
        if self.shared.is_none() {
            return;
        }
        // lamps-lint: allow(panic) callers pass the index they just stepped
        let deltas = self.replicas[i].drain_prefix_deltas();
        if deltas.is_empty() {
            return;
        }
        match self.netstate.as_mut() {
            Some(netstate) => netstate.note_deltas(i, deltas),
            None => {
                if let Some(index) = self.shared.as_mut() {
                    for delta in &deltas {
                        index.on_delta(i, delta);
                    }
                }
            }
        }
    }

    /// Placement-aware admission re-queue (the ROADMAP follow-on to
    /// multi-replica dispatch): a request OOM-rejected by replica
    /// `owner` before it ever ran — holding nothing there — is
    /// withdrawn and re-queued **once** to the best sibling that can
    /// admit it right now ([`pick_rescue_sibling`]: owner excluded,
    /// scored like placement — prefix-affinity keeps its discount — and
    /// ties to the lowest index). Its starvation state moves with it,
    /// its dispatch-log entry is rewritten so every request still has
    /// exactly one owner, and any dispatch-time steering claim is
    /// re-booked against the rescue target. Returns whether any request
    /// moved (fleet-level progress).
    fn rescue_stranded(&mut self, owner: usize) -> bool {
        if !self.requeue {
            return false;
        }
        let moves = if self.netstate.is_some() {
            self.rescue_moves_bounded(owner)
        } else {
            rescue_stranded_on(&mut self.replicas, owner, self.policy,
                               self.shared.as_ref(),
                               &mut self.requeued)
        };
        for &(id, j, credit) in &moves {
            // The dispatch-time steering claim no longer holds once the
            // request leaves the replica it was steered to: re-book the
            // stats against the rescue target's actual credit.
            if let Some(stats) = self.shared_stats.as_mut() {
                if let Some((r0, tokens)) = self.steered_log.remove(&id)
                {
                    stats.unnote(r0, tokens);
                }
                stats.note(j, credit.0);
                if credit > Tokens::ZERO {
                    self.steered_log.insert(id, (j, credit.0));
                }
            }
            if let Some(entry) = self
                .assignments
                .iter_mut()
                .rev()
                .find(|(rid, _)| *rid == id)
            {
                entry.1 = j;
            }
        }
        !moves.is_empty()
    }

    /// Bounded-staleness rescue sweep (`--net-model` armed): targets
    /// come from [`pick_rescue_sibling_bounded`] — digest headroom and
    /// digest load, zero live probes — and the **one** live check runs
    /// at adoption time: [`Engine::can_fit_fresh_with`] against the
    /// chosen sibling, because a stale digest can say "fits" when
    /// reality will not. A refused rescue leaves the request stranded
    /// on its owner *without* burning the once-only `requeued` guard
    /// (it re-queues on a later sweep, with fresher digests); a second
    /// refusal burns it — genuine fleet-wide pressure, and bouncing
    /// would thrash.
    fn rescue_moves_bounded(&mut self, owner: usize)
                            -> Vec<(RequestId, usize, Tokens)> {
        let Some(stranded) = self
            .replicas
            .get(owner)
            .map(|e| e.stranded_waiting())
        else {
            return Vec::new();
        };
        if stranded.is_empty() {
            return Vec::new();
        }
        let Some(netstate) = self.netstate.as_mut() else {
            return Vec::new();
        };
        let now = self
            .replicas
            .iter()
            .map(|e| e.now())
            .min()
            .unwrap_or(Micros::ZERO);
        let block_size = self
            .replicas
            .first()
            .map_or(1, |e| e.cfg.block_size)
            .max(1);
        let round = |t: u64| t.div_ceil(block_size) * block_size;
        let mut promised: Vec<u64> = self
            .replicas
            .iter()
            .map(|e| e.owed_admission_tokens().0)
            .collect();
        let mut moves = Vec::new();
        for id in stranded {
            if self.requeued.contains(&id) {
                continue;
            }
            let (target, chain) = {
                let Some(req) = self
                    .replicas
                    .get(owner)
                    .and_then(|e| e.request(id))
                else {
                    continue;
                };
                let needed = round(req.spec.prompt_tokens.0 + 1);
                let arrival =
                    ArrivalScratch::new(&req.spec, block_size);
                let target = pick_rescue_sibling_bounded(
                    netstate, owner, now, &self.eligible, &promised,
                    needed);
                // The steering stats re-book against the target's
                // stale-mirror credit, like dispatch.
                let credit = match (target, self.policy) {
                    (Some(j), PlacementKind::PrefixAffinity) => {
                        prefix_credits(&self.replicas, &arrival,
                                       self.shared.as_ref())
                            .get(j)
                            .copied()
                            .unwrap_or(0)
                    }
                    _ => 0,
                };
                (target.map(|j| (j, credit)), arrival.into_chain())
            };
            let Some((j, credit)) = target else {
                continue; // no digest promises room — leave it
            };
            // Adoption-time re-validation against the live engine —
            // the sweep's one live probe.
            netstate.note_probe();
            let fits = {
                let Some(req) = self
                    .replicas
                    .get(owner)
                    .and_then(|e| e.request(id))
                else {
                    continue;
                };
                self.replicas.get(j).is_some_and(|e| {
                    e.can_fit_fresh_with(
                        &req.spec,
                        Tokens(promised.get(j).copied().unwrap_or(0)))
                })
            };
            if !fits {
                netstate.stats.rescue_refusals += 1;
                if !self.rescue_refused.insert(id) {
                    // Second refusal: burn the guard for real.
                    self.requeued.insert(id);
                }
                continue;
            }
            let Some(w) = self
                .replicas
                .get_mut(owner)
                .and_then(|e| e.withdraw_waiting(id))
            else {
                continue;
            };
            if let Some(p) = promised.get_mut(j) {
                *p += round(w.spec.prompt_tokens.0 + 1);
            }
            self.requeued.insert(id);
            if let Some(chain) = chain {
                if let Some(e) = self.replicas.get_mut(j) {
                    e.seed_chain(id, block_size, chain);
                }
            }
            if let Some(e) = self.replicas.get_mut(j) {
                e.adopt(w);
            }
            moves.push((id, j, Tokens(credit)));
        }
        moves
    }

    /// One fleet round: dispatch due arrivals, then advance the
    /// most-lagging replica that can make progress (deterministic
    /// interleaving). Returns false when the whole fleet is idle with
    /// nothing pending.
    pub fn step(&mut self) -> bool {
        let progressed = self.step_inner();
        if self.audit {
            if let Err(e) = crate::audit::check_fleet(self) {
                // lamps-lint: allow(panic) a tripped audit invariant is a fleet bug — fail loudly
                panic!("{e}");
            }
        }
        progressed
    }

    fn step_inner(&mut self) -> bool {
        let next_arrival = self.pending.front().map(|s| s.arrival);
        let busy_min = self
            .replicas
            .iter()
            .filter(|e| e.has_live_work())
            .map(|e| e.now())
            .min();
        let Some(busy_now) = busy_min else {
            // Fully idle fleet: one jump round to the next arrival —
            // mirroring the single engine's idle jump exactly
            // (including time-cap semantics: the jump is its own round).
            let Some(t) = next_arrival else {
                // Nothing in flight, nothing pending: quiesce — land
                // every buffered gossip message so the mirror
                // converges to exact before the fleet reports idle.
                self.net_flush();
                return false;
            };
            for e in &mut self.replicas {
                e.advance_clock_to(t);
            }
            if self.netstate.is_some() {
                self.net_pump(t);
            }
            self.dispatch_due(t);
            return true;
        };
        // Idle replicas trail the fleet (toward the next arrival, but
        // never past the busy frontier) so a parked replica neither
        // freezes dispatch nor runs ahead of time it could still be
        // handed work for.
        let follow = match next_arrival {
            Some(t) => t.min(busy_now),
            None => busy_now,
        };
        for e in &mut self.replicas {
            if !e.has_live_work() {
                e.advance_clock_to(follow);
            }
        }
        let frontier = self.now();
        if self.netstate.is_some() {
            self.net_pump(frontier);
        }
        self.dispatch_due(frontier);
        // Every replica sees the next shared arrival as an idle-jump
        // target — the single-engine parity trick for the corner where
        // a replica has stuck waiters and no events of its own.
        let hint = self.pending.front().map(|s| s.arrival);
        for e in &mut self.replicas {
            e.set_external_event(hint);
        }
        #[cfg(test)]
        {
            if self.legacy_scan {
                return self.step_round_scan();
            }
        }
        let progressed = self.step_round_heap();
        if !progressed {
            // No replica progressed and (therefore) no arrivals
            // remain: the stuck remainder can never run (same
            // termination the single engine reaches). Converge the
            // mirror before reporting idle.
            self.net_flush();
        }
        progressed
    }

    /// One round of most-lagging-first stepping over the clock-keyed
    /// min-heap. Identical step order to the old full sort — the
    /// round's order is fixed by the clocks at round start; stale
    /// entries (clock advanced since push) are lazily re-filed on pop,
    /// already-stepped and idle replicas are deferred back to the heap
    /// at round end — but a round that progresses on its first
    /// candidate pops O(1) entries instead of sorting all n
    /// (`heap_matches_legacy_scan_step_order` pins the equivalence).
    fn step_round_heap(&mut self) -> bool {
        self.step_round += 1;
        let round = self.step_round;
        self.step_deferred.clear();
        let mut result = false;
        while let Some(Reverse((t, i))) = self.step_heap.pop() {
            let Some(now_i) = self.replicas.get(i).map(|e| e.now())
            else {
                continue;
            };
            if self.step_seen.get(i).copied() == Some(round) {
                // This replica already had its turn (its refreshed
                // entry rose back to the top); keep it for later
                // rounds.
                self.step_deferred.push((t, i));
                continue;
            }
            if t != now_i {
                // Stale after a clock advance: re-file at the true
                // position and re-examine in order.
                self.step_heap.push(Reverse((now_i, i)));
                continue;
            }
            if let Some(s) = self.step_seen.get_mut(i) {
                *s = round;
            }
            let live = self
                .replicas
                .get(i)
                .is_some_and(|e| e.has_live_work());
            if !live {
                self.step_deferred.push((t, i));
                continue;
            }
            self.note_stepped(i);
            // lamps-lint: allow(panic) the heap holds indexes of this very Vec
            let progressed = self.replicas[i].step();
            // A step mutates only the stepped replica — mirror its
            // prefix-cache resident-set deltas into the shared index
            // even when it reported no progress (a no-progress step
            // can still have purged cache entries while dropping an
            // oversized request), then give any request it
            // memory-rejected before first run a one-time chance on a
            // sibling with free KV. A rescue is fleet progress in its
            // own right: the moved request must get its turn even if
            // every replica's own step stalled this round.
            self.absorb_prefix_deltas(i);
            let rescued = self.rescue_stranded(i);
            let refreshed = self
                .replicas
                .get(i)
                .map_or(t, |e| e.now());
            self.step_heap.push(Reverse((refreshed, i)));
            if progressed || rescued {
                result = true;
                break;
            }
        }
        for &(t, i) in &self.step_deferred {
            self.step_heap.push(Reverse((t, i)));
        }
        result
    }

    /// The pre-heap step order — a full `(clock, index)` sort every
    /// round — kept verbatim so the equivalence test can pin the heap
    /// against it, step for step and byte for byte.
    #[cfg(test)]
    fn step_round_scan(&mut self) -> bool {
        let mut order: Vec<usize> = (0..self.replicas.len()).collect();
        order.sort_by_key(|&i| (self.replicas[i].now(), i));
        for i in order {
            if !self.replicas[i].has_live_work() {
                continue;
            }
            self.note_stepped(i);
            let progressed = self.replicas[i].step();
            self.absorb_prefix_deltas(i);
            let rescued = self.rescue_stranded(i);
            if progressed || rescued {
                return true;
            }
        }
        self.net_flush();
        false
    }

    #[cfg(test)]
    fn note_stepped(&mut self, i: usize) {
        self.stepped_log.push(i);
    }

    #[cfg(not(test))]
    fn note_stepped(&mut self, _i: usize) {}

    /// Land every in-flight and buffered gossip message (no-op with
    /// the network off). Called at every quiesce point so the mirror's
    /// eventual-consistency contract — exact at idle — holds.
    fn net_flush(&mut self) {
        if let Some(netstate) = self.netstate.as_mut() {
            netstate.flush(self.shared.as_mut());
        }
    }

    /// One modeled-network round: publish each replica's due gossip
    /// window and load digest at its own clock, deliver everything due
    /// at the fleet frontier, then run the elastic-fleet tick.
    fn net_pump(&mut self, frontier: Micros) {
        if let Some(netstate) = self.netstate.as_mut() {
            for (i, e) in self.replicas.iter().enumerate() {
                netstate.publish_due(i, e.now(), e);
            }
            netstate.deliver_until(frontier, self.shared.as_mut());
        }
        self.autoscale_tick(frontier);
    }

    /// Elastic replica count (`--autoscale MIN:MAX`): park any replica
    /// whose drain completed, then — on the gossip cadence — warm a
    /// parked replica up when digest pressure says the active set is
    /// saturated (pre-seeding its prefix cache from the sibling with
    /// the largest resident set), or start draining an idle replica
    /// down toward the floor when the fleet has gone quiet. Every
    /// decision reads published digests only (bounded staleness), so
    /// it is deterministic and needs no live probes.
    fn autoscale_tick(&mut self, frontier: Micros) {
        for (i, e) in self.replicas.iter_mut().enumerate() {
            if self.states.get(i).copied() == Some(ReplicaState::Draining)
                && e.drain_complete()
            {
                e.set_draining(false);
                if let Some(s) = self.states.get_mut(i) {
                    *s = ReplicaState::Parked;
                }
            }
        }
        let Some(netstate) = self.netstate.as_mut() else {
            return;
        };
        let Some(scale) = netstate.config().autoscale else {
            return;
        };
        if !netstate.autoscale_due(frontier) {
            return;
        }
        let active: Vec<usize> = self
            .states
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == ReplicaState::Active)
            .map(|(i, _)| i)
            .collect();
        let saturated = active
            .iter()
            .filter(|&&i| {
                netstate
                    .digest(i)
                    .is_some_and(|d| d.headroom_tokens == 0)
            })
            .count();
        let idle = active
            .iter()
            .filter(|&&i| {
                netstate.digest(i).is_some_and(|d| d.live == 0)
            })
            .count();
        let want_up = self.pending.len() > active.len()
            || saturated * 2 > active.len();
        if want_up && active.len() < scale.max {
            let parked = self
                .states
                .iter()
                .position(|s| *s == ReplicaState::Parked);
            if let Some(p) = parked {
                // Warm-up: pre-seed the newcomer's prefix cache from
                // the sibling with the largest resident set, so its
                // first arrivals hit instead of cold-starting.
                let donor = active
                    .iter()
                    .copied()
                    .max_by_key(|&i| {
                        (self
                             .replicas
                             .get(i)
                             .map_or(0,
                                     |e| e.resident_prefix_hashes()
                                         .len()),
                         Reverse(i))
                    });
                if let Some(d) = donor {
                    let hashes = self
                        .replicas
                        .get(d)
                        .map(|e| e.resident_prefix_hashes())
                        .unwrap_or_default();
                    if !hashes.is_empty() {
                        if let Some(e) = self.replicas.get_mut(p) {
                            e.preseed_prefix_cache(&hashes,
                                                   PRESEED_MAX_BLOCKS);
                            // The seeded blocks are journaled like any
                            // resident-set change — put them on the
                            // wire now so the mirror learns about the
                            // newcomer's warm cache.
                            let deltas = e.drain_prefix_deltas();
                            netstate.note_deltas(p, deltas);
                        }
                    }
                }
                if let Some(s) = self.states.get_mut(p) {
                    *s = ReplicaState::Active;
                }
                netstate.stats.scale_ups += 1;
            }
        } else if self.pending.is_empty()
            && active.len() > scale.min
            && idle > 0
        {
            // Drain the highest-index active replica that is idle
            // right now; it parks once its (empty) drain completes.
            let victim = active.iter().copied().rev().find(|&i| {
                self.replicas
                    .get(i)
                    .is_some_and(|e| !e.has_live_work())
            });
            if let Some(v) = victim {
                if let Some(e) = self.replicas.get_mut(v) {
                    e.set_draining(true);
                }
                if let Some(s) = self.states.get_mut(v) {
                    *s = ReplicaState::Draining;
                }
                netstate.stats.scale_downs += 1;
            }
        }
        self.eligible = self
            .states
            .iter()
            .map(|s| *s == ReplicaState::Active)
            .collect();
    }

    /// Drive the fleet until idle (or `time_cap` on the fleet frontier).
    pub fn run_until_idle(&mut self, time_cap: Option<Micros>) {
        while self.step() {
            if let Some(cap) = time_cap {
                if self.now() >= cap {
                    break;
                }
            }
            self.steps += 1;
            if self.steps >= MAX_FLEET_STEPS {
                // lamps-lint: allow(panic) livelock safety valve — aborting beats spinning forever
                panic!("fleet exceeded MAX_FLEET_STEPS — scheduling \
                        livelock?");
            }
        }
        for e in &mut self.replicas {
            e.finish_run();
        }
    }

    /// Run a trace to completion across the fleet and report.
    pub fn run_trace(&mut self, trace: &Trace) -> FleetReport {
        self.run_trace_limited(trace, None)
    }

    /// Run a trace, stopping at `time_cap` (fleet frontier) if given.
    pub fn run_trace_limited(&mut self, trace: &Trace,
                             time_cap: Option<Micros>) -> FleetReport {
        for spec in &trace.requests {
            self.enqueue(spec.clone());
        }
        self.run_until_idle(time_cap);
        self.fleet_report()
    }

    /// Per-replica reports plus the fleet aggregate. With one replica
    /// the fleet report *is* that replica's report — byte-identical to
    /// the single-engine path.
    pub fn fleet_report(&mut self) -> FleetReport {
        for e in &mut self.replicas {
            e.finish_run();
        }
        let per_replica: Vec<RunReport> = self
            .replicas
            .iter()
            .map(|e| e.metrics.report())
            .collect();
        let fleet = if per_replica.len() == 1 {
            // lamps-lint: allow(panic) guarded by the length check above
            per_replica[0].clone()
        } else {
            let mut latencies: Vec<Micros> = Vec::new();
            let mut ttfts: Vec<Micros> = Vec::new();
            for e in &self.replicas {
                for rec in e.metrics.records() {
                    if let Some(l) = rec.latency() {
                        latencies.push(l);
                    }
                    if let Some(t) = rec.ttft() {
                        ttfts.push(t);
                    }
                }
            }
            RunReport::aggregate(&per_replica, &latencies, &ttfts)
        };
        FleetReport {
            fleet,
            per_replica,
            placement: self.policy,
            shared_prefix: self.shared_stats.clone(),
            net: self.netstate.as_ref().map(|n| n.stats().clone()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CostModel, HandlingPolicy, SchedulerKind};
    use crate::core::request::{ApiCallSpec, ApiType, HandlingStrategy};
    use crate::core::types::Tokens;

    fn unit_cfg(replicas: usize, placement: PlacementKind)
                -> SystemConfig {
        SystemConfig {
            scheduler: SchedulerKind::Fcfs,
            memory_budget: Tokens(100),
            max_batch: 4,
            block_size: 1,
            starvation_threshold: None,
            cost: CostModel::unit(),
            replicas,
            placement,
            ..SystemConfig::default()
        }
    }

    fn simple_spec(id: u64, arrival: u64, decode: u64) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: Micros(arrival),
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![],
            final_decode: Tokens(decode),
        }
    }

    #[test]
    fn round_robin_rotates_in_arrival_order() {
        let mut set =
            ReplicaSet::simulated(unit_cfg(3, PlacementKind::RoundRobin));
        let trace = Trace::new("t", 1.0, (0..7)
            .map(|i| simple_spec(i, i * 1000, 2))
            .collect());
        let report = set.run_trace(&trace);
        assert_eq!(report.fleet.completed, 7);
        let replicas: Vec<usize> =
            set.assignments().iter().map(|(_, r)| *r).collect();
        assert_eq!(replicas, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(report.per_replica.len(), 3);
        let per: usize =
            report.per_replica.iter().map(|r| r.completed).sum();
        assert_eq!(per, 7);
    }

    #[test]
    fn single_replica_matches_engine_run() {
        let trace = Trace::new("t", 1.0, vec![
            simple_spec(0, 0, 3),
            simple_spec(1, 500_000, 4),
            simple_spec(2, 9_000_000, 2),
        ]);
        let cfg = unit_cfg(1, PlacementKind::MemoryOverTime);
        let mut engine = Engine::simulated(cfg.clone());
        let solo = engine.run_trace(&trace);
        let mut set = ReplicaSet::simulated(cfg);
        let fleet = set.run_trace(&trace);
        assert_eq!(solo.to_json(true), fleet.fleet.to_json(true),
                   "replicas = 1 must be byte-identical");
    }

    #[test]
    fn memory_over_time_spreads_simultaneous_arrivals() {
        // Four equal simultaneous requests, four replicas: placement
        // load must include enqueued-but-unsubmitted arrivals, so each
        // replica gets exactly one (not all four piling onto replica 0).
        let mut set = ReplicaSet::simulated(
            unit_cfg(4, PlacementKind::MemoryOverTime));
        let trace = Trace::new("t", 1.0, (0..4)
            .map(|i| simple_spec(i, 0, 5))
            .collect());
        let report = set.run_trace(&trace);
        assert_eq!(report.fleet.completed, 4);
        let mut replicas: Vec<usize> =
            set.assignments().iter().map(|(_, r)| *r).collect();
        replicas.sort_unstable();
        assert_eq!(replicas, vec![0, 1, 2, 3]);
    }

    #[test]
    fn enqueue_keeps_reversed_arrivals_sorted() {
        // Regression for the O(n²) backward-scan insert: reversed
        // arrival order is its worst case and the serve frontend's
        // realistic one. The queue must stay (arrival, id)-sorted.
        let mut set =
            ReplicaSet::simulated(unit_cfg(2, PlacementKind::RoundRobin));
        for i in (0..64u64).rev() {
            set.enqueue(simple_spec(i, i * 1_000, 1));
        }
        // Equal-arrival duplicates pin the id tie-break too.
        set.enqueue(simple_spec(90, 10_000, 1));
        set.enqueue(simple_spec(70, 10_000, 1));
        let keys: Vec<(Micros, RequestId)> =
            set.pending.iter().map(|s| (s.arrival, s.id)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "queue must stay arrival-sorted");
        assert_eq!(set.pending.len(), 66);
    }

    #[test]
    fn requeue_rescues_stranded_request_to_idle_sibling() {
        // Regression (placement-aware admission): round-robin puts X on
        // replica 0, whose memory request H holds through a 100 000 s
        // Preserve API call, while replica 1 goes idle after its short
        // job. PR 3 stranded X on replica 0 until the API returned; the
        // re-queue must move it to the idle sibling and serve it now.
        let h = RequestSpec {
            id: RequestId(0),
            arrival: Micros(0),
            prompt: String::new(),
            prompt_tokens: Tokens(25),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(2),
                api_type: ApiType::Qa,
                duration: Micros(100_000 * 1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(1),
        };
        let run = |requeue: bool| {
            let mut cfg = unit_cfg(2, PlacementKind::RoundRobin);
            cfg.memory_budget = Tokens(30);
            cfg.handling =
                HandlingPolicy::Forced(HandlingStrategy::Preserve);
            cfg.admission_requeue = requeue;
            let mut set = ReplicaSet::simulated(cfg);
            let trace = Trace::new("t", 1.0, vec![
                h.clone(),
                simple_spec(1, 0, 2),
                RequestSpec {
                    prompt_tokens: Tokens(4),
                    ..simple_spec(2, 1_000_000, 2)
                },
            ]);
            let report = set.run_trace(&trace);
            assert_eq!(report.fleet.completed, 3,
                       "every request completes either way");
            set
        };

        let rescued = run(true);
        let owner: Vec<usize> = rescued
            .assignments()
            .iter()
            .filter(|(id, _)| *id == RequestId(2))
            .map(|(_, r)| *r)
            .collect();
        assert_eq!(owner, vec![1],
                   "X must be re-homed (once) to the idle sibling");
        assert!(rescued.replica(0).request(RequestId(2)).is_none(),
                "no trace of X may remain on the rejecting owner");
        let x = rescued.replica(1).request(RequestId(2)).unwrap();
        assert!(x.is_finished());
        assert!(x.finished_at.unwrap() < Micros(60_000_000),
                "rescued X must finish long before the API returns \
                 (got {})", x.finished_at.unwrap());

        // Without the re-queue, X is stranded behind the full owner
        // until the 100 000 s call returns — the PR 3 failure mode.
        let stranded = run(false);
        let x = stranded.replica(0).request(RequestId(2)).unwrap();
        assert!(x.finished_at.unwrap() > Micros(100_000 * 1_000_000),
                "control run must reproduce the stranding");
    }

    #[test]
    fn external_api_returns_route_to_owner_replica() {
        // `--api-source external` at fleet level: the parked request's
        // return must route to the replica that owns it, the fleet must
        // go idle (not livelock) while the call is unresolved, and a
        // misdirected result must be refused.
        let mut cfg = unit_cfg(2, PlacementKind::RoundRobin);
        cfg.api_source = crate::config::ApiSourceKind::External;
        cfg.handling =
            HandlingPolicy::Forced(HandlingStrategy::Preserve);
        let mut set = ReplicaSet::simulated(cfg);
        set.enqueue(RequestSpec {
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(2),
                api_type: ApiType::Qa,
                duration: Micros(5_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(1),
            ..simple_spec(0, 0, 0)
        });
        set.enqueue(simple_spec(1, 0, 2));
        set.run_until_idle(None);
        // Round-robin: id 0 on replica 0 (parked), id 1 on replica 1
        // (finished); the fleet idles with the call outstanding.
        assert_eq!(set.owner_of(RequestId(0)), Some(0));
        assert!(set.replica(0).request(RequestId(0)).unwrap()
                    .in_api_wait());
        assert!(set.replica(1).request(RequestId(1)).unwrap()
                    .is_finished());
        assert!(set.complete_api_call(RequestId(9), 0, Tokens(0))
                    .is_err(), "unknown request refused");
        set.complete_api_call(RequestId(0), 0, Tokens(3)).unwrap();
        set.run_until_idle(None);
        let r0 = set.replica(0).request(RequestId(0)).unwrap();
        assert!(r0.is_finished());
        assert_eq!(r0.logical_context, Tokens(6),
                   "2 decoded + 3 tool-result tokens + 1 final");
        assert_eq!(set.replica(0).metrics.api_calls_completed, 1,
                   "the predicted-vs-actual gap is observable");
    }

    #[test]
    fn heap_matches_legacy_scan_step_order() {
        // Satellite 1: the clock-keyed min-heap must reproduce the old
        // full-sort most-lagging order exactly — same replicas stepped
        // in the same order, same final report bytes.
        let trace = Trace::new("t", 1.0, (0..40)
            .map(|i| RequestSpec {
                prompt_tokens: Tokens(i % 7),
                ..simple_spec(i, i * 137_000, (i % 5) + 1)
            })
            .collect());
        let run = |legacy: bool| {
            let mut set = ReplicaSet::simulated(
                unit_cfg(5, PlacementKind::RoundRobin));
            set.legacy_scan = legacy;
            let report = set.run_trace(&trace);
            (set.stepped_log.clone(), report.to_json(true))
        };
        let (heap_log, heap_json) = run(false);
        let (scan_log, scan_json) = run(true);
        assert!(!heap_log.is_empty());
        assert_eq!(heap_log, scan_log,
                   "heap and scan must step identical replica order");
        assert_eq!(heap_json, scan_json);
    }

    #[test]
    fn bounded_rescue_revalidates_before_adopting() {
        // Satellite 2: with no fresh digest the bounded rescue
        // optimistically assumes the sibling is roomy — the live
        // `can_fit_fresh` re-validation at adoption time must catch
        // the lie, and the first refusal must not burn the once-only
        // re-queue guard (the second does).
        use crate::config::NetModelKind;
        let mut cfg = unit_cfg(2, PlacementKind::RoundRobin);
        cfg.memory_budget = Tokens(30);
        cfg.handling =
            HandlingPolicy::Forced(HandlingStrategy::Preserve);
        cfg.admission_requeue = true;
        cfg.net.model = NetModelKind::Lan;
        let hog = |id: u64| RequestSpec {
            prompt_tokens: Tokens(25),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(2),
                api_type: ApiType::Qa,
                duration: Micros(100_000 * 1_000_000),
                response_tokens: Tokens(0),
            }],
            ..simple_spec(id, 0, 1)
        };
        let mut set = ReplicaSet::simulated(cfg.clone());
        assert!(set.net_state().is_some(), "lan model arms the net");
        // Both replicas park a 27-token hog behind a 100 000 s call;
        // replica 0 additionally strands a 4-token victim.
        set.replicas[0].enqueue(hog(0));
        set.replicas[1].enqueue(hog(1));
        for e in &mut set.replicas {
            e.step(); // drain the arrival into the waiting queue
            while e.has_runnable_work() {
                e.step();
            }
        }
        let victim = RequestSpec {
            prompt_tokens: Tokens(4),
            ..simple_spec(2, 0, 1)
        };
        set.replicas[0].enqueue(victim);
        set.replicas[0].step();
        assert_eq!(set.replicas[0].stranded_waiting(),
                   vec![RequestId(2)]);
        // No digest ever published: the picker assumes replica 1 is
        // roomy, the live check refuses, the guard survives.
        assert!(set.rescue_moves_bounded(0).is_empty());
        assert_eq!(set.net_state().unwrap().stats().rescue_refusals, 1);
        assert!(!set.requeued.contains(&RequestId(2)),
                "first refusal must not burn the once-only guard");
        assert!(set.rescue_refused.contains(&RequestId(2)));
        // Second refusal burns it; a third sweep skips the request.
        assert!(set.rescue_moves_bounded(0).is_empty());
        assert_eq!(set.net_state().unwrap().stats().rescue_refusals, 2);
        assert!(set.requeued.contains(&RequestId(2)),
                "second refusal burns the guard");
        assert!(set.rescue_moves_bounded(0).is_empty());
        assert_eq!(set.net_state().unwrap().stats().rescue_refusals, 2);

        // Same setup with an idle sibling: re-validation passes and
        // the move happens on the first sweep.
        let mut set = ReplicaSet::simulated(cfg);
        set.replicas[0].enqueue(hog(0));
        set.replicas[0].step();
        while set.replicas[0].has_runnable_work() {
            set.replicas[0].step();
        }
        set.replicas[0].enqueue(RequestSpec {
            prompt_tokens: Tokens(4),
            ..simple_spec(2, 0, 1)
        });
        set.replicas[0].step();
        let moves = set.rescue_moves_bounded(0);
        assert_eq!(moves, vec![(RequestId(2), 1, Tokens(0))]);
        assert!(set.requeued.contains(&RequestId(2)));
        assert!(set.replicas[1].request(RequestId(2)).is_some());
    }

    #[test]
    fn autoscale_warms_up_under_backlog_and_drains_at_quiesce() {
        use crate::config::{AutoscaleConfig, NetModelKind};
        let mut cfg = unit_cfg(3, PlacementKind::LeastLoaded);
        cfg.net.model = NetModelKind::Lan;
        cfg.net.autoscale = Some(AutoscaleConfig { min: 1, max: 3 });
        let mut set = ReplicaSet::simulated(cfg);
        assert_eq!(set.replica_states(),
                   &[ReplicaState::Active, ReplicaState::Parked,
                     ReplicaState::Parked],
                   "autoscale boots at the floor");
        let trace = Trace::new("t", 1.0, (0..12)
            .map(|i| simple_spec(i, 0, 3))
            .collect());
        let report = set.run_trace(&trace);
        assert_eq!(report.fleet.completed, 12,
                   "elasticity must never lose a request");
        let stats = report.net.as_ref().unwrap();
        assert!(stats.scale_ups >= 1,
                "a 12-deep backlog on one active replica must warm a \
                 parked sibling up (got {} scale-ups)", stats.scale_ups);
        let active = set
            .replica_states()
            .iter()
            .filter(|s| **s == ReplicaState::Active)
            .count();
        assert!(active >= 1, "the floor is always staffed");
        for (i, s) in set.replica_states().iter().enumerate() {
            if *s != ReplicaState::Active {
                assert!(!set.replica(i).has_live_work(),
                        "a non-active replica must hold no live work");
            }
        }
    }

    #[test]
    fn net_off_keeps_net_key_out_of_fleet_json() {
        let mut set =
            ReplicaSet::simulated(unit_cfg(2, PlacementKind::RoundRobin));
        let trace = Trace::new("t", 1.0, (0..3)
            .map(|i| simple_spec(i, i * 1000, 1))
            .collect());
        let report = set.run_trace(&trace);
        assert!(report.net.is_none());
        assert!(!report.to_json(false).contains("\"net\""));
        assert!(set.net_state().is_none());
    }

    #[test]
    fn fleet_json_shape() {
        let mut set =
            ReplicaSet::simulated(unit_cfg(2, PlacementKind::LeastLoaded));
        let trace = Trace::new("t", 1.0, (0..4)
            .map(|i| simple_spec(i, i * 250_000, 2))
            .collect());
        let report = set.run_trace(&trace);
        let v = crate::util::json::parse(&report.to_json(false)).unwrap();
        assert_eq!(v.u64_field("replicas").unwrap(), 2);
        assert_eq!(v.str_field("placement").unwrap(), "least-loaded");
        assert_eq!(v.field("fleet").unwrap()
                       .u64_field("completed").unwrap(), 4);
        assert_eq!(v.field("per_replica").unwrap()
                       .as_arr().unwrap().len(), 2);
    }
}
