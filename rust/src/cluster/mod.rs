//! Multi-replica dispatch: a [`ReplicaSet`] owns N [`Engine`] replicas
//! (one modeled GPU each, with its own KV budget, swap space, and API
//! executor) behind one shared admission queue.
//!
//! **Placement.** Each arriving request is dispatched to exactly one
//! replica by a pluggable [`PlacementKind`] policy — least outstanding
//! memory-over-time (the LAMPS rank integral steering placement the same
//! way it steers ordering), least-loaded, or round-robin — and never
//! migrates: its KV blocks, swap traffic, and API returns all stay on
//! the owning replica (InferCept's locality argument: swapped state must
//! come back to the GPU that owns the KV layout).
//!
//! **Deterministic interleaving.** `ReplicaSet::step` always advances
//! the most-lagging replica (minimum virtual clock, ties by index), so a
//! fleet run is a deterministic discrete-event simulation no matter how
//! replica clocks drift apart. Idle replicas' clocks trail the fleet so
//! a parked replica never freezes the dispatch frontier, and every
//! replica sees the shared queue's next arrival as an idle-jump target
//! (`Engine::set_external_event`) — which is exactly what makes the
//! `replicas = 1` fleet reproduce the single-engine path byte for byte,
//! the refactor's safety rail (`tests/replica_properties.rs` asserts
//! it).
//!
//! **Fan-in.** Per-replica [`RunReport`]s are aggregated into a
//! fleet-wide report ([`RunReport::aggregate`]): counters sum, latency /
//! TTFT percentiles are rebuilt from the merged per-request samples, and
//! throughput is fleet completions over the latest replica end time.
//!
//! **Cross-replica prefix sharing** (`--shared-prefix`, see
//! [`shared_prefix`]): replicas journal their prefix-cache resident-set
//! deltas, the fleet mirrors them into a [`SharedPrefixIndex`], and
//! `--placement prefix-affinity` discounts the prefill leg of the
//! arrival's rank integral on replicas that already hold its prefix.
//!
//! **Placement-aware admission re-queue**
//! (`SystemConfig::admission_requeue`): a request memory-rejected by
//! its owner before it ever ran is re-queued once to the best sibling
//! with free KV instead of waiting out the owner's pressure.

pub mod shared_prefix;

use std::collections::{HashMap, HashSet, VecDeque};

use crate::config::{PlacementKind, SystemConfig};
use crate::core::request::RequestSpec;
use crate::core::types::{Micros, RequestId, Tokens};
use crate::engine::Engine;
use crate::kv::prefix;
use crate::metrics::{RunReport, SharedPrefixStats};
use crate::workload::Trace;

pub use shared_prefix::{PrefixDeltaSink, SharedPrefixIndex};

/// Safety valve against scheduling livelock across the fleet (mirrors
/// the engine's own guard).
const MAX_FLEET_STEPS: u64 = 400_000_000;

/// One arrival's placement-time scratch state: the spec plus its
/// lazily-computed, computed-at-most-once prompt content chain.
///
/// Before this existed, `prefix_credits` hashed the prompt from
/// scratch on every probe — and the same arrival could be hashed again
/// by a rescue sweep and a third time by the owning engine at
/// admission. The scratch pins the one-shot contract: the chain is
/// computed on first use (never at all for policies that don't need
/// it), every later probe borrows it, and [`ArrivalScratch::into_chain`]
/// hands the finished chain to the chosen replica's memo
/// (`Engine::seed_chain`) so admission and registration extend it
/// instead of rehashing. Interior-mutable (`OnceCell`) so placement
/// probes stay `&`-only — the probe-purity lint's contract.
pub struct ArrivalScratch<'a> {
    spec: &'a RequestSpec,
    block_size: u64,
    chain: std::cell::OnceCell<Vec<prefix::BlockHash>>,
}

impl<'a> ArrivalScratch<'a> {
    /// Scratch for one arrival at the fleet's KV block size (clamped
    /// to 1 so a degenerate config cannot divide by zero).
    pub fn new(spec: &'a RequestSpec, block_size: u64)
               -> ArrivalScratch<'a> {
        ArrivalScratch {
            spec,
            block_size: block_size.max(1),
            chain: std::cell::OnceCell::new(),
        }
    }

    pub fn spec(&self) -> &RequestSpec {
        self.spec
    }

    /// The arrival's full-prompt content chain, hashed on first call
    /// and borrowed thereafter.
    fn chain(&self) -> &[prefix::BlockHash] {
        self.chain.get_or_init(|| {
            prefix::content_chain(self.spec, self.block_size,
                                  self.spec.prompt_tokens)
        })
    }

    /// Surrender the chain if any probe computed it (`None` means no
    /// probe needed hashing — nothing to seed). The caller forwards it
    /// to the placed replica's chain memo.
    pub fn into_chain(self) -> Option<Vec<prefix::BlockHash>> {
        self.chain.into_inner()
    }
}

/// Choose a replica for the next arrival under `policy`, returning the
/// chosen index and — for prefix-affinity placement — the cached-token
/// credit the choice was steered by (zero for every other policy, or
/// when no [`SharedPrefixIndex`] is supplied). `rr_next` is the
/// round-robin cursor (ignored by the other policies). Ties break
/// toward the lowest replica index, keeping placement deterministic.
/// Read-only over the replicas: probing a candidate never perturbs its
/// state. The arrival comes wrapped in an [`ArrivalScratch`] so its
/// prompt is hashed at most once across every probe of the placement
/// path.
///
/// Shared by the simulation driver below and the serving frontend's
/// wall-clock dispatch loop (`server::spawn_replicated`).
pub fn pick_replica(replicas: &[Engine], policy: PlacementKind,
                    rr_next: &mut usize, arrival: &ArrivalScratch<'_>,
                    shared: Option<&SharedPrefixIndex>)
                    -> (usize, Tokens) {
    if replicas.len() <= 1 {
        return (0, Tokens::ZERO);
    }
    match policy {
        PlacementKind::RoundRobin => {
            let r = *rr_next % replicas.len();
            *rr_next += 1;
            (r, Tokens::ZERO)
        }
        PlacementKind::LeastLoaded => (
            replicas
                .iter()
                .enumerate()
                .min_by_key(|(i, e)| (e.live_load(), *i))
                .map(|(i, _)| i)
                .unwrap_or(0),
            Tokens::ZERO,
        ),
        PlacementKind::MemoryOverTime => {
            let mut best = 0usize;
            let mut best_load = f64::INFINITY;
            for (i, e) in replicas.iter().enumerate() {
                let load = e.load_memory_over_time();
                if load < best_load {
                    best = i;
                    best_load = load;
                }
            }
            (best, Tokens::ZERO)
        }
        PlacementKind::PrefixAffinity => {
            // Probe the arrival's content chain against the fleet
            // index: each replica's consecutive leading resident blocks
            // become a cached-token credit that discounts the prefill
            // leg of the arrival's own rank integral on that replica —
            // the same memory-over-time objective, now seeing what each
            // replica already holds.
            let credits = prefix_credits(replicas, arrival, shared);
            let mut best = 0usize;
            let mut best_score = f64::INFINITY;
            for ((i, e), &credit) in
                replicas.iter().enumerate().zip(&credits)
            {
                let score = e.placement_score_prefixed(arrival.spec(),
                                                       Tokens(credit));
                if score < best_score {
                    best = i;
                    best_score = score;
                }
            }
            let credit = credits.get(best).copied().unwrap_or(0);
            (best, Tokens(credit))
        }
    }
}

/// Best sibling able to admit `spec` right now, excluding `owner` —
/// the admission re-queue's target choice. Scored like placement:
/// under prefix-affinity with a live index the sibling's score carries
/// the same prefill-leg discount for resident prefixes (so a rescue
/// never silently defeats steering — the request's prefix home wins
/// whenever it can admit); every other policy takes the least
/// outstanding memory-over-time. Ties to the lowest index. Siblings
/// that cannot admit the spec are skipped *before* any scoring, so in
/// the saturated-fleet case (everyone full) a call costs O(replicas)
/// cheap arithmetic, and the O(live) load probes run only when a
/// rescue is actually about to happen — at most once per request,
/// thanks to the caller's once-only guard.
///
/// `reserved[j]` tokens are already promised to replica `j` by earlier
/// moves of the same sweep (block-rounded), so one sweep cannot
/// overcommit a sibling whose adoptees hold no KV yet.
///
/// Returns the chosen sibling and its cached-token credit (zero
/// outside prefix-affinity).
pub fn pick_rescue_sibling(replicas: &[Engine], owner: usize,
                           arrival: &ArrivalScratch<'_>,
                           policy: PlacementKind,
                           shared: Option<&SharedPrefixIndex>,
                           reserved: &[u64])
                           -> Option<(usize, Tokens)> {
    // Admissibility first: in the saturated case nothing below runs —
    // no prompt hashing, no load sums.
    let fitting: Vec<usize> = replicas
        .iter()
        .enumerate()
        .filter(|&(j, e)| {
            j != owner
                && e.can_fit_fresh_with(
                    arrival.spec(),
                    Tokens(reserved.get(j).copied().unwrap_or(0)))
        })
        .map(|(j, _)| j)
        .collect();
    if fitting.is_empty() {
        return None;
    }
    let affinity = policy == PlacementKind::PrefixAffinity;
    let credits: Vec<u64> = if affinity {
        prefix_credits(replicas, arrival, shared)
    } else {
        vec![0; replicas.len()]
    };
    let mut best: Option<(f64, usize)> = None;
    for &j in &fitting {
        let Some(e) = replicas.get(j) else { continue };
        let credit = credits.get(j).copied().unwrap_or(0);
        let score = if affinity {
            e.placement_score_prefixed(arrival.spec(), Tokens(credit))
        } else {
            e.load_memory_over_time()
        };
        // Ascending j: strict < keeps the lowest index on ties.
        let better = match best {
            None => true,
            Some((bs, _)) => score < bs,
        };
        if better {
            best = Some((score, j));
        }
    }
    best.map(|(_, j)| {
        (j, Tokens(credits.get(j).copied().unwrap_or(0)))
    })
}

/// Per-replica cached-token credits of the arrival's prompt chain
/// against the shared index — the probe shared by prefix-affinity
/// placement and the rescue target choice. All zeros when no index is
/// supplied or it is empty (nothing is hashed in that case); otherwise
/// the chain is borrowed from the [`ArrivalScratch`], which hashes it
/// once per arrival no matter how many probes ask.
fn prefix_credits(replicas: &[Engine], arrival: &ArrivalScratch<'_>,
                  shared: Option<&SharedPrefixIndex>) -> Vec<u64> {
    match shared {
        Some(index) if !index.is_empty() => {
            index.cached_tokens_per_replica(arrival.chain(),
                                            arrival.block_size,
                                            replicas.len())
        }
        _ => vec![0; replicas.len()],
    }
}

/// One admission re-queue sweep over `owner`'s stranded requests — the
/// protocol core shared by the simulated fleet
/// ([`ReplicaSet::rescue_stranded`]) and the serving frontend: skip
/// ids already moved once (`requeued`), pick the target via
/// [`pick_rescue_sibling`], withdraw from the owner, adopt on the
/// target. Returns the moves made as `(id, target, credit)` so each
/// driver applies its own side effects (dispatch-log rewrite and
/// steering-stats re-booking vs. completion-watcher re-pointing).
pub fn rescue_stranded_on(replicas: &mut [Engine], owner: usize,
                          policy: PlacementKind,
                          shared: Option<&SharedPrefixIndex>,
                          requeued: &mut HashSet<RequestId>)
                          -> Vec<(RequestId, usize, Tokens)> {
    // lamps-lint: allow(panic) owner is a valid replica index by contract
    let stranded = replicas[owner].stranded_waiting();
    if stranded.is_empty() {
        return Vec::new();
    }
    let block_size =
        replicas.first().map_or(1, |e| e.cfg.block_size).max(1);
    // Tokens promised to each sibling: its own owed-but-unadmitted
    // backlog (covering adoptees of *previous* sweeps, which hold no
    // KV until admitted and are invisible to the block manager) plus
    // this sweep's earlier moves. Without the reservation, sweeps
    // could overcommit one sibling and burn later victims' once-only
    // guards on moves that leave them worse off. Block-rounded,
    // matching what admission will allocate.
    let mut promised: Vec<u64> = replicas
        .iter()
        .map(|e| e.owed_admission_tokens().0)
        .collect();
    let mut moves = Vec::new();
    for id in stranded {
        if requeued.contains(&id) {
            continue;
        }
        let (target, chain) = {
            // lamps-lint: allow(panic) owner is a valid replica index by contract
            let Some(req) = replicas[owner].request(id) else {
                continue;
            };
            let arrival = ArrivalScratch::new(&req.spec, block_size);
            let target = pick_rescue_sibling(replicas, owner, &arrival,
                                             policy, shared, &promised);
            (target, arrival.into_chain())
        };
        let Some((j, credit)) = target else {
            continue; // no sibling can admit it either — leave it
        };
        // lamps-lint: allow(panic) owner is a valid replica index by contract
        let Some(w) = replicas[owner].withdraw_waiting(id) else {
            continue;
        };
        if let Some(p) = promised.get_mut(j) {
            *p += (w.spec.prompt_tokens.0 + 1).div_ceil(block_size)
                * block_size;
        }
        requeued.insert(id);
        if let Some(chain) = chain {
            // The sweep already hashed the prompt for its probes — hand
            // the chain to the adopter so admission extends it in place.
            // lamps-lint: allow(panic) pick_rescue_sibling returns an in-range sibling
            replicas[j].seed_chain(id, block_size, chain);
        }
        // lamps-lint: allow(panic) pick_rescue_sibling returns an in-range sibling
        replicas[j].adopt(w);
        moves.push((id, j, credit));
    }
    moves
}

/// Fleet-wide result of a multi-replica run: the aggregate plus each
/// replica's own report (per-replica stats are what expose placement
/// skew).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub fleet: RunReport,
    pub per_replica: Vec<RunReport>,
    pub placement: PlacementKind,
    /// Shared prefix index stats — `Some` only when `--shared-prefix`
    /// was active, so the index-less fleet JSON (the PR 3 shape) stays
    /// byte-identical with the feature off.
    pub shared_prefix: Option<SharedPrefixStats>,
}

impl FleetReport {
    /// JSON rendering: the fleet aggregate plus per-replica reports.
    /// Timelines are per-replica gauges that do not compose into one
    /// fleet series, so `with_timeline` emits them on the per-replica
    /// reports (with one replica the fleet report *is* the replica's
    /// and carries its timeline directly).
    pub fn to_json(&self, with_timeline: bool) -> String {
        use crate::util::json::{self, Value};
        let mut pairs = vec![
            ("replicas", json::num(self.per_replica.len() as f64)),
            ("placement", json::s(self.placement.label())),
            ("fleet", self.fleet.to_value(with_timeline)),
            ("per_replica",
             Value::Arr(self
                 .per_replica
                 .iter()
                 .map(|r| r.to_value(with_timeline))
                 .collect())),
        ];
        if let Some(stats) = &self.shared_prefix {
            pairs.push(("shared_prefix", stats.to_value()));
        }
        json::write(&json::obj(pairs))
    }
}

/// N engines, one shared admission queue, a placement policy — plus,
/// under `--shared-prefix`, the fleet-level [`SharedPrefixIndex`] the
/// prefix-affinity placement probes.
pub struct ReplicaSet {
    replicas: Vec<Engine>,
    policy: PlacementKind,
    /// Shared admission queue: arrival-sorted, not yet placed.
    pending: VecDeque<RequestSpec>,
    /// Dispatch log: every placed request and its owning replica (a
    /// re-queued request's entry is rewritten to its final owner).
    assignments: Vec<(RequestId, usize)>,
    rr_next: usize,
    steps: u64,
    /// Fleet-wide hash → replica-set mirror of the per-replica prefix
    /// caches (`--shared-prefix`); `None` keeps the PR 3 path intact.
    shared: Option<SharedPrefixIndex>,
    /// Steering stats reported alongside the fleet report; `Some` iff
    /// `shared` is.
    shared_stats: Option<SharedPrefixStats>,
    /// Placement-aware admission re-queue enabled
    /// (`cfg.admission_requeue`, replicas > 1).
    requeue: bool,
    /// Requests already re-queued once — a second strandedness is
    /// genuine fleet-wide pressure, and bouncing would thrash.
    requeued: HashSet<RequestId>,
    /// Which replica each steered request was credited to (and for how
    /// many tokens), so a later rescue can re-book the stats against
    /// where the request actually ended up.
    steered_log: HashMap<RequestId, (usize, u64)>,
    /// Fleet-level invariant audit ([`crate::audit::check_fleet`])
    /// after every step, per `cfg.audit`. Observe-only; the
    /// per-replica engines additionally run their own auditors.
    audit: bool,
}

impl ReplicaSet {
    /// Simulated fleet: `cfg.replicas` copies of [`Engine::simulated`],
    /// each with the full per-GPU `memory_budget` and the same seed (the
    /// workload seed, not a per-replica identity).
    pub fn simulated(cfg: SystemConfig) -> ReplicaSet {
        assert!(cfg.replicas >= 1, "a fleet needs at least one replica");
        let policy = cfg.placement;
        let track_shared = cfg.shared_prefix && cfg.prefix_cache.enabled
            && cfg.replicas > 1;
        let requeue = cfg.admission_requeue && cfg.replicas > 1;
        let n = cfg.replicas;
        let replicas = (0..n)
            .map(|_| Engine::simulated(cfg.clone()))
            .collect();
        ReplicaSet {
            replicas,
            policy,
            pending: VecDeque::new(),
            assignments: Vec::new(),
            rr_next: 0,
            steps: 0,
            shared: track_shared.then(SharedPrefixIndex::new),
            shared_stats: track_shared
                .then(|| SharedPrefixStats::new(n)),
            requeue,
            requeued: HashSet::new(),
            steered_log: HashMap::new(),
            audit: cfg.audit.enabled(),
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn replica(&self, i: usize) -> &Engine {
        // lamps-lint: allow(panic) Vec-style API — out-of-range is the caller's bug
        &self.replicas[i]
    }

    /// `(arrival, id)` of every spec still in the shared admission
    /// queue, in queue order (invariant-auditor tap).
    pub(crate) fn audit_pending(
        &self) -> impl Iterator<Item = (Micros, RequestId)> + '_ {
        self.pending.iter().map(|s| (s.arrival, s.id))
    }

    /// Every placed request with its owning replica, in dispatch order.
    /// A request the admission re-queue moved appears once, under its
    /// final owner.
    pub fn assignments(&self) -> &[(RequestId, usize)] {
        &self.assignments
    }

    /// The fleet-level shared prefix index, when `--shared-prefix` (and
    /// the per-replica prefix cache) is active.
    pub fn shared_index(&self) -> Option<&SharedPrefixIndex> {
        self.shared.as_ref()
    }

    /// Steering stats of the shared index (`Some` iff it is active).
    pub fn shared_stats(&self) -> Option<&SharedPrefixStats> {
        self.shared_stats.as_ref()
    }

    /// Fleet frontier: the minimum replica clock (the time up to which
    /// every replica's history is final).
    pub fn now(&self) -> Micros {
        self.replicas
            .iter()
            .map(|e| e.now())
            .min()
            // lamps-lint: allow(panic) the constructor asserts replicas >= 1
            .expect("non-empty fleet")
    }

    /// Record Fig 2 timeline points on every replica.
    pub fn set_record_timeline(&mut self, on: bool) {
        for e in &mut self.replicas {
            e.record_timeline = on;
        }
    }

    /// The replica currently responsible for `id` — consulted so
    /// externally-resolved API returns route to the request's *current*
    /// owner (the admission re-queue may have moved it after
    /// placement; a parked request itself never moves, because only
    /// never-scheduled requests are relocatable).
    pub fn owner_of(&self, id: RequestId) -> Option<usize> {
        self.replicas
            .iter()
            .position(|e| e.request(id).is_some())
    }

    /// Resolve an externally-held API call (`--api-source external`)
    /// on whichever replica owns the request — the fleet-level twin of
    /// [`Engine::complete_api_call`].
    pub fn complete_api_call(&mut self, id: RequestId, index: usize,
                             response_tokens: Tokens)
                             -> anyhow::Result<()> {
        let Some(owner) = self.owner_of(id) else {
            anyhow::bail!("unknown request {id}");
        };
        // lamps-lint: allow(panic) owner_of returns an in-range position
        self.replicas[owner].complete_api_call(id, index,
                                               response_tokens)
    }

    /// Queue a spec for arrival-time placement, keeping the shared
    /// queue arrival-sorted. `partition_point` binary search: O(log n)
    /// comparisons per insert even for the serve frontend's
    /// out-of-order submissions (the old backward scan degenerated to
    /// O(n²) total there), and equal keys land *after* their peers —
    /// the same stable order the scan produced. In-order trace appends
    /// still cost one comparison plus a tail push.
    pub fn enqueue(&mut self, spec: RequestSpec) {
        let key = (spec.arrival, spec.id);
        let idx = self
            .pending
            .partition_point(|s| (s.arrival, s.id) <= key);
        self.pending.insert(idx, spec);
    }

    /// Place every pending arrival that the fleet frontier has reached.
    fn dispatch_due(&mut self, frontier: Micros) {
        while self
            .pending
            .front()
            .is_some_and(|s| s.arrival <= frontier)
        {
            let Some(spec) = self.pending.pop_front() else { break };
            let block_size = self
                .replicas
                .first()
                .map_or(1, |e| e.cfg.block_size)
                .max(1);
            let arrival = ArrivalScratch::new(&spec, block_size);
            let (r, credit) = pick_replica(&self.replicas, self.policy,
                                           &mut self.rr_next, &arrival,
                                           self.shared.as_ref());
            if let Some(chain) = arrival.into_chain() {
                // Placement hashed the prompt once — seed the chosen
                // replica's memo so admission/registration extend it
                // instead of rehashing the same bytes.
                // lamps-lint: allow(panic) pick_replica returns an in-range index
                self.replicas[r].seed_chain(spec.id, block_size, chain);
            }
            // A spec submit would fail-fast drop (it can never fit an
            // empty replica) must not count as steering — the credit
            // will never be served.
            // lamps-lint: allow(panic) pick_replica returns an in-range index
            if self.replicas[r].fits_capacity(&spec) {
                if let Some(stats) = self.shared_stats.as_mut() {
                    stats.note(r, credit.0);
                    if credit > Tokens::ZERO {
                        self.steered_log.insert(spec.id, (r, credit.0));
                    }
                }
            }
            self.assignments.push((spec.id, r));
            // lamps-lint: allow(panic) pick_replica returns an in-range index
            self.replicas[r].enqueue(spec);
        }
    }

    /// Mirror replica `i`'s journaled prefix-cache resident-set deltas
    /// into the fleet index through the [`PrefixDeltaSink`] observer
    /// seam (no-op unless `--shared-prefix` armed the journals).
    fn absorb_prefix_deltas(&mut self, i: usize) {
        let Some(index) = self.shared.as_mut() else {
            return;
        };
        // lamps-lint: allow(panic) callers pass the index they just stepped
        for delta in self.replicas[i].drain_prefix_deltas() {
            index.on_delta(i, &delta);
        }
    }

    /// Placement-aware admission re-queue (the ROADMAP follow-on to
    /// multi-replica dispatch): a request OOM-rejected by replica
    /// `owner` before it ever ran — holding nothing there — is
    /// withdrawn and re-queued **once** to the best sibling that can
    /// admit it right now ([`pick_rescue_sibling`]: owner excluded,
    /// scored like placement — prefix-affinity keeps its discount — and
    /// ties to the lowest index). Its starvation state moves with it,
    /// its dispatch-log entry is rewritten so every request still has
    /// exactly one owner, and any dispatch-time steering claim is
    /// re-booked against the rescue target. Returns whether any request
    /// moved (fleet-level progress).
    fn rescue_stranded(&mut self, owner: usize) -> bool {
        if !self.requeue {
            return false;
        }
        let moves = rescue_stranded_on(&mut self.replicas, owner,
                                       self.policy, self.shared.as_ref(),
                                       &mut self.requeued);
        for &(id, j, credit) in &moves {
            // The dispatch-time steering claim no longer holds once the
            // request leaves the replica it was steered to: re-book the
            // stats against the rescue target's actual credit.
            if let Some(stats) = self.shared_stats.as_mut() {
                if let Some((r0, tokens)) = self.steered_log.remove(&id)
                {
                    stats.unnote(r0, tokens);
                }
                stats.note(j, credit.0);
                if credit > Tokens::ZERO {
                    self.steered_log.insert(id, (j, credit.0));
                }
            }
            if let Some(entry) = self
                .assignments
                .iter_mut()
                .rev()
                .find(|(rid, _)| *rid == id)
            {
                entry.1 = j;
            }
        }
        !moves.is_empty()
    }

    /// One fleet round: dispatch due arrivals, then advance the
    /// most-lagging replica that can make progress (deterministic
    /// interleaving). Returns false when the whole fleet is idle with
    /// nothing pending.
    pub fn step(&mut self) -> bool {
        let progressed = self.step_inner();
        if self.audit {
            if let Err(e) = crate::audit::check_fleet(self) {
                // lamps-lint: allow(panic) a tripped audit invariant is a fleet bug — fail loudly
                panic!("{e}");
            }
        }
        progressed
    }

    fn step_inner(&mut self) -> bool {
        let next_arrival = self.pending.front().map(|s| s.arrival);
        let busy_min = self
            .replicas
            .iter()
            .filter(|e| e.has_live_work())
            .map(|e| e.now())
            .min();
        let Some(busy_now) = busy_min else {
            // Fully idle fleet: one jump round to the next arrival —
            // mirroring the single engine's idle jump exactly
            // (including time-cap semantics: the jump is its own round).
            let Some(t) = next_arrival else {
                return false;
            };
            for e in &mut self.replicas {
                e.advance_clock_to(t);
            }
            self.dispatch_due(t);
            return true;
        };
        // Idle replicas trail the fleet (toward the next arrival, but
        // never past the busy frontier) so a parked replica neither
        // freezes dispatch nor runs ahead of time it could still be
        // handed work for.
        let follow = match next_arrival {
            Some(t) => t.min(busy_now),
            None => busy_now,
        };
        for e in &mut self.replicas {
            if !e.has_live_work() {
                e.advance_clock_to(follow);
            }
        }
        let frontier = self.now();
        self.dispatch_due(frontier);
        // Every replica sees the next shared arrival as an idle-jump
        // target — the single-engine parity trick for the corner where
        // a replica has stuck waiters and no events of its own.
        let hint = self.pending.front().map(|s| s.arrival);
        for e in &mut self.replicas {
            e.set_external_event(hint);
        }
        let mut order: Vec<usize> = (0..self.replicas.len()).collect();
        // lamps-lint: allow(panic) order holds indexes of this very Vec
        order.sort_by_key(|&i| (self.replicas[i].now(), i));
        for i in order {
            // lamps-lint: allow(panic) order holds indexes of this very Vec
            if !self.replicas[i].has_live_work() {
                continue;
            }
            // lamps-lint: allow(panic) order holds indexes of this very Vec
            let progressed = self.replicas[i].step();
            // A step mutates only the stepped replica — mirror its
            // prefix-cache resident-set deltas into the shared index
            // even when it reported no progress (a no-progress step can
            // still have purged cache entries while dropping an
            // oversized request), then give any request it
            // memory-rejected before first run a one-time chance on a
            // sibling with free KV. A rescue is fleet progress in its
            // own right: the moved request must get its turn even if
            // every replica's own step stalled this round.
            self.absorb_prefix_deltas(i);
            let rescued = self.rescue_stranded(i);
            if progressed || rescued {
                return true;
            }
        }
        // No replica progressed and (therefore) no arrivals remain: the
        // stuck remainder can never run (same termination the single
        // engine reaches).
        false
    }

    /// Drive the fleet until idle (or `time_cap` on the fleet frontier).
    pub fn run_until_idle(&mut self, time_cap: Option<Micros>) {
        while self.step() {
            if let Some(cap) = time_cap {
                if self.now() >= cap {
                    break;
                }
            }
            self.steps += 1;
            if self.steps >= MAX_FLEET_STEPS {
                // lamps-lint: allow(panic) livelock safety valve — aborting beats spinning forever
                panic!("fleet exceeded MAX_FLEET_STEPS — scheduling \
                        livelock?");
            }
        }
        for e in &mut self.replicas {
            e.finish_run();
        }
    }

    /// Run a trace to completion across the fleet and report.
    pub fn run_trace(&mut self, trace: &Trace) -> FleetReport {
        self.run_trace_limited(trace, None)
    }

    /// Run a trace, stopping at `time_cap` (fleet frontier) if given.
    pub fn run_trace_limited(&mut self, trace: &Trace,
                             time_cap: Option<Micros>) -> FleetReport {
        for spec in &trace.requests {
            self.enqueue(spec.clone());
        }
        self.run_until_idle(time_cap);
        self.fleet_report()
    }

    /// Per-replica reports plus the fleet aggregate. With one replica
    /// the fleet report *is* that replica's report — byte-identical to
    /// the single-engine path.
    pub fn fleet_report(&mut self) -> FleetReport {
        for e in &mut self.replicas {
            e.finish_run();
        }
        let per_replica: Vec<RunReport> = self
            .replicas
            .iter()
            .map(|e| e.metrics.report())
            .collect();
        let fleet = if per_replica.len() == 1 {
            // lamps-lint: allow(panic) guarded by the length check above
            per_replica[0].clone()
        } else {
            let mut latencies: Vec<Micros> = Vec::new();
            let mut ttfts: Vec<Micros> = Vec::new();
            for e in &self.replicas {
                for rec in e.metrics.records() {
                    if let Some(l) = rec.latency() {
                        latencies.push(l);
                    }
                    if let Some(t) = rec.ttft() {
                        ttfts.push(t);
                    }
                }
            }
            RunReport::aggregate(&per_replica, &latencies, &ttfts)
        };
        FleetReport {
            fleet,
            per_replica,
            placement: self.policy,
            shared_prefix: self.shared_stats.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CostModel, HandlingPolicy, SchedulerKind};
    use crate::core::request::{ApiCallSpec, ApiType, HandlingStrategy};
    use crate::core::types::Tokens;

    fn unit_cfg(replicas: usize, placement: PlacementKind)
                -> SystemConfig {
        SystemConfig {
            scheduler: SchedulerKind::Fcfs,
            memory_budget: Tokens(100),
            max_batch: 4,
            block_size: 1,
            starvation_threshold: None,
            cost: CostModel::unit(),
            replicas,
            placement,
            ..SystemConfig::default()
        }
    }

    fn simple_spec(id: u64, arrival: u64, decode: u64) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: Micros(arrival),
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![],
            final_decode: Tokens(decode),
        }
    }

    #[test]
    fn round_robin_rotates_in_arrival_order() {
        let mut set =
            ReplicaSet::simulated(unit_cfg(3, PlacementKind::RoundRobin));
        let trace = Trace::new("t", 1.0, (0..7)
            .map(|i| simple_spec(i, i * 1000, 2))
            .collect());
        let report = set.run_trace(&trace);
        assert_eq!(report.fleet.completed, 7);
        let replicas: Vec<usize> =
            set.assignments().iter().map(|(_, r)| *r).collect();
        assert_eq!(replicas, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(report.per_replica.len(), 3);
        let per: usize =
            report.per_replica.iter().map(|r| r.completed).sum();
        assert_eq!(per, 7);
    }

    #[test]
    fn single_replica_matches_engine_run() {
        let trace = Trace::new("t", 1.0, vec![
            simple_spec(0, 0, 3),
            simple_spec(1, 500_000, 4),
            simple_spec(2, 9_000_000, 2),
        ]);
        let cfg = unit_cfg(1, PlacementKind::MemoryOverTime);
        let mut engine = Engine::simulated(cfg.clone());
        let solo = engine.run_trace(&trace);
        let mut set = ReplicaSet::simulated(cfg);
        let fleet = set.run_trace(&trace);
        assert_eq!(solo.to_json(true), fleet.fleet.to_json(true),
                   "replicas = 1 must be byte-identical");
    }

    #[test]
    fn memory_over_time_spreads_simultaneous_arrivals() {
        // Four equal simultaneous requests, four replicas: placement
        // load must include enqueued-but-unsubmitted arrivals, so each
        // replica gets exactly one (not all four piling onto replica 0).
        let mut set = ReplicaSet::simulated(
            unit_cfg(4, PlacementKind::MemoryOverTime));
        let trace = Trace::new("t", 1.0, (0..4)
            .map(|i| simple_spec(i, 0, 5))
            .collect());
        let report = set.run_trace(&trace);
        assert_eq!(report.fleet.completed, 4);
        let mut replicas: Vec<usize> =
            set.assignments().iter().map(|(_, r)| *r).collect();
        replicas.sort_unstable();
        assert_eq!(replicas, vec![0, 1, 2, 3]);
    }

    #[test]
    fn enqueue_keeps_reversed_arrivals_sorted() {
        // Regression for the O(n²) backward-scan insert: reversed
        // arrival order is its worst case and the serve frontend's
        // realistic one. The queue must stay (arrival, id)-sorted.
        let mut set =
            ReplicaSet::simulated(unit_cfg(2, PlacementKind::RoundRobin));
        for i in (0..64u64).rev() {
            set.enqueue(simple_spec(i, i * 1_000, 1));
        }
        // Equal-arrival duplicates pin the id tie-break too.
        set.enqueue(simple_spec(90, 10_000, 1));
        set.enqueue(simple_spec(70, 10_000, 1));
        let keys: Vec<(Micros, RequestId)> =
            set.pending.iter().map(|s| (s.arrival, s.id)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "queue must stay arrival-sorted");
        assert_eq!(set.pending.len(), 66);
    }

    #[test]
    fn requeue_rescues_stranded_request_to_idle_sibling() {
        // Regression (placement-aware admission): round-robin puts X on
        // replica 0, whose memory request H holds through a 100 000 s
        // Preserve API call, while replica 1 goes idle after its short
        // job. PR 3 stranded X on replica 0 until the API returned; the
        // re-queue must move it to the idle sibling and serve it now.
        let h = RequestSpec {
            id: RequestId(0),
            arrival: Micros(0),
            prompt: String::new(),
            prompt_tokens: Tokens(25),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(2),
                api_type: ApiType::Qa,
                duration: Micros(100_000 * 1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(1),
        };
        let run = |requeue: bool| {
            let mut cfg = unit_cfg(2, PlacementKind::RoundRobin);
            cfg.memory_budget = Tokens(30);
            cfg.handling =
                HandlingPolicy::Forced(HandlingStrategy::Preserve);
            cfg.admission_requeue = requeue;
            let mut set = ReplicaSet::simulated(cfg);
            let trace = Trace::new("t", 1.0, vec![
                h.clone(),
                simple_spec(1, 0, 2),
                RequestSpec {
                    prompt_tokens: Tokens(4),
                    ..simple_spec(2, 1_000_000, 2)
                },
            ]);
            let report = set.run_trace(&trace);
            assert_eq!(report.fleet.completed, 3,
                       "every request completes either way");
            set
        };

        let rescued = run(true);
        let owner: Vec<usize> = rescued
            .assignments()
            .iter()
            .filter(|(id, _)| *id == RequestId(2))
            .map(|(_, r)| *r)
            .collect();
        assert_eq!(owner, vec![1],
                   "X must be re-homed (once) to the idle sibling");
        assert!(rescued.replica(0).request(RequestId(2)).is_none(),
                "no trace of X may remain on the rejecting owner");
        let x = rescued.replica(1).request(RequestId(2)).unwrap();
        assert!(x.is_finished());
        assert!(x.finished_at.unwrap() < Micros(60_000_000),
                "rescued X must finish long before the API returns \
                 (got {})", x.finished_at.unwrap());

        // Without the re-queue, X is stranded behind the full owner
        // until the 100 000 s call returns — the PR 3 failure mode.
        let stranded = run(false);
        let x = stranded.replica(0).request(RequestId(2)).unwrap();
        assert!(x.finished_at.unwrap() > Micros(100_000 * 1_000_000),
                "control run must reproduce the stranding");
    }

    #[test]
    fn external_api_returns_route_to_owner_replica() {
        // `--api-source external` at fleet level: the parked request's
        // return must route to the replica that owns it, the fleet must
        // go idle (not livelock) while the call is unresolved, and a
        // misdirected result must be refused.
        let mut cfg = unit_cfg(2, PlacementKind::RoundRobin);
        cfg.api_source = crate::config::ApiSourceKind::External;
        cfg.handling =
            HandlingPolicy::Forced(HandlingStrategy::Preserve);
        let mut set = ReplicaSet::simulated(cfg);
        set.enqueue(RequestSpec {
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(2),
                api_type: ApiType::Qa,
                duration: Micros(5_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(1),
            ..simple_spec(0, 0, 0)
        });
        set.enqueue(simple_spec(1, 0, 2));
        set.run_until_idle(None);
        // Round-robin: id 0 on replica 0 (parked), id 1 on replica 1
        // (finished); the fleet idles with the call outstanding.
        assert_eq!(set.owner_of(RequestId(0)), Some(0));
        assert!(set.replica(0).request(RequestId(0)).unwrap()
                    .in_api_wait());
        assert!(set.replica(1).request(RequestId(1)).unwrap()
                    .is_finished());
        assert!(set.complete_api_call(RequestId(9), 0, Tokens(0))
                    .is_err(), "unknown request refused");
        set.complete_api_call(RequestId(0), 0, Tokens(3)).unwrap();
        set.run_until_idle(None);
        let r0 = set.replica(0).request(RequestId(0)).unwrap();
        assert!(r0.is_finished());
        assert_eq!(r0.logical_context, Tokens(6),
                   "2 decoded + 3 tool-result tokens + 1 final");
        assert_eq!(set.replica(0).metrics.api_calls_completed, 1,
                   "the predicted-vs-actual gap is observable");
    }

    #[test]
    fn fleet_json_shape() {
        let mut set =
            ReplicaSet::simulated(unit_cfg(2, PlacementKind::LeastLoaded));
        let trace = Trace::new("t", 1.0, (0..4)
            .map(|i| simple_spec(i, i * 250_000, 2))
            .collect());
        let report = set.run_trace(&trace);
        let v = crate::util::json::parse(&report.to_json(false)).unwrap();
        assert_eq!(v.u64_field("replicas").unwrap(), 2);
        assert_eq!(v.str_field("placement").unwrap(), "least-loaded");
        assert_eq!(v.field("fleet").unwrap()
                       .u64_field("completed").unwrap(), 4);
        assert_eq!(v.field("per_replica").unwrap()
                       .as_arr().unwrap().len(), 2);
    }
}
