use std::io::{BufReader, Cursor};

use super::*;
use crate::util::json;

// -------------------------------------------------------------------
// Zero-copy semantics
// -------------------------------------------------------------------

#[test]
fn unescaped_strings_borrow_the_input() {
    let line = r#"{"type":"request","prompt":"plain ascii prompt","output_tokens":4,"api_calls":[]}"#;
    let Ok(Frame::Request(req)) = Frame::parse(line) else {
        panic!("expected a request frame");
    };
    assert!(matches!(req.prompt, Cow::Borrowed(_)),
            "no escapes -> the prompt must borrow the line");
    assert_eq!(req.prompt, "plain ascii prompt");
    // Multi-byte UTF-8 without escapes still borrows.
    let line = "{\"prompt\":\"héllo wörld ✓\",\"output_tokens\":1}";
    let Ok(Frame::V1Request(req)) = Frame::parse(line) else {
        panic!("expected a v1 frame");
    };
    assert!(matches!(req.prompt, Cow::Borrowed(_)));
    assert_eq!(req.prompt, "héllo wörld ✓");
}

#[test]
fn escaped_strings_copy_and_decode() {
    let line = r#"{"prompt":"line\none \"two\" \\ \/ \t Aé","output_tokens":1}"#;
    let Ok(Frame::V1Request(req)) = Frame::parse(line) else {
        panic!("expected a v1 frame");
    };
    assert!(matches!(req.prompt, Cow::Owned(_)),
            "escapes force an owned copy");
    assert_eq!(req.prompt, "line\none \"two\" \\ / \t Aé");
    // Decoded text matches the old tree parser exactly.
    let old = json::parse(line).unwrap().str_field("prompt").unwrap();
    assert_eq!(req.prompt, old.as_str());
}

// -------------------------------------------------------------------
// Parse parity with the old util::json + field-walk path
// -------------------------------------------------------------------

#[test]
fn syntax_errors_match_util_json_byte_for_byte() {
    // Every line here fails JSON parsing; the typed lexer must report
    // the identical message (clients see these in error frames).
    let cases = [
        "not json",
        "",
        "   ",
        "{",
        "tru",
        "nul",
        "falsehood extra",
        "123 xyz",
        "{} garbage",
        "[1,]",
        "[1 2]",
        r#"{"a" 1}"#,
        r#"{"a":}"#,
        r#"{"a":1,}"#,
        r#"{"a":1"#,
        r#""unterminated"#,
        r#"{"a":"\q"}"#,
        r#""bad\u12""#,
        r#"{"prompt":"x","output_tokens":-}"#,
        r#"{"api_calls":[{]}"#,
        "{\"nested\":{\"deep\":[1,{\"x\":}]}}",
    ];
    for line in cases {
        let old = json::parse(line)
            .err()
            .map(|e| e.to_string())
            .unwrap_or_else(|| panic!("'{line}' should fail json::parse"));
        let new = Frame::parse(line)
            .err()
            .map(|e| e.to_string())
            .unwrap_or_else(|| panic!("'{line}' should fail Frame::parse"));
        assert_eq!(new, old, "error text diverged for input: {line}");
    }
}

#[test]
fn field_errors_match_the_old_walk() {
    let cases: &[(&str, &str)] = &[
        ("{}", "bad request: missing JSON field 'prompt'"),
        ("[1,2]", "bad request: missing JSON field 'prompt'"),
        ("\"str\"", "bad request: missing JSON field 'prompt'"),
        ("42", "bad request: missing JSON field 'prompt'"),
        ("null", "bad request: missing JSON field 'prompt'"),
        (r#"{"prompt":5,"output_tokens":1}"#,
         "bad request: field 'prompt' not a string"),
        (r#"{"prompt":"x"}"#,
         "bad request: missing JSON field 'output_tokens'"),
        (r#"{"prompt":"x","output_tokens":"y"}"#,
         "bad request: field 'output_tokens' not a number"),
        (r#"{"prompt":"x","output_tokens":1,"api_calls":3}"#,
         "bad request: 'api_calls' must be an array"),
        (r#"{"prompt":"x","output_tokens":1,"api_calls":[{"decode_before":1,"api_type":"nope"}]}"#,
         "bad request: unknown api_type 'nope'"),
        (r#"{"prompt":"x","output_tokens":1,"api_calls":[{"api_type":"qa"}]}"#,
         "bad request: missing JSON field 'decode_before'"),
        (r#"{"type":"request"}"#,
         "bad request: missing JSON field 'prompt'"),
        // A non-string type reads as absent -> v1 dispatch (old
        // `.and_then(as_str)` behavior).
        (r#"{"type":5}"#, "bad request: missing JSON field 'prompt'"),
        (r#"{"type":"tool_result"}"#,
         "bad tool_result: missing JSON field 'id'"),
        (r#"{"type":"tool_result","id":1}"#,
         "bad tool_result: missing JSON field 'index'"),
        (r#"{"type":"tool_result","id":1,"index":0}"#,
         "bad tool_result: missing JSON field 'response_tokens'"),
        (r#"{"type":"tool_result","id":"one","index":0,"response_tokens":2}"#,
         "bad tool_result: field 'id' not a number"),
        (r#"{"type":"cancel"}"#, "bad cancel: missing JSON field 'id'"),
        (r#"{"type":"bogus"}"#, "unknown frame type 'bogus'"),
    ];
    for (line, expect) in cases {
        let err = Frame::parse(line)
            .err()
            .unwrap_or_else(|| panic!("'{line}' should fail"));
        assert_eq!(err.reply_message(), *expect, "input: {line}");
    }
}

#[test]
fn duplicate_keys_are_last_wins_like_a_btreemap() {
    // Last occurrence decides value AND acceptability, both ways.
    let Ok(Frame::V1Request(r)) = Frame::parse(
        r#"{"prompt":5,"prompt":"a","output_tokens":1,"output_tokens":7}"#)
    else {
        panic!("expected v1");
    };
    assert_eq!(r.prompt, "a");
    assert_eq!(r.output_tokens, 7);
    let err = Frame::parse(r#"{"prompt":"a","prompt":5,"output_tokens":1}"#)
        .err()
        .map(|e| e.reply_message());
    assert_eq!(err.as_deref(),
               Some("bad request: field 'prompt' not a string"));
    // A later non-string `type` demotes the line to v1 (the old map's
    // last-wins + `.and_then(as_str)`).
    let Ok(Frame::V1Request(_)) = Frame::parse(
        r#"{"type":"request","type":1,"prompt":"x","output_tokens":1}"#)
    else {
        panic!("expected v1 dispatch");
    };
}

#[test]
fn typed_frames_carry_the_old_walk_semantics() {
    // Defaults: api_type -> tool, response_tokens -> 4, api_ms -> None.
    let Ok(Frame::Request(r)) = Frame::parse(
        r#"{"type":"request","prompt":"p","output_tokens":20,
            "api_calls":[
              {"decode_before":5,"api_type":"qa","api_ms":700,
               "response_tokens":32},
              {"decode_before":3,"api_type":"image"},
              {"decode_before":2}]}"#)
    else {
        panic!("expected request");
    };
    assert_eq!(r.api_calls.len(), 3);
    assert_eq!(r.api_calls[0].api_type, ApiType::Qa);
    assert_eq!(r.api_calls[0].api_ms, Some(700));
    assert_eq!(r.api_calls[0].response_tokens, 32);
    assert_eq!(r.api_calls[1].api_ms, None);
    assert_eq!(r.api_calls[1].response_tokens, 4);
    assert_eq!(r.api_calls[2].api_type, ApiType::Tool(0));
    // v1 fallback synthesizes one generic tool call from
    // pre_api_tokens/api_ms.
    let Ok(Frame::V1Request(r)) = Frame::parse(
        r#"{"prompt":"hi","output_tokens":12,"pre_api_tokens":4,"api_ms":50}"#)
    else {
        panic!("expected v1");
    };
    assert_eq!(r.api_calls.len(), 1);
    assert_eq!(r.api_calls[0].decode_before, 4);
    assert_eq!(r.api_calls[0].api_ms, Some(50));
    assert_eq!(r.api_calls[0].api_type, ApiType::Tool(0));
    // Floats truncate and negatives saturate exactly like the old
    // `as_u64` cast; lenient optionals ignore wrong-typed values.
    let Ok(Frame::ToolResult(t)) = Frame::parse(
        r#"{"type":"tool_result","id":2.9,"index":-3,"response_tokens":8}"#)
    else {
        panic!("expected tool_result");
    };
    assert_eq!(t.id, 2);
    assert_eq!(t.index, 0);
    let Ok(Frame::V1Request(r)) = Frame::parse(
        r#"{"prompt":"x","output_tokens":1,"pre_api_tokens":"lots"}"#)
    else {
        panic!("expected v1");
    };
    assert!(r.api_calls.is_empty(), "non-numeric pre_api_tokens ignored");
    let Ok(Frame::Cancel(c)) =
        Frame::parse(r#"{"type":"cancel","id":7}"#)
    else {
        panic!("expected cancel");
    };
    assert_eq!(c.id, 7);
    // Unknown keys are skipped (with full syntax validation).
    assert!(Frame::parse(
        r#"{"prompt":"x","output_tokens":1,"extra":{"deep":[1,"s",null]}}"#)
        .is_ok());
}

// -------------------------------------------------------------------
// Encoder parity with the old json::write path
// -------------------------------------------------------------------

/// Build the exact Value tree the old `RequestEvent::to_json` built.
fn old_style(pairs: Vec<(&str, json::Value)>) -> String {
    json::write(&json::obj(pairs))
}

#[test]
fn event_frames_encode_byte_identically_to_json_write() {
    let id = json::num(5.0);
    let cases: Vec<(EventFrame<'_>, String)> = vec![
        (EventFrame::Queued { id: 5 },
         old_style(vec![("type", json::s("queued")),
                        ("id", id.clone())])),
        (EventFrame::Placed { id: 5, replica: 2 },
         old_style(vec![("type", json::s("placed")),
                        ("id", id.clone()),
                        ("replica", json::num(2.0))])),
        (EventFrame::Rescued { id: 5, from: 2, to: 0 },
         old_style(vec![("type", json::s("rescued")),
                        ("id", id.clone()),
                        ("from", json::num(2.0)),
                        ("to", json::num(0.0))])),
        (EventFrame::FirstToken { id: 5 },
         old_style(vec![("type", json::s("first_token")),
                        ("id", id.clone())])),
        (EventFrame::Tokens { id: 5, chunk: 7 },
         old_style(vec![("type", json::s("tokens")),
                        ("id", id.clone()),
                        ("chunk", json::num(7.0))])),
        (EventFrame::ApiCallStarted {
            id: 5,
            index: 0,
            strategy: "swap",
            predicted_us: 690_000,
            external: true,
        },
         old_style(vec![("type", json::s("api_call_started")),
                        ("id", id.clone()),
                        ("index", json::num(0.0)),
                        ("strategy", json::s("swap")),
                        ("predicted_us", json::num(690_000.0)),
                        ("external", json::Value::Bool(true))])),
        (EventFrame::ApiCallCompleted {
            id: 5,
            index: 1,
            actual_us: 1_234,
        },
         old_style(vec![("type", json::s("api_call_completed")),
                        ("id", id.clone()),
                        ("index", json::num(1.0)),
                        ("actual_us", json::num(1_234.0))])),
        (EventFrame::Dropped {
            id: 5,
            reason: "a \"quoted\" \\ reason\nwith\tcontrol\u{1}bytes",
        },
         old_style(vec![
             ("type", json::s("dropped")),
             ("id", id.clone()),
             ("reason",
              json::s("a \"quoted\" \\ reason\nwith\tcontrol\u{1}bytes")),
         ])),
        (EventFrame::SessionError { id: 5, error: "wrong index" },
         old_style(vec![("type", json::s("error")),
                        ("id", id.clone()),
                        ("error", json::s("wrong index"))])),
        (EventFrame::Error { error: "bad request: bad literal at byte 0" },
         old_style(vec![
             ("type", json::s("error")),
             ("error", json::s("bad request: bad literal at byte 0")),
         ])),
    ];
    for (frame, expect) in &cases {
        assert_eq!(&Encoder::frame_to_string(frame), expect,
                   "frame diverged: {frame:?}");
    }
}

#[test]
fn completion_frames_encode_byte_identically_to_json_write() {
    // Served completion with generated ids (negative ones too — the
    // i32 -> f64 -> i64 chain must match).
    let served = CompletionFrame {
        id: 3,
        latency_us: 27_384,
        ttft_us: Some(812),
        tokens_decoded: 6,
        generated: Some(&[1, -2, 40_000]),
        dropped: None,
    };
    let mut pairs = vec![
        ("id", json::num(3.0)),
        ("latency_us", json::num(27_384.0)),
        ("tokens_decoded", json::num(6.0)),
        ("ttft_us", json::num(812.0)),
        ("generated",
         json::Value::Arr(vec![json::num(1.0), json::num(-2.0),
                               json::num(40_000.0)])),
    ];
    let old_v1 = json::write(&json::obj(pairs.clone()));
    assert_eq!(Encoder::frame_to_string(&EventFrame::Completion(served)),
               old_v1);
    pairs.push(("type", json::s("finished")));
    let old_finished = json::write(&json::obj(pairs));
    assert_eq!(Encoder::frame_to_string(&EventFrame::Finished(served)),
               old_finished);
    // Dropped completion: null ttft/generated plus the dropped reason.
    let dropped = CompletionFrame {
        id: 9,
        latency_us: 0,
        ttft_us: None,
        tokens_decoded: 0,
        generated: None,
        dropped: Some("context outgrew budget"),
    };
    let old = json::write(&json::obj(vec![
        ("id", json::num(9.0)),
        ("latency_us", json::num(0.0)),
        ("tokens_decoded", json::num(0.0)),
        ("ttft_us", json::Value::Null),
        ("generated", json::Value::Null),
        ("dropped", json::s("context outgrew budget")),
        ("type", json::s("finished")),
    ]));
    assert_eq!(Encoder::frame_to_string(&EventFrame::Finished(dropped)),
               old);
    // Number edge: a huge latency exercises the non-integer branch of
    // the number rule through the identical f64 chain.
    let huge = CompletionFrame {
        id: 1,
        latency_us: u64::MAX,
        ttft_us: Some(2u64.pow(53)),
        tokens_decoded: 1,
        generated: None,
        dropped: None,
    };
    let old = json::write(&json::obj(vec![
        ("id", json::num(1.0)),
        ("latency_us", json::num(u64::MAX as f64)),
        ("tokens_decoded", json::num(1.0)),
        ("ttft_us", json::num(2f64.powi(53))),
        ("generated", json::Value::Null),
    ]));
    assert_eq!(Encoder::frame_to_string(&EventFrame::Completion(huge)),
               old);
}

#[test]
fn encoder_batches_frames_and_resets_on_drain() {
    let mut enc = Encoder::with_capacity(256);
    assert!(enc.is_empty());
    enc.push(&EventFrame::Queued { id: 0 });
    enc.push(&EventFrame::FirstToken { id: 0 });
    let expect = "{\"id\":0,\"type\":\"queued\"}\n\
                  {\"id\":0,\"type\":\"first_token\"}\n";
    assert_eq!(enc.bytes(), expect.as_bytes());
    assert_eq!(enc.len(), expect.len());
    let mut out: Vec<u8> = Vec::new();
    enc.drain_to(&mut out).unwrap();
    assert_eq!(out, expect.as_bytes());
    assert!(enc.is_empty(), "drain resets the buffer for reuse");
    enc.drain_to(&mut out).unwrap();
    assert_eq!(out.len(), expect.len(), "empty drain writes nothing");
}

// -------------------------------------------------------------------
// Client-side canonical lines
// -------------------------------------------------------------------

#[test]
fn to_line_round_trips_through_parse() {
    let req = RequestFrame {
        prompt: Cow::Borrowed("what is 6 times 7?"),
        api_calls: vec![CallFrame {
            decode_before: 2,
            api_ms: None,
            api_type: ApiType::Math,
            response_tokens: 2,
        }],
        output_tokens: 4,
    };
    let line = req.to_line();
    assert_eq!(line,
               "{\"type\":\"request\",\"prompt\":\"what is 6 times 7?\",\
                \"output_tokens\":4,\"api_calls\":[{\"decode_before\":2,\
                \"api_type\":\"math\",\"response_tokens\":2}]}");
    let Ok(Frame::Request(back)) = Frame::parse(&line) else {
        panic!("round trip failed");
    };
    assert_eq!(back, req);
    // api_ms present rides between api_type and response_tokens.
    let with_ms = RequestFrame {
        prompt: Cow::Borrowed("x"),
        api_calls: vec![CallFrame {
            decode_before: 1,
            api_ms: Some(700),
            api_type: ApiType::Qa,
            response_tokens: 4,
        }],
        output_tokens: 1,
    };
    let Ok(Frame::Request(back)) = Frame::parse(&with_ms.to_line()) else {
        panic!("round trip failed");
    };
    assert_eq!(back, with_ms);
    let tr = ToolResultFrame { id: 0, index: 0, response_tokens: 2 };
    assert_eq!(tr.to_line(),
               "{\"type\":\"tool_result\",\"id\":0,\"index\":0,\
                \"response_tokens\":2}");
    assert_eq!(Frame::parse(&tr.to_line()),
               Ok(Frame::ToolResult(tr)));
    let c = CancelFrame { id: 3 };
    assert_eq!(c.to_line(), "{\"type\":\"cancel\",\"id\":3}");
    assert_eq!(Frame::parse(&c.to_line()), Ok(Frame::Cancel(c)));
}

// -------------------------------------------------------------------
// Line framing
// -------------------------------------------------------------------

fn reader_over(bytes: &[u8], cap: usize)
               -> FrameReader<BufReader<Cursor<Vec<u8>>>> {
    FrameReader::new(BufReader::with_capacity(cap,
                                              Cursor::new(bytes.to_vec())))
}

#[test]
fn frame_reader_splits_lines_and_strips_cr() {
    let mut r = reader_over(b"one\ntwo\r\n\nlast", 8192);
    assert_eq!(r.next_line().unwrap(), Some(WireLine::Frame(b"one")));
    assert_eq!(r.next_line().unwrap(), Some(WireLine::Frame(b"two")));
    assert_eq!(r.next_line().unwrap(), Some(WireLine::Frame(b"")));
    // Final line without a trailing newline is still yielded.
    assert_eq!(r.next_line().unwrap(), Some(WireLine::Frame(b"last")));
    assert!(r.next_line().unwrap().is_none(), "clean EOF");
    assert!(r.next_line().unwrap().is_none(), "EOF is sticky");
}

#[test]
fn frame_reader_survives_byte_at_a_time_delivery() {
    // A 1-byte BufReader forces every fill_buf to deliver one byte —
    // the degenerate version of frames split across TCP segments —
    // including splits inside a multi-byte UTF-8 character.
    let line = "{\"prompt\":\"héllo ✓\",\"output_tokens\":1}";
    let bytes = format!("{line}\n{line}").into_bytes();
    let mut r = reader_over(&bytes, 1);
    for _ in 0..2 {
        let Some(WireLine::Frame(got)) = r.next_line().unwrap() else {
            panic!("expected a frame");
        };
        assert_eq!(got, line.as_bytes());
        let text = std::str::from_utf8(got).unwrap();
        assert!(matches!(Frame::parse(text), Ok(Frame::V1Request(_))));
    }
    assert!(r.next_line().unwrap().is_none());
}

#[test]
fn frame_reader_reports_oversized_lines_and_resyncs() {
    let mut huge = vec![b'x'; MAX_FRAME_BYTES + 10];
    huge.push(b'\n');
    huge.extend_from_slice(b"{\"ok\":1}\n");
    let mut r = reader_over(&huge, 4096);
    assert_eq!(r.next_line().unwrap(),
               Some(WireLine::Oversized { bytes: MAX_FRAME_BYTES + 10 }));
    // The stream resynchronized on the newline: the next line is whole.
    assert_eq!(r.next_line().unwrap(),
               Some(WireLine::Frame(b"{\"ok\":1}".as_slice())));
    assert!(r.next_line().unwrap().is_none());
    // A line of exactly MAX_FRAME_BYTES still passes.
    let mut edge = vec![b'y'; MAX_FRAME_BYTES];
    edge.push(b'\n');
    let mut r = reader_over(&edge, 4096);
    let Some(WireLine::Frame(got)) = r.next_line().unwrap() else {
        panic!("a cap-sized line must not be dropped");
    };
    assert_eq!(got.len(), MAX_FRAME_BYTES);
}

#[test]
fn frame_reader_yields_invalid_utf8_for_the_dispatcher() {
    // Framing is byte-level: invalid UTF-8 reaches the caller, who
    // answers with an error frame instead of killing the connection.
    let mut r = reader_over(b"\xff\xfe bad bytes\nnext\n", 8192);
    let Some(WireLine::Frame(got)) = r.next_line().unwrap() else {
        panic!("expected a frame");
    };
    assert!(std::str::from_utf8(got).is_err());
    assert_eq!(r.next_line().unwrap(), Some(WireLine::Frame(b"next")));
}
