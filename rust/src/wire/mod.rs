//! Typed zero-copy wire layer for protocol v2 (NDJSON over TCP).
//!
//! The serving front door used to route every inbound line through
//! [`crate::util::json::parse`] (an allocating `Value` tree plus a
//! `str_field -> String` walk) and rebuild every outbound event frame
//! through `json::write` (one fresh `String` per event). At millions of
//! streaming sessions each `tokens` frame is the per-token hot path, so
//! this module replaces both directions with typed surfaces:
//!
//! - **Inbound**: [`Frame::parse`] lexes a line *in place* and produces
//!   a typed [`Frame`] (`request`, `tool_result`, the reserved `cancel`,
//!   or a type-less v1 one-shot). Strings are [`Cow`]`<'a, str>`: they
//!   borrow the connection read buffer verbatim and only allocate when
//!   an escape sequence forces a copy. Parse failures are structured
//!   ([`FrameError`]) and render byte-for-byte the same messages the old
//!   `Value`-tree walk produced, so client-visible error frames are
//!   unchanged.
//! - **Outbound**: [`EventFrame`] + [`Encoder`] serialize event frames
//!   into a reusable per-connection buffer with hardcoded canonical key
//!   order (the alphabetical order the old `BTreeMap` writer emitted —
//!   byte-identical output), and [`Encoder::drain_to`] flushes a whole
//!   pump batch with one gathered `write` instead of three syscalls per
//!   frame.
//! - **Framing**: [`FrameReader`] splits the socket byte stream into
//!   newline-delimited frames without UTF-8-validating (or copying) more
//!   than one line at a time, and caps a single frame at
//!   [`MAX_FRAME_BYTES`] so a hostile endless line cannot balloon
//!   memory ([`WireLine::Oversized`]).
//!
//! The `cancel` frame type (`{"type":"cancel","id":N}`) is *reserved*:
//! it parses into [`Frame::Cancel`] but the server currently answers
//! with a non-terminal error frame — client-driven cancellation is a
//! ROADMAP item and reserving the type now keeps old servers' replies
//! ("unknown frame type") distinguishable from future real support.
//!
//! Compatibility contract: every encoder path here is pinned
//! byte-for-byte against the old `util::json` writer by unit tests and
//! by the `examples/protocol_v2.ndjson` golden-transcript test
//! (`tests/wire_golden.rs`); `benches/micro_wire.rs` pins the
//! allocation and frames/sec win.

use std::borrow::Cow;
use std::io::{self, BufRead, Write};

use crate::core::request::ApiType;

/// Hard cap on one NDJSON frame. A line longer than this is swallowed
/// (to resynchronize on the next newline) and reported as
/// [`WireLine::Oversized`] instead of being buffered.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// JSON syntax error, rendering the exact messages
/// [`crate::util::json::parse`] produced so client-visible error frames
/// stay byte-identical across the rework.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonError {
    Expected { ch: char, at: usize },
    UnterminatedString,
    BadEscape { at: usize },
    BadUnicodeEscape,
    BadLiteral { at: usize },
    BadNumber { text: String, at: usize, why: String },
    TrailingChars { at: usize },
    UnexpectedEnd,
    ExpectedCommaOrBrace { at: usize },
    ExpectedCommaOrBracket { at: usize },
    /// Pass-through of a std error's own text (hex-escape edge cases),
    /// matching what the old parser's `?` conversions surfaced.
    Raw(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Expected { ch, at } => {
                write!(f, "expected '{ch}' at byte {at}")
            }
            JsonError::UnterminatedString => write!(f, "unterminated string"),
            JsonError::BadEscape { at } => {
                write!(f, "bad escape at byte {at}")
            }
            JsonError::BadUnicodeEscape => write!(f, "bad \\u escape"),
            JsonError::BadLiteral { at } => {
                write!(f, "bad literal at byte {at}")
            }
            JsonError::BadNumber { text, at, why } => {
                write!(f, "bad number '{text}' at byte {at}: {why}")
            }
            JsonError::TrailingChars { at } => {
                write!(f, "trailing characters at byte {at}")
            }
            JsonError::UnexpectedEnd => write!(f, "unexpected end of input"),
            JsonError::ExpectedCommaOrBrace { at } => {
                write!(f, "expected ',' or '}}' at byte {at}")
            }
            JsonError::ExpectedCommaOrBracket { at } => {
                write!(f, "expected ',' or ']' at byte {at}")
            }
            JsonError::Raw(msg) => write!(f, "{msg}"),
        }
    }
}

/// A frame field that is missing or carries the wrong JSON type
/// (message texts match the old `str_field`/`u64_field` walk).
#[derive(Debug, Clone, PartialEq)]
pub enum FieldError {
    Missing(&'static str),
    NotAString(&'static str),
    NotANumber(&'static str),
    ApiCallsNotArray,
    UnknownApiType(String),
}

impl std::fmt::Display for FieldError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FieldError::Missing(key) => {
                write!(f, "missing JSON field '{key}'")
            }
            FieldError::NotAString(key) => {
                write!(f, "field '{key}' not a string")
            }
            FieldError::NotANumber(key) => {
                write!(f, "field '{key}' not a number")
            }
            FieldError::ApiCallsNotArray => {
                write!(f, "'api_calls' must be an array")
            }
            FieldError::UnknownApiType(name) => {
                write!(f, "unknown api_type '{name}'")
            }
        }
    }
}

/// Which typed frame a field error belongs to — decides the reply
/// prefix (`bad request:` / `bad tool_result:` / `bad cancel:`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    Request,
    ToolResult,
    Cancel,
}

/// Structured parse error for one inbound line.
#[derive(Debug, Clone, PartialEq)]
pub enum FrameError {
    /// The line is not well-formed JSON.
    Json(JsonError),
    /// Well-formed JSON, but a typed frame field is missing/mistyped.
    Field { frame: FrameKind, err: FieldError },
    /// A `type` value this protocol version does not know.
    UnknownFrameType(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Json(e) => write!(f, "{e}"),
            FrameError::Field { err, .. } => write!(f, "{err}"),
            FrameError::UnknownFrameType(t) => {
                write!(f, "unknown frame type '{t}'")
            }
        }
    }
}

impl std::error::Error for FrameError {}

impl From<JsonError> for FrameError {
    fn from(e: JsonError) -> Self {
        FrameError::Json(e)
    }
}

impl FrameError {
    /// The full client-visible error text, with the same prefixes the
    /// old dispatch attached (`bad request: ...`, `bad tool_result:
    /// ...`, bare `unknown frame type '...'`). Syntax errors always
    /// read `bad request:` because the old code parsed the JSON before
    /// it knew the frame type.
    pub fn reply_message(&self) -> String {
        match self {
            FrameError::Json(e) => format!("bad request: {e}"),
            FrameError::Field { frame, err } => match frame {
                FrameKind::Request => format!("bad request: {err}"),
                FrameKind::ToolResult => format!("bad tool_result: {err}"),
                FrameKind::Cancel => format!("bad cancel: {err}"),
            },
            FrameError::UnknownFrameType(t) => {
                format!("unknown frame type '{t}'")
            }
        }
    }
}

// ---------------------------------------------------------------------
// Typed inbound frames
// ---------------------------------------------------------------------

/// A `{"type":"request"}` (or type-less v1) line. `prompt` borrows the
/// read buffer unless the JSON contained escape sequences.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame<'a> {
    pub prompt: Cow<'a, str>,
    pub api_calls: Vec<CallFrame>,
    pub output_tokens: u64,
}

/// One `api_calls` entry of a request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct CallFrame {
    /// Decode tokens before this call fires.
    pub decode_before: u64,
    /// Simulated call duration in milliseconds. Under
    /// `--api-source external` this is only a prediction hint; omitted,
    /// the class's historical mean (Table 2) is used either way.
    pub api_ms: Option<u64>,
    pub api_type: ApiType,
    /// Tokens the API response appends on return (an external
    /// `tool_result` overrides this with the tool's actual length).
    pub response_tokens: u64,
}

/// `{"type":"tool_result","id":N,"index":N,"response_tokens":N}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ToolResultFrame {
    pub id: u64,
    pub index: u64,
    pub response_tokens: u64,
}

/// `{"type":"cancel","id":N}` — reserved; parsed but not yet acted on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CancelFrame {
    pub id: u64,
}

/// One parsed inbound line of the v2 wire protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame<'a> {
    Request(RequestFrame<'a>),
    ToolResult(ToolResultFrame),
    /// Reserved frame type (see module docs).
    Cancel(CancelFrame),
    /// A line with no `type` field: the legacy v1 one-shot shape
    /// (`prompt`/`output_tokens` plus optional
    /// `pre_api_tokens`/`api_ms`), answered with a single completion
    /// object instead of event frames.
    V1Request(RequestFrame<'a>),
}

impl<'a> Frame<'a> {
    /// Parse one NDJSON line into a typed frame, borrowing unescaped
    /// strings from `line`. Error messages (including syntax errors)
    /// are byte-identical to the old `util::json` + field-walk path.
    pub fn parse(line: &'a str) -> Result<Frame<'a>, FrameError> {
        let mut lex = Lexer::new(line);
        lex.skip_ws();
        let fields = if lex.peek() == Some(b'{') {
            lex.frame_fields()?
        } else {
            // Not an object: lex it anyway so malformed JSON reports
            // the same syntax error the old tree parser did; a valid
            // non-object value dispatches as an (empty) v1 request,
            // which then fails with "missing JSON field 'prompt'" —
            // again matching the old walk.
            lex.skip_value()?;
            FrameFields::default()
        };
        lex.skip_ws();
        if lex.pos != lex.b.len() {
            return Err(JsonError::TrailingChars { at: lex.pos }.into());
        }
        dispatch_fields(fields)
    }
}

// ---------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------

/// Slice lexer over one line. Positions are byte offsets into the
/// original line so error messages agree with the old parser.
struct Lexer<'a> {
    s: &'a str,
    b: &'a [u8],
    pos: usize,
}

impl<'a> Lexer<'a> {
    fn new(s: &'a str) -> Self {
        Lexer { s, b: s.as_bytes(), pos: 0 }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, ch: u8) -> Result<(), JsonError> {
        if self.peek() == Some(ch) {
            self.pos += 1;
            Ok(())
        } else {
            Err(JsonError::Expected { ch: ch as char, at: self.pos })
        }
    }

    /// Lex a JSON string. The fast path scans to the closing quote and
    /// borrows the slice verbatim; only an escape sequence falls back
    /// to an owned accumulator (util::json's full escape set).
    fn string(&mut self) -> Result<Cow<'a, str>, JsonError> {
        self.expect_byte(b'"')?;
        let start = self.pos;
        loop {
            match self.peek() {
                None => return Err(JsonError::UnterminatedString),
                Some(b'"') => {
                    let text =
                        self.s.get(start..self.pos).unwrap_or_default();
                    self.pos += 1;
                    return Ok(Cow::Borrowed(text));
                }
                Some(b'\\') => break,
                Some(_) => self.pos += 1,
            }
        }
        // Slow path: restart from the string start with an owned
        // buffer, replicating util::json's escapes (and error
        // positions) bit for bit.
        self.pos = start;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::UnterminatedString),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(Cow::Owned(out));
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex_bytes = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or(JsonError::BadUnicodeEscape)?;
                            let hex = std::str::from_utf8(hex_bytes)
                                .map_err(|e| {
                                    JsonError::Raw(e.to_string())
                                })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| {
                                    JsonError::Raw(e.to_string())
                                })?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => {
                            return Err(JsonError::BadEscape {
                                at: self.pos,
                            });
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    let run = self.pos;
                    while matches!(self.peek(),
                                   Some(c) if c != b'"' && c != b'\\')
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        self.s.get(run..self.pos).unwrap_or_default(),
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<f64, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = self.s.get(start..self.pos).unwrap_or_default();
        text.parse::<f64>().map_err(|e| JsonError::BadNumber {
            text: text.to_string(),
            at: start,
            why: e.to_string(),
        })
    }

    fn literal(&mut self) -> Result<(), JsonError> {
        let rest = self.s.get(self.pos..).unwrap_or_default();
        for lit in ["true", "false", "null"] {
            if rest.starts_with(lit) {
                self.pos += lit.len();
                return Ok(());
            }
        }
        Err(JsonError::BadLiteral { at: self.pos })
    }

    /// Lex past any JSON value, validating it exactly as the old tree
    /// parser did (so ignored/unknown fields still reject bad syntax).
    fn skip_value(&mut self) -> Result<(), JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.skip_obj(),
            Some(b'[') => self.skip_arr(),
            Some(b'"') => self.string().map(|_| ()),
            Some(b't' | b'f' | b'n') => self.literal(),
            Some(_) => self.number().map(|_| ()),
            None => Err(JsonError::UnexpectedEnd),
        }
    }

    fn skip_obj(&mut self) -> Result<(), JsonError> {
        self.expect_byte(b'{')?;
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_value()?;
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => {
                    return Err(JsonError::ExpectedCommaOrBrace {
                        at: self.pos,
                    });
                }
            }
        }
    }

    fn skip_arr(&mut self) -> Result<(), JsonError> {
        self.expect_byte(b'[')?;
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.skip_value()?;
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => {
                    return Err(JsonError::ExpectedCommaOrBracket {
                        at: self.pos,
                    });
                }
            }
        }
    }

    /// Capture a required string field, last occurrence wins (BTreeMap
    /// insert parity for duplicate keys).
    fn capture_string(&mut self, slot: &mut Seen<Cow<'a, str>>)
                      -> Result<(), JsonError> {
        self.skip_ws();
        if self.peek() == Some(b'"') {
            *slot = Seen::Got(self.string()?);
        } else {
            self.skip_value()?;
            *slot = Seen::WrongType;
        }
        Ok(())
    }

    /// Capture an optional string (`.get(..).and_then(as_str)` parity:
    /// a wrong-typed final occurrence reads as absent).
    fn capture_opt_string(&mut self, slot: &mut Option<Cow<'a, str>>)
                          -> Result<(), JsonError> {
        self.skip_ws();
        if self.peek() == Some(b'"') {
            *slot = Some(self.string()?);
        } else {
            self.skip_value()?;
            *slot = None;
        }
        Ok(())
    }

    /// Capture a required number field (`u64_field` parity: any
    /// non-number JSON value is a type error, floats truncate, and
    /// negatives saturate to 0 via the same `f64 as u64` cast).
    fn capture_u64(&mut self, slot: &mut Seen<u64>)
                   -> Result<(), JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{' | b'[' | b'"' | b't' | b'f' | b'n') => {
                self.skip_value()?;
                *slot = Seen::WrongType;
            }
            Some(_) => {
                let n = self.number()?;
                *slot = Seen::Got(n as u64);
            }
            None => return Err(JsonError::UnexpectedEnd),
        }
        Ok(())
    }

    /// Capture an optional number (`.get(..).and_then(as_u64)` parity:
    /// a wrong-typed final occurrence resets the slot to `None`).
    fn capture_opt_u64(&mut self, slot: &mut Option<u64>)
                       -> Result<(), JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{' | b'[' | b'"' | b't' | b'f' | b'n') => {
                self.skip_value()?;
                *slot = None;
            }
            Some(_) => *slot = Some(self.number()? as u64),
            None => return Err(JsonError::UnexpectedEnd),
        }
        Ok(())
    }

    /// Capture the `api_calls` array as typed per-call accumulators.
    fn capture_api_calls(&mut self, slot: &mut Seen<Vec<CallFields>>)
                         -> Result<(), JsonError> {
        self.skip_ws();
        if self.peek() != Some(b'[') {
            self.skip_value()?;
            *slot = Seen::WrongType;
            return Ok(());
        }
        self.expect_byte(b'[')?;
        let mut calls = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            *slot = Seen::Got(calls);
            return Ok(());
        }
        loop {
            calls.push(self.call_fields()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    *slot = Seen::Got(calls);
                    return Ok(());
                }
                _ => {
                    return Err(JsonError::ExpectedCommaOrBracket {
                        at: self.pos,
                    });
                }
            }
        }
    }

    /// Lex one `api_calls` element. Non-object elements are skipped
    /// into an empty accumulator — the old walk's `get()` on them
    /// returned `None` for every key, so validation (missing
    /// `decode_before`) fires identically at build time.
    fn call_fields(&mut self) -> Result<CallFields, JsonError> {
        self.skip_ws();
        let mut call = CallFields::default();
        if self.peek() != Some(b'{') {
            self.skip_value()?;
            return Ok(call);
        }
        self.expect_byte(b'{')?;
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(call);
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            match key.as_ref() {
                "api_type" => {
                    self.skip_ws();
                    if self.peek() == Some(b'"') {
                        let name = self.string()?;
                        call.api_type =
                            match ApiType::parse(name.as_ref()) {
                                Some(t) => CallType::Known(t),
                                None => {
                                    CallType::Unknown(name.into_owned())
                                }
                            };
                    } else {
                        // Non-string api_type reads as absent (the old
                        // `.and_then(as_str)` walk) — generic tool.
                        self.skip_value()?;
                        call.api_type = CallType::Omitted;
                    }
                }
                "decode_before" => {
                    self.capture_u64(&mut call.decode_before)?;
                }
                "api_ms" => self.capture_opt_u64(&mut call.api_ms)?,
                "response_tokens" => {
                    self.capture_opt_u64(&mut call.response_tokens)?;
                }
                _ => self.skip_value()?,
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(call);
                }
                _ => {
                    return Err(JsonError::ExpectedCommaOrBrace {
                        at: self.pos,
                    });
                }
            }
        }
    }

    /// Lex a whole frame object into field accumulators (single pass,
    /// no tree).
    fn frame_fields(&mut self) -> Result<FrameFields<'a>, JsonError> {
        let mut fields = FrameFields::default();
        self.expect_byte(b'{')?;
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(fields);
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            match key.as_ref() {
                "type" => self.capture_opt_string(&mut fields.typ)?,
                "prompt" => self.capture_string(&mut fields.prompt)?,
                "output_tokens" => {
                    self.capture_u64(&mut fields.output_tokens)?;
                }
                "api_calls" => {
                    self.capture_api_calls(&mut fields.api_calls)?;
                }
                "pre_api_tokens" => {
                    self.capture_opt_u64(&mut fields.pre_api_tokens)?;
                }
                "api_ms" => self.capture_opt_u64(&mut fields.api_ms)?,
                "id" => self.capture_u64(&mut fields.id)?,
                "index" => self.capture_u64(&mut fields.index)?,
                "response_tokens" => {
                    self.capture_u64(&mut fields.response_tokens)?;
                }
                _ => self.skip_value()?,
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(fields);
                }
                _ => {
                    return Err(JsonError::ExpectedCommaOrBrace {
                        at: self.pos,
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Field accumulators
// ---------------------------------------------------------------------

/// Tri-state field accumulator preserving the old tree's last-wins
/// duplicate-key semantics: the *final* occurrence decides both the
/// value and whether its type was acceptable.
#[derive(Debug)]
enum Seen<T> {
    Missing,
    WrongType,
    Got(T),
}

impl<T> Default for Seen<T> {
    fn default() -> Self {
        Seen::Missing
    }
}

impl<T> Seen<T> {
    fn required(self, frame: FrameKind, key: &'static str,
                wrong: fn(&'static str) -> FieldError)
                -> Result<T, FrameError> {
        match self {
            Seen::Got(v) => Ok(v),
            Seen::Missing => Err(FrameError::Field {
                frame,
                err: FieldError::Missing(key),
            }),
            Seen::WrongType => {
                Err(FrameError::Field { frame, err: wrong(key) })
            }
        }
    }
}

/// `api_type` accumulator: unknown names are stored, not rejected, so
/// duplicate-key last-wins and the old walk's validate-at-the-end
/// ordering both hold.
#[derive(Debug, Default)]
enum CallType {
    #[default]
    Omitted,
    Known(ApiType),
    Unknown(String),
}

#[derive(Debug, Default)]
struct CallFields {
    api_type: CallType,
    decode_before: Seen<u64>,
    api_ms: Option<u64>,
    response_tokens: Option<u64>,
}

#[derive(Debug, Default)]
struct FrameFields<'a> {
    typ: Option<Cow<'a, str>>,
    prompt: Seen<Cow<'a, str>>,
    output_tokens: Seen<u64>,
    api_calls: Seen<Vec<CallFields>>,
    pre_api_tokens: Option<u64>,
    api_ms: Option<u64>,
    id: Seen<u64>,
    index: Seen<u64>,
    response_tokens: Seen<u64>,
}

fn dispatch_fields(mut fields: FrameFields<'_>)
                   -> Result<Frame<'_>, FrameError> {
    let typ = fields.typ.take();
    match typ.as_deref() {
        None => Ok(Frame::V1Request(build_request(fields)?)),
        Some("request") => Ok(Frame::Request(build_request(fields)?)),
        Some("tool_result") => {
            let kind = FrameKind::ToolResult;
            let id =
                fields.id.required(kind, "id", FieldError::NotANumber)?;
            let index = fields
                .index
                .required(kind, "index", FieldError::NotANumber)?;
            let response_tokens = fields.response_tokens.required(
                kind,
                "response_tokens",
                FieldError::NotANumber,
            )?;
            Ok(Frame::ToolResult(ToolResultFrame {
                id,
                index,
                response_tokens,
            }))
        }
        Some("cancel") => {
            let id = fields.id.required(FrameKind::Cancel, "id",
                                        FieldError::NotANumber)?;
            Ok(Frame::Cancel(CancelFrame { id }))
        }
        Some(other) => Err(FrameError::UnknownFrameType(other.to_string())),
    }
}

/// Validation order matches the old `WireRequest::from_value`: prompt,
/// then output_tokens, then api_calls (elements in order; per call,
/// api_type before decode_before).
fn build_request(fields: FrameFields<'_>)
                 -> Result<RequestFrame<'_>, FrameError> {
    let kind = FrameKind::Request;
    let prompt = fields
        .prompt
        .required(kind, "prompt", FieldError::NotAString)?;
    let output_tokens = fields.output_tokens.required(
        kind,
        "output_tokens",
        FieldError::NotANumber,
    )?;
    let api_calls = match fields.api_calls {
        Seen::Got(calls) => {
            let mut out = Vec::with_capacity(calls.len());
            for call in calls {
                out.push(build_call(call)?);
            }
            out
        }
        Seen::WrongType => {
            return Err(FrameError::Field {
                frame: kind,
                err: FieldError::ApiCallsNotArray,
            });
        }
        Seen::Missing => {
            // Legacy v1 single-call shape.
            let pre = fields.pre_api_tokens.unwrap_or(0);
            let api_ms = fields.api_ms.unwrap_or(0);
            if pre > 0 {
                vec![CallFrame {
                    decode_before: pre,
                    api_ms: Some(api_ms),
                    api_type: ApiType::Tool(0),
                    response_tokens: 4,
                }]
            } else {
                vec![]
            }
        }
    };
    Ok(RequestFrame { prompt, api_calls, output_tokens })
}

fn build_call(call: CallFields) -> Result<CallFrame, FrameError> {
    let api_type = match call.api_type {
        CallType::Known(t) => t,
        CallType::Omitted => ApiType::Tool(0),
        CallType::Unknown(name) => {
            return Err(FrameError::Field {
                frame: FrameKind::Request,
                err: FieldError::UnknownApiType(name),
            });
        }
    };
    let decode_before = call.decode_before.required(
        FrameKind::Request,
        "decode_before",
        FieldError::NotANumber,
    )?;
    Ok(CallFrame {
        decode_before,
        api_ms: call.api_ms,
        api_type,
        response_tokens: call.response_tokens.unwrap_or(4),
    })
}

// ---------------------------------------------------------------------
// Client-side canonical encoders
// ---------------------------------------------------------------------

impl RequestFrame<'_> {
    /// Canonical client-side request line (no trailing newline) —
    /// byte-for-byte what `examples/protocol_v2.ndjson` shows:
    /// `type`, `prompt`, `output_tokens`, then `api_calls` entries as
    /// `decode_before`, `api_type`, optional `api_ms`,
    /// `response_tokens`.
    pub fn to_line(&self) -> String {
        let mut enc = Encoder::new();
        enc.raw(b"{\"type\":\"request\",\"prompt\":");
        enc.quoted(self.prompt.as_ref());
        enc.raw(b",\"output_tokens\":");
        enc.num_u64(self.output_tokens);
        enc.raw(b",\"api_calls\":[");
        for (i, call) in self.api_calls.iter().enumerate() {
            if i > 0 {
                enc.raw(b",");
            }
            enc.raw(b"{\"decode_before\":");
            enc.num_u64(call.decode_before);
            enc.raw(b",\"api_type\":");
            enc.quoted(call.api_type.label());
            if let Some(ms) = call.api_ms {
                enc.raw(b",\"api_ms\":");
                enc.num_u64(ms);
            }
            enc.raw(b",\"response_tokens\":");
            enc.num_u64(call.response_tokens);
            enc.raw(b"}");
        }
        enc.raw(b"]}");
        enc.into_string()
    }
}

impl ToolResultFrame {
    /// Canonical client-side tool-result line (no trailing newline).
    pub fn to_line(&self) -> String {
        let mut enc = Encoder::new();
        enc.raw(b"{\"type\":\"tool_result\",\"id\":");
        enc.num_u64(self.id);
        enc.raw(b",\"index\":");
        enc.num_u64(self.index);
        enc.raw(b",\"response_tokens\":");
        enc.num_u64(self.response_tokens);
        enc.raw(b"}");
        enc.into_string()
    }
}

impl CancelFrame {
    /// Canonical client-side cancel line (reserved frame type).
    pub fn to_line(&self) -> String {
        let mut enc = Encoder::new();
        enc.raw(b"{\"type\":\"cancel\",\"id\":");
        enc.num_u64(self.id);
        enc.raw(b"}");
        enc.into_string()
    }
}

// ---------------------------------------------------------------------
// Typed outbound frames
// ---------------------------------------------------------------------

/// Completion payload of a `finished` event frame (or a bare v1
/// completion reply). Borrows the server-side completion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompletionFrame<'a> {
    pub id: u64,
    pub latency_us: u64,
    pub ttft_us: Option<u64>,
    pub tokens_decoded: u64,
    pub generated: Option<&'a [i32]>,
    /// `Some(reason)` only for dropped requests — the key is omitted
    /// entirely for served completions.
    pub dropped: Option<&'a str>,
}

/// One typed outbound frame. Encoded key order is the canonical
/// (alphabetical) order the old `BTreeMap` writer produced, hardcoded
/// per variant — see [`Encoder`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventFrame<'a> {
    Queued { id: u64 },
    Placed { id: u64, replica: u64 },
    Rescued { id: u64, from: u64, to: u64 },
    FirstToken { id: u64 },
    Tokens { id: u64, chunk: u64 },
    ApiCallStarted {
        id: u64,
        index: u64,
        strategy: &'a str,
        predicted_us: u64,
        external: bool,
    },
    ApiCallCompleted { id: u64, index: u64, actual_us: u64 },
    /// Terminal `finished` frame (the completion's own id rides in the
    /// payload).
    Finished(CompletionFrame<'a>),
    Dropped { id: u64, reason: &'a str },
    /// Session-scoped error frame (`{"error","id","type"}`).
    SessionError { id: u64, error: &'a str },
    /// Connection-scoped error frame with no session id.
    Error { error: &'a str },
    /// Bare v1 completion reply (a `finished` frame minus the `type`).
    Completion(CompletionFrame<'a>),
}

/// Reusable outbound frame buffer: push typed frames, then flush the
/// whole batch to the socket with one write + flush
/// ([`Encoder::drain_to`]) instead of one `String` + three syscalls per
/// event. Byte output is pinned to the old `json::write` path.
#[derive(Debug)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

impl Encoder {
    pub fn new() -> Self {
        Encoder { buf: Vec::new() }
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Encoder { buf: Vec::with_capacity(bytes) }
    }

    /// Encode one frame plus its newline into the buffer.
    pub fn push(&mut self, frame: &EventFrame<'_>) {
        self.encode(frame);
        self.buf.push(b'\n');
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Write the whole batch with a single `write_all` + `flush`, then
    /// reset the buffer for reuse (capacity is retained).
    pub fn drain_to<W: Write>(&mut self, w: &mut W) -> io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        w.write_all(&self.buf)?;
        w.flush()?;
        self.buf.clear();
        Ok(())
    }

    /// One frame as a `String` (no trailing newline) — the drop-in
    /// replacement for the old per-event `json::write` call sites.
    pub fn frame_to_string(frame: &EventFrame<'_>) -> String {
        let mut enc = Encoder::new();
        enc.encode(frame);
        enc.into_string()
    }

    fn into_string(self) -> String {
        // Every byte pushed is either ASCII or a verbatim UTF-8 char
        // copy, so this cannot fail; the fallback is unreachable.
        String::from_utf8(self.buf).unwrap_or_default()
    }

    fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// The old writer's number rule: integers (up to the f64-exact
    /// range) print as i64, everything else via `{}` on the f64.
    fn num_f64(&mut self, n: f64) {
        if n.fract() == 0.0 && n.abs() < 9e15 {
            let _ = write!(self.buf, "{}", n as i64);
        } else {
            let _ = write!(self.buf, "{n}");
        }
    }

    /// All wire numbers historically round-tripped through `f64`
    /// (`json::num(x as f64)`): keep that exact cast chain.
    fn num_u64(&mut self, v: u64) {
        self.num_f64(v as f64);
    }

    /// The old writer's string escaping, byte for byte: `"`, `\`,
    /// `\n`, `\t`, `\r` named; other control bytes as `\u00xx`;
    /// everything else (including multi-byte UTF-8) verbatim.
    fn quoted(&mut self, s: &str) {
        self.buf.push(b'"');
        let bytes = s.as_bytes();
        let mut start = 0usize;
        for (i, &b) in bytes.iter().enumerate() {
            if b == b'"' || b == b'\\' || b < 0x20 {
                self.raw(bytes.get(start..i).unwrap_or_default());
                match b {
                    b'"' => self.raw(b"\\\""),
                    b'\\' => self.raw(b"\\\\"),
                    b'\n' => self.raw(b"\\n"),
                    b'\t' => self.raw(b"\\t"),
                    b'\r' => self.raw(b"\\r"),
                    _ => {
                        let _ = write!(self.buf, "\\u{:04x}", b as u32);
                    }
                }
                start = i + 1;
            }
        }
        self.raw(bytes.get(start..).unwrap_or_default());
        self.buf.push(b'"');
    }

    fn encode(&mut self, frame: &EventFrame<'_>) {
        match frame {
            EventFrame::Queued { id } => {
                self.raw(b"{\"id\":");
                self.num_u64(*id);
                self.raw(b",\"type\":\"queued\"}");
            }
            EventFrame::Placed { id, replica } => {
                self.raw(b"{\"id\":");
                self.num_u64(*id);
                self.raw(b",\"replica\":");
                self.num_u64(*replica);
                self.raw(b",\"type\":\"placed\"}");
            }
            EventFrame::Rescued { id, from, to } => {
                self.raw(b"{\"from\":");
                self.num_u64(*from);
                self.raw(b",\"id\":");
                self.num_u64(*id);
                self.raw(b",\"to\":");
                self.num_u64(*to);
                self.raw(b",\"type\":\"rescued\"}");
            }
            EventFrame::FirstToken { id } => {
                self.raw(b"{\"id\":");
                self.num_u64(*id);
                self.raw(b",\"type\":\"first_token\"}");
            }
            EventFrame::Tokens { id, chunk } => {
                self.raw(b"{\"chunk\":");
                self.num_u64(*chunk);
                self.raw(b",\"id\":");
                self.num_u64(*id);
                self.raw(b",\"type\":\"tokens\"}");
            }
            EventFrame::ApiCallStarted {
                id,
                index,
                strategy,
                predicted_us,
                external,
            } => {
                self.raw(b"{\"external\":");
                if *external {
                    self.raw(b"true");
                } else {
                    self.raw(b"false");
                }
                self.raw(b",\"id\":");
                self.num_u64(*id);
                self.raw(b",\"index\":");
                self.num_u64(*index);
                self.raw(b",\"predicted_us\":");
                self.num_u64(*predicted_us);
                self.raw(b",\"strategy\":");
                self.quoted(strategy);
                self.raw(b",\"type\":\"api_call_started\"}");
            }
            EventFrame::ApiCallCompleted { id, index, actual_us } => {
                self.raw(b"{\"actual_us\":");
                self.num_u64(*actual_us);
                self.raw(b",\"id\":");
                self.num_u64(*id);
                self.raw(b",\"index\":");
                self.num_u64(*index);
                self.raw(b",\"type\":\"api_call_completed\"}");
            }
            EventFrame::Finished(c) => self.completion(c, true),
            EventFrame::Completion(c) => self.completion(c, false),
            EventFrame::Dropped { id, reason } => {
                self.raw(b"{\"id\":");
                self.num_u64(*id);
                self.raw(b",\"reason\":");
                self.quoted(reason);
                self.raw(b",\"type\":\"dropped\"}");
            }
            EventFrame::SessionError { id, error } => {
                self.raw(b"{\"error\":");
                self.quoted(error);
                self.raw(b",\"id\":");
                self.num_u64(*id);
                self.raw(b",\"type\":\"error\"}");
            }
            EventFrame::Error { error } => {
                self.raw(b"{\"error\":");
                self.quoted(error);
                self.raw(b",\"type\":\"error\"}");
            }
        }
    }

    /// Completion body, canonical key order: `dropped` (only when
    /// present), `generated`, `id`, `latency_us`, `tokens_decoded`,
    /// `ttft_us`, then `"type":"finished"` for event frames.
    fn completion(&mut self, c: &CompletionFrame<'_>, finished: bool) {
        self.raw(b"{");
        if let Some(reason) = c.dropped {
            self.raw(b"\"dropped\":");
            self.quoted(reason);
            self.raw(b",");
        }
        self.raw(b"\"generated\":");
        match c.generated {
            Some(toks) => {
                self.raw(b"[");
                for (i, t) in toks.iter().enumerate() {
                    if i > 0 {
                        self.raw(b",");
                    }
                    self.num_f64(f64::from(*t));
                }
                self.raw(b"]");
            }
            None => self.raw(b"null"),
        }
        self.raw(b",\"id\":");
        self.num_u64(c.id);
        self.raw(b",\"latency_us\":");
        self.num_u64(c.latency_us);
        self.raw(b",\"tokens_decoded\":");
        self.num_u64(c.tokens_decoded);
        self.raw(b",\"ttft_us\":");
        match c.ttft_us {
            Some(t) => self.num_u64(t),
            None => self.raw(b"null"),
        }
        if finished {
            self.raw(b",\"type\":\"finished\"}");
        } else {
            self.raw(b"}");
        }
    }
}

// ---------------------------------------------------------------------
// Line framing
// ---------------------------------------------------------------------

/// One framed line off the socket.
#[derive(Debug, PartialEq, Eq)]
pub enum WireLine<'a> {
    /// Raw line bytes, `\n` (and a trailing `\r`) stripped. UTF-8 is
    /// *not* validated here — the dispatcher decides how to answer
    /// invalid bytes instead of tearing the connection down.
    Frame(&'a [u8]),
    /// The line exceeded [`MAX_FRAME_BYTES`]; its `bytes` were
    /// swallowed up to the next newline so the stream stays in sync.
    Oversized { bytes: usize },
}

/// Newline framing over any [`BufRead`], reusing one line buffer for
/// the life of the connection (the inbound half of the zero-copy
/// story: [`Frame::parse`] borrows its strings from this buffer).
#[derive(Debug)]
pub struct FrameReader<R> {
    inner: R,
    buf: Vec<u8>,
}

impl<R: BufRead> FrameReader<R> {
    pub fn new(inner: R) -> Self {
        FrameReader { inner, buf: Vec::new() }
    }

    /// Next line, or `Ok(None)` at clean EOF. A final line without a
    /// trailing newline is yielded (matching `BufRead::lines`).
    pub fn next_line(&mut self) -> io::Result<Option<WireLine<'_>>> {
        self.buf.clear();
        let mut dropped = 0usize;
        let mut saw_any = false;
        loop {
            let (used, done) = {
                let chunk = match self.inner.fill_buf() {
                    Ok(c) => c,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                if chunk.is_empty() {
                    (0, true)
                } else {
                    saw_any = true;
                    match chunk.iter().position(|&b| b == b'\n') {
                        Some(i) => {
                            let take =
                                chunk.get(..i).unwrap_or_default();
                            if dropped > 0
                                || self.buf.len() + take.len()
                                    > MAX_FRAME_BYTES
                            {
                                dropped += take.len();
                            } else {
                                self.buf.extend_from_slice(take);
                            }
                            (i + 1, true)
                        }
                        None => {
                            if dropped > 0
                                || self.buf.len() + chunk.len()
                                    > MAX_FRAME_BYTES
                            {
                                dropped += chunk.len();
                            } else {
                                self.buf.extend_from_slice(chunk);
                            }
                            (chunk.len(), false)
                        }
                    }
                }
            };
            self.inner.consume(used);
            if done {
                break;
            }
        }
        if !saw_any && self.buf.is_empty() && dropped == 0 {
            return Ok(None);
        }
        if dropped > 0 {
            return Ok(Some(WireLine::Oversized {
                bytes: self.buf.len() + dropped,
            }));
        }
        if self.buf.ends_with(b"\r") {
            self.buf.pop();
        }
        Ok(Some(WireLine::Frame(&self.buf)))
    }
}

#[cfg(test)]
mod tests;
