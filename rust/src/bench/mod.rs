//! Figure harness: the (system x dataset x rate) grid runner every paper
//! figure bench drives, plus table formatting. See DESIGN.md §4 for the
//! experiment index.
//!
//! # Perf trajectory
//!
//! Three checked-in `BENCH_*.json` snapshots at the repo root record
//! the hot-path baselines CI gates against (each bench reads its file
//! via `--gate` and fails a >20% regression; a missing baseline fails
//! CI outright):
//!
//! | snapshot                     | bench             | gated metric |
//! |------------------------------|-------------------|--------------|
//! | `BENCH_micro_wire.json`      | `micro_wire`      | typed inbound `frames_per_sec`, outbound `events_per_sec` |
//! | `BENCH_micro_placement.json` | `micro_placement` | `replicas_64.cached_probes_per_sec` |
//! | `BENCH_fig6.json`            | `fig6_e2e`        | none — end-to-end trajectory only (CI checks the emission path writes a non-empty report) |
//!
//! Regenerate any snapshot with the command in its `notes` field and
//! commit the result; [`write_bench_json`] keeps the key order stable
//! so diffs stay reviewable.

use crate::cluster::ReplicaSet;
use crate::config::{ComposeConfig, CostModel, PlacementKind,
                    PrefixCacheConfig, SystemConfig};
use crate::core::types::Micros;
use crate::engine::Engine;
use crate::metrics::{RunReport, Summary};
use crate::util::json::{self, Value};
use crate::workload::{infercept, toolbench, Trace};

/// The two model presets of the paper's evaluation, as cost-model scale
/// factors over the calibrated base (Vicuna 13B is ~2x GPT-J 6B's compute
/// per token; EXPERIMENTS.md §Calibration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelPreset {
    GptJ6b,
    Vicuna13b,
}

impl ModelPreset {
    pub fn label(&self) -> &'static str {
        match self {
            ModelPreset::GptJ6b => "gptj-6b",
            ModelPreset::Vicuna13b => "vicuna-13b",
        }
    }

    pub fn cost(&self) -> CostModel {
        let base = CostModel::paper_scale();
        match self {
            ModelPreset::GptJ6b => base,
            ModelPreset::Vicuna13b => CostModel {
                decode_base: Micros(base.decode_base.0 * 19 / 10),
                decode_per_ctx_token_us: base.decode_per_ctx_token_us
                    * 1.8,
                prefill_per_token_us: base.prefill_per_token_us * 1.8,
                swap_per_token_us: base.swap_per_token_us * 1.4,
                ..base
            },
        }
    }
}

/// Datasets of the evaluation (§6.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    SingleApi,
    MultiApi,
    ToolBench,
}

impl Dataset {
    pub const ALL: [Dataset; 3] =
        [Dataset::SingleApi, Dataset::MultiApi, Dataset::ToolBench];

    pub fn label(&self) -> &'static str {
        match self {
            Dataset::SingleApi => "single-api",
            Dataset::MultiApi => "multi-api",
            Dataset::ToolBench => "toolbench",
        }
    }

    pub fn generate(&self, n: usize, rate: f64, seed: u64) -> Trace {
        match self {
            Dataset::SingleApi => infercept::single_api_dataset(n, rate,
                                                                seed),
            Dataset::MultiApi => infercept::multi_api_dataset(n, rate,
                                                              seed),
            Dataset::ToolBench => toolbench::dataset(n, rate, seed),
        }
    }
}

/// The compared systems (§6.1 baselines + §6.3 ablation).
pub const SYSTEMS: [&str; 3] = ["vllm", "infercept", "lamps"];
pub const BREAKDOWN_SYSTEMS: [&str; 4] =
    ["vllm", "infercept", "lamps-no-sched", "lamps"];

/// One measured grid cell.
#[derive(Debug, Clone)]
pub struct Cell {
    pub system: String,
    pub dataset: &'static str,
    pub model: &'static str,
    pub rate: f64,
    pub report: RunReport,
}

/// KV budget for figure cells. The paper's evaluation regime is
/// memory-bound (40 GB caps); scaled to this synthetic workload the
/// binding point sits around 12k token slots (EXPERIMENTS.md
/// §Calibration).
pub const FIGURE_BUDGET: u64 = 12_000;

/// Run one (system, dataset, model, rate) cell on the simulator with the
/// legacy (unchunked, synchronous-swap) composer settings.
pub fn run_cell(system: &str, dataset: Dataset, model: ModelPreset,
                rate: f64, n_requests: usize, seed: u64,
                time_cap: Option<Micros>) -> Cell {
    run_cell_with(system, dataset, model, rate, n_requests, seed,
                  time_cap, ComposeConfig::default())
}

/// Run one cell with explicit batch-composer settings (chunked prefill /
/// token budget / async swap) — the before/after axis of the
/// `micro_batch_composer` bench and the chunked Fig 6 grid.
#[allow(clippy::too_many_arguments)]
pub fn run_cell_with(system: &str, dataset: Dataset, model: ModelPreset,
                     rate: f64, n_requests: usize, seed: u64,
                     time_cap: Option<Micros>, compose: ComposeConfig)
                     -> Cell {
    run_cell_fleet(system, dataset, model, rate, n_requests, seed,
                   time_cap, compose, 1, PlacementKind::MemoryOverTime)
}

/// Run one cell across `replicas` engines behind a
/// [`ReplicaSet`](crate::cluster::ReplicaSet). With `replicas = 1` the
/// single-engine path runs unchanged (byte-identical — the replica
/// refactor's safety rail); with more, the cell's report is the fleet
/// aggregate. Each replica gets the full `FIGURE_BUDGET` (one modeled
/// GPU each).
#[allow(clippy::too_many_arguments)]
pub fn run_cell_fleet(system: &str, dataset: Dataset, model: ModelPreset,
                      rate: f64, n_requests: usize, seed: u64,
                      time_cap: Option<Micros>, compose: ComposeConfig,
                      replicas: usize, placement: PlacementKind)
                      -> Cell {
    run_cell_fleet_shared(system, dataset, model, rate, n_requests,
                          seed, time_cap, compose, replicas, placement,
                          PrefixCacheConfig::default(), false)
}

/// [`run_cell_fleet`] with explicit prefix-cache settings and the
/// cross-replica shared prefix index switch — the fig6
/// `LAMPS_PREFIX_CACHE` / `LAMPS_SHARED_PREFIX` axis and the
/// `micro_shared_prefix` bench's comparison knob.
#[allow(clippy::too_many_arguments)]
pub fn run_cell_fleet_shared(system: &str, dataset: Dataset,
                             model: ModelPreset, rate: f64,
                             n_requests: usize, seed: u64,
                             time_cap: Option<Micros>,
                             compose: ComposeConfig, replicas: usize,
                             placement: PlacementKind,
                             prefix: PrefixCacheConfig,
                             shared_prefix: bool) -> Cell {
    let mut cfg = SystemConfig::preset(system)
        .unwrap_or_else(|| panic!("unknown system preset {system}"));
    cfg.cost = model.cost();
    cfg.seed = seed;
    cfg.memory_budget = crate::core::types::Tokens(FIGURE_BUDGET);
    cfg.compose = compose;
    cfg.replicas = replicas.max(1);
    cfg.placement = placement;
    cfg.prefix_cache = prefix;
    cfg.shared_prefix = shared_prefix;
    // Bench-level audit switch (the CI smoke's `LAMPS_AUDIT` axis):
    // "on"/"off" force the invariant auditor either way; any other
    // value keeps Auto (debug builds audit, release builds don't).
    match std::env::var("LAMPS_AUDIT").as_deref() {
        Ok("on") => cfg.audit = crate::config::AuditMode::On,
        Ok("off") => cfg.audit = crate::config::AuditMode::Off,
        _ => {}
    }
    // Bench-level duration-seam switch (the CI smoke's
    // `LAMPS_API_PRED` axis): "learned" turns the online per-class
    // estimators on; "static" (or unset) keeps the pass-through seam.
    if let Ok(name) = std::env::var("LAMPS_API_PRED") {
        if let Some(kind) = crate::config::ApiPredKind::parse(&name) {
            cfg.api_pred = kind;
        }
    }
    // ToolBench uses the score-update interval of 10 (§5).
    if dataset == Dataset::ToolBench {
        cfg.score_update_interval = 10;
    }
    let trace = dataset.generate(n_requests, rate, seed);
    let report = if cfg.replicas > 1 {
        let mut set = ReplicaSet::simulated(cfg);
        set.run_trace_limited(&trace, time_cap).fleet
    } else {
        let mut engine = Engine::simulated(cfg);
        engine.run_trace_limited(&trace, time_cap)
    };
    Cell {
        system: system.to_string(),
        dataset: dataset.label(),
        model: model.label(),
        rate,
        report,
    }
}

/// Print a figure table: one row per cell with the paper's four metrics.
pub fn print_cells(title: &str, cells: &[Cell]) {
    println!("\n== {title} ==");
    println!("{:<12} {:<11} {:<10} {:>5}  {:>12} {:>12} {:>12} {:>12} \
              {:>9} {:>6}",
             "system", "dataset", "model", "rate", "lat_mean(s)",
             "lat_p99(s)", "ttft_mean(s)", "ttft_p99(s)", "thr(r/s)",
             "done");
    for c in cells {
        println!("{:<12} {:<11} {:<10} {:>5.1}  {:>12.3} {:>12.3} \
                  {:>12.3} {:>12.3} {:>9.3} {:>6}",
                 c.system, c.dataset, c.model, c.rate,
                 c.report.latency.mean_secs(),
                 c.report.latency.p99_secs(),
                 c.report.ttft.mean_secs(),
                 c.report.ttft.p99_secs(),
                 c.report.throughput_rps,
                 c.report.completed);
    }
}

/// A [`Summary`] in the stable `BENCH_*.json` schema.
pub fn summary_json(s: &Summary) -> Value {
    json::obj(vec![
        ("mean_us", json::num(s.mean_us)),
        ("p50_us", json::num(s.p50_us)),
        ("p99_us", json::num(s.p99_us)),
        ("max_us", json::num(s.max_us)),
    ])
}

/// One grid cell in the stable `BENCH_*.json` schema: the simulated
/// completion/TTFT percentiles plus the measured wall-clock cost of
/// producing them (`wall_elapsed_us` comes from the bench binary —
/// library code never reads the wall clock). `engine_steps_per_sec`
/// is the raw-speed axis the perf trajectory tracks: simulated
/// engine iterations retired per wall second.
pub fn cell_json(cell: &Cell, wall_elapsed_us: u64) -> Value {
    let steps_per_sec = if wall_elapsed_us == 0 {
        0.0
    } else {
        cell.report.iterations as f64 * 1e6 / wall_elapsed_us as f64
    };
    json::obj(vec![
        ("system", json::s(&cell.system)),
        ("dataset", json::s(cell.dataset)),
        ("model", json::s(cell.model)),
        ("rate", json::num(cell.rate)),
        ("completed", json::num(cell.report.completed as f64)),
        ("latency", summary_json(&cell.report.latency)),
        ("ttft", summary_json(&cell.report.ttft)),
        ("throughput_rps", json::num(cell.report.throughput_rps)),
        ("wall", json::obj(vec![
            ("elapsed_us", json::num(wall_elapsed_us as f64)),
            ("engine_steps_per_sec", json::num(steps_per_sec)),
        ])),
    ])
}

/// Write a `BENCH_<name>.json` perf-trajectory snapshot: a single
/// JSON object with the bench name first and the caller's payload
/// pairs after it. The checked-in copies at the repository root are
/// the regression baselines the CI bench smoke compares against.
pub fn write_bench_json(path: &str, bench: &str,
                        body: Vec<(&str, Value)>)
                        -> std::io::Result<()> {
    let mut pairs = vec![("bench", json::s(bench))];
    pairs.extend(body);
    let mut text = json::write(&json::obj(pairs));
    text.push('\n');
    std::fs::write(path, text)
}

/// §6.2-style headline: percentage improvement of `a` over `b`
/// (positive = `a` better, i.e. lower).
pub fn improvement_pct(a: f64, b: f64) -> f64 {
    if b <= 0.0 {
        return 0.0;
    }
    (b - a) / b * 100.0
}

/// Print LAMPS-vs-baseline improvements for a set of cells sharing
/// (dataset, model, rate).
pub fn print_headline(cells: &[Cell]) {
    let lamps: Vec<&Cell> =
        cells.iter().filter(|c| c.system == "lamps").collect();
    for l in lamps {
        for base_name in ["infercept", "vllm"] {
            if let Some(b) = cells.iter().find(|c| {
                c.system == base_name
                    && c.dataset == l.dataset
                    && c.model == l.model
                    && c.rate == l.rate
            }) {
                println!(
                    "[headline] {} {} rate {:>4.1}: vs {:<9} latency {:+.1}% \
                     ttft {:+.1}%",
                    l.dataset, l.model, l.rate, base_name,
                    improvement_pct(l.report.latency.mean_us,
                                    b.report.latency.mean_us),
                    improvement_pct(l.report.ttft.mean_us,
                                    b.report.ttft.mean_us));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improvement_math() {
        assert_eq!(improvement_pct(50.0, 100.0), 50.0);
        assert_eq!(improvement_pct(100.0, 100.0), 0.0);
        assert!(improvement_pct(150.0, 100.0) < 0.0);
        assert_eq!(improvement_pct(1.0, 0.0), 0.0);
    }

    #[test]
    fn presets_have_distinct_costs() {
        let g = ModelPreset::GptJ6b.cost();
        let v = ModelPreset::Vicuna13b.cost();
        assert!(v.decode_base > g.decode_base);
        assert!(v.prefill_per_token_us > g.prefill_per_token_us);
    }

    #[test]
    fn small_cell_runs() {
        let cell = run_cell("lamps", Dataset::SingleApi,
                            ModelPreset::GptJ6b, 2.0, 20, 42, None);
        assert_eq!(cell.report.completed, 20);
        assert!(cell.report.latency.mean_us > 0.0);
    }

    #[test]
    fn small_fleet_cell_runs() {
        let cell = run_cell_fleet("lamps", Dataset::SingleApi,
                                  ModelPreset::GptJ6b, 2.0, 20, 42, None,
                                  ComposeConfig::default(), 2,
                                  PlacementKind::RoundRobin);
        assert_eq!(cell.report.completed, 20);
        assert!(cell.report.latency.mean_us > 0.0);
    }
}
