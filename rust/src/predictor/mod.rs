//! Prediction of request properties (paper §4.2): pre-API output length
//! from the prompt, API duration + response length from the per-class
//! historical table (Table 2).
//!
//! The engine consumes predictions through the [`Predictor`] trait; three
//! implementations exist:
//! - [`oracle::OraclePredictor`] — true values from the spec (complete
//!   information, used by the Fig 3 analysis and as INFERCEPT's at-API
//!   knowledge).
//! - [`oracle::NoisyOraclePredictor`] — Gaussian error injection
//!   `N(0, p * measured)` per Fig 11.
//! - [`opt_classifier::PjrtPredictor`] — the AOT-compiled OPT-125M
//!   stand-in (embedding -> 50-bin classifier) executed via PJRT.
//!
//! Whatever the predictor, every API-*duration* estimate the engine
//! consumes afterwards flows through the [`duration::DurationModel`]
//! seam. Its contract, which all five consumer layers (handling choice,
//! rank integral, `encounter_api`, the `ApiCallStarted` event, and the
//! stateless placement/rescue probes) rely on:
//! - revisions are **pure reads** (`&self`) — probes never mutate
//!   estimator state;
//! - estimators **update at outcome only** — one `observe` per finished
//!   call, at the simulated/external return sites; rescue/adopt moves a
//!   request without a second predict or observe;
//! - estimator state is **fixed-order** (a class-indexed array, never
//!   HashMap iteration), so learned runs stay bit-deterministic.
//!
//! Direct `api_stats` reads outside `predictor/` and `workload/` are
//! banned by lamps-lint rule `predictor-seam`.

pub mod api_stats;
pub mod duration;
#[cfg(feature = "pjrt")]
pub mod opt_classifier;
pub mod oracle;

use crate::core::request::{RequestSpec, SegmentPrediction};

/// Produces one [`SegmentPrediction`] per segment of a request.
pub trait Predictor {
    fn predict(&mut self, spec: &RequestSpec) -> Vec<SegmentPrediction>;

    /// Prediction latency to charge per request (the paper measures
    /// 13.7 ms/input for OPT-125M on an A100; simulated predictors are
    /// free unless configured otherwise).
    fn latency(&self) -> crate::core::types::Micros {
        crate::core::types::Micros::ZERO
    }
}
