//! Oracle predictors: exact values and Gaussian-noised values (Fig 11's
//! controlled error injection: `error ~ N(0, p * measured)`,
//! `predicted = measured + error`).

use crate::core::request::{RequestSpec, SegmentPrediction};
use crate::core::types::{Micros, Tokens};
use crate::predictor::Predictor;
use crate::util::Rng;

/// Complete-information predictor: returns the spec's true values.
#[derive(Debug, Default)]
pub struct OraclePredictor;

impl Predictor for OraclePredictor {
    fn predict(&mut self, spec: &RequestSpec) -> Vec<SegmentPrediction> {
        (0..spec.num_segments())
            .map(|seg| SegmentPrediction {
                decode_tokens: spec.segment_decode(seg),
                api_duration: spec.api_calls.get(seg).map(|c| c.duration),
                response_tokens: spec
                    .api_calls
                    .get(seg)
                    .map(|c| c.response_tokens)
                    .unwrap_or(Tokens::ZERO),
            })
            .collect()
    }
}

/// Oracle + Gaussian error on output length and API duration (Fig 11).
#[derive(Debug)]
pub struct NoisyOraclePredictor {
    /// The paper's error parameter `p` (0.05, 0.10, 0.30, 0.50).
    pub error_pct: f64,
    rng: Rng,
}

impl NoisyOraclePredictor {
    pub fn new(error_pct: f64, seed: u64) -> NoisyOraclePredictor {
        NoisyOraclePredictor {
            error_pct,
            rng: Rng::new(seed ^ 0xB10E_F00D),
        }
    }

    fn noisy(&mut self, measured: f64) -> f64 {
        let err = self.rng.normal() * self.error_pct * measured;
        (measured + err).max(0.0)
    }
}

impl Predictor for NoisyOraclePredictor {
    fn predict(&mut self, spec: &RequestSpec) -> Vec<SegmentPrediction> {
        (0..spec.num_segments())
            .map(|seg| {
                let true_decode = spec.segment_decode(seg).0 as f64;
                let decode = self.noisy(true_decode).round().max(1.0) as u64;
                let api_duration = spec.api_calls.get(seg).map(|c| {
                    Micros::from_secs_f64(
                        self.noisy(c.duration.as_secs_f64()))
                });
                SegmentPrediction {
                    decode_tokens: Tokens(decode),
                    api_duration,
                    response_tokens: spec
                        .api_calls
                        .get(seg)
                        .map(|c| c.response_tokens)
                        .unwrap_or(Tokens::ZERO),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::{ApiCallSpec, ApiType};
    use crate::core::types::RequestId;

    fn spec() -> RequestSpec {
        RequestSpec {
            id: RequestId(1),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(8),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(40),
                api_type: ApiType::Qa,
                duration: Micros::from_secs_f64(0.7),
                response_tokens: Tokens(20),
            }],
            final_decode: Tokens(60),
        }
    }

    #[test]
    fn oracle_is_exact() {
        let preds = OraclePredictor.predict(&spec());
        assert_eq!(preds.len(), 2);
        assert_eq!(preds[0].decode_tokens, Tokens(40));
        assert_eq!(preds[0].api_duration, Some(Micros::from_secs_f64(0.7)));
        assert_eq!(preds[0].response_tokens, Tokens(20));
        assert_eq!(preds[1].decode_tokens, Tokens(60));
        assert_eq!(preds[1].api_duration, None);
    }

    #[test]
    fn zero_noise_equals_oracle() {
        let mut noisy = NoisyOraclePredictor::new(0.0, 1);
        let preds = noisy.predict(&spec());
        assert_eq!(preds, OraclePredictor.predict(&spec()));
    }

    #[test]
    fn noise_scale_tracks_error_pct() {
        let s = spec();
        let sample_err = |pct: f64| -> f64 {
            let mut p = NoisyOraclePredictor::new(pct, 3);
            let n = 2000;
            (0..n)
                .map(|_| {
                    let pred = p.predict(&s)[0].decode_tokens.0 as f64;
                    (pred - 40.0).abs()
                })
                .sum::<f64>()
                / n as f64
        };
        let small = sample_err(0.05);
        let large = sample_err(0.50);
        // E|N(0, p*40)| = p*40*sqrt(2/pi): ~1.6 at 5%, ~16 at 50%.
        assert!(small < 3.0, "small {small}");
        assert!(large > 10.0, "large {large}");
        assert!(large > 4.0 * small);
    }

    #[test]
    fn noisy_never_negative() {
        let mut p = NoisyOraclePredictor::new(2.0, 9);
        for _ in 0..500 {
            let preds = p.predict(&spec());
            assert!(preds[0].decode_tokens.0 >= 1);
        }
    }
}
