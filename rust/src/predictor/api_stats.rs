//! Per-API-class duration / frequency statistics — the paper's Table 2.
//!
//! LAMPS predicts API duration from the API *type* alone: "each corresponds
//! to specific operations with known computational complexities ...
//! execution times within the same API type have low variance" (§3.2.1).
//! This table is both the workload generator's sampling source and the
//! predictor's estimate (the predictor uses the class mean).

use crate::core::request::ApiType;
use crate::core::types::Micros;

/// (mean, std) pairs exactly as published in Table 2.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ApiClassStats {
    /// API duration in seconds: (mean, std).
    pub duration_secs: (f64, f64),
    /// API calls per request: (mean, std).
    pub calls_per_request: (f64, f64),
    /// Response length in tokens: (mean, std). Not in Table 2; profiled
    /// from the INFERCEPT artifact descriptions (short structured replies
    /// for Math/VE, longer text for QA/Chatbot).
    pub response_tokens: (f64, f64),
}

/// Table 2, INFERCEPT rows.
pub fn stats_for(api: ApiType) -> ApiClassStats {
    match api {
        ApiType::Math => ApiClassStats {
            duration_secs: (9e-5, 6e-5),
            calls_per_request: (3.75, 1.3),
            response_tokens: (4.0, 2.0),
        },
        ApiType::Qa => ApiClassStats {
            duration_secs: (0.69, 0.17),
            calls_per_request: (2.52, 1.73),
            response_tokens: (32.0, 12.0),
        },
        ApiType::Ve => ApiClassStats {
            duration_secs: (0.09, 0.014),
            calls_per_request: (28.18, 15.2),
            response_tokens: (8.0, 4.0),
        },
        ApiType::Chatbot => ApiClassStats {
            duration_secs: (28.6, 15.6),
            calls_per_request: (4.45, 1.96),
            response_tokens: (48.0, 24.0),
        },
        ApiType::Image => ApiClassStats {
            duration_secs: (20.03, 7.8),
            calls_per_request: (6.91, 3.93),
            response_tokens: (6.0, 2.0),
        },
        ApiType::Tts => ApiClassStats {
            duration_secs: (17.24, 7.6),
            calls_per_request: (6.91, 3.93),
            response_tokens: (6.0, 2.0),
        },
        // Table 2, ToolBench row (one latency class for all categories).
        ApiType::Tool(_) => ApiClassStats {
            duration_secs: (1.72, 3.33),
            calls_per_request: (2.45, 1.81),
            response_tokens: (24.0, 10.0),
        },
    }
}

/// The predictor's duration estimate for a class: the historical mean.
pub fn predicted_duration(api: ApiType) -> Micros {
    Micros::from_secs_f64(stats_for(api).duration_secs.0)
}

/// The predictor's response-length estimate: the historical mean.
pub fn predicted_response_tokens(api: ApiType) -> u64 {
    stats_for(api).response_tokens.0.round() as u64
}

/// All INFERCEPT-dataset classes, with the mix weights used by the
/// workload generator (uniform over the six augmentation types, matching
/// INFERCEPT's combined-workload construction).
pub const INFERCEPT_CLASSES: [ApiType; 6] = [
    ApiType::Math,
    ApiType::Qa,
    ApiType::Ve,
    ApiType::Chatbot,
    ApiType::Image,
    ApiType::Tts,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values_pinned() {
        assert_eq!(stats_for(ApiType::Math).duration_secs, (9e-5, 6e-5));
        assert_eq!(stats_for(ApiType::Chatbot).duration_secs, (28.6, 15.6));
        assert_eq!(stats_for(ApiType::Tool(7)).duration_secs, (1.72, 3.33));
        assert_eq!(stats_for(ApiType::Ve).calls_per_request, (28.18, 15.2));
    }

    #[test]
    fn predicted_duration_is_class_mean() {
        assert_eq!(predicted_duration(ApiType::Image),
                   Micros::from_secs_f64(20.03));
        assert_eq!(predicted_duration(ApiType::Math), Micros(90));
    }

    #[test]
    fn class_labels_distinct() {
        let labels: Vec<&str> =
            INFERCEPT_CLASSES.iter().map(|c| c.label()).collect();
        let mut dedup = labels.clone();
        dedup.dedup();
        assert_eq!(labels.len(), dedup.len());
    }
}
