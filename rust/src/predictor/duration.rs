//! The duration seam: every API-duration estimate an engine consumes is
//! routed through one [`DurationModel`] so the static Table 2 path and the
//! learned online estimators are interchangeable behind a single surface.
//!
//! Seam contract (every consumer relies on all three):
//! - **Pure reads.** [`DurationModel::revise`] is `&self` and mutates
//!   nothing — placement/rescue probes in `cluster/` may call it freely
//!   without breaking the probe-purity contract (lamps-lint `probe-purity`
//!   guards the engine side; this module guards the model side by simply
//!   having no interior mutability).
//! - **Update at outcome only.** [`DurationModel::observe`] is the single
//!   mutation point and is called exactly once per finished API call, at
//!   the two outcome sites (`route_api_return` for simulated returns,
//!   which `complete_api_call` also funnels through for external ones).
//!   Rescue/adopt carries a request's predictions across replicas without
//!   a second predict or observe.
//! - **Fixed-order state.** Estimators live in a fixed `[ClassEstimator;
//!   NUM_CLASSES]` array indexed by [`class_index`]; no HashMap iteration
//!   anywhere, so two identical runs produce bit-identical estimator
//!   state and reports (replica determinism).
//!
//! With [`ApiPredKind::Static`] (the default) `revise` is the identity
//! and `observe` a no-op: reports stay byte-identical to the pre-seam
//! code. With `Learned`, each class keeps an online mean (running mean
//! early, 5% EWMA once warm), a 64-sample sliding window whose sorted
//! copy serves as the streaming quantile sketch, and an EWMA of the
//! *post-revision* relative error. `revise` blends the raw per-call
//! estimate toward a conservative class estimate (mean nudged toward p90)
//! with a weight that grows as the observed error histogram runs hot —
//! the adaptive fallback of ROADMAP's learned-predictor item.

use crate::config::ApiPredKind;
use crate::core::request::ApiType;
use crate::core::types::Micros;
use crate::util::json::{self, Value};

use super::api_stats;

/// Number of duration classes: the six INFERCEPT augmentations plus the
/// collapsed ToolBench row (Table 2 collapses all tool categories into
/// one latency class, and so do we).
pub const NUM_CLASSES: usize = 7;

/// Sliding-window size of the per-class quantile sketch.
const WINDOW: usize = 64;

/// Observations a class needs before `revise` trusts its estimate.
const MIN_OBS: u64 = 4;

/// EWMA floor: once `n >= 20`, new outcomes weigh 5%.
const EWMA_ALPHA: f64 = 0.05;

/// Relative error (EWMA) at which blending starts / saturates.
const HEAT_LO: f64 = 0.10;
const HEAT_HI: f64 = 0.50;

/// Fraction of the (p90 - mean) gap added to the class estimate at full
/// heat — the conservative-quantile bias (overestimating a duration is
/// the cheaper scheduling mistake: it costs recompute, not memory).
const CONSERVATIVE_P90_WEIGHT: f64 = 0.25;

/// Fixed class index for the estimator array (never a HashMap key).
pub fn class_index(api: ApiType) -> usize {
    match api {
        ApiType::Math => 0,
        ApiType::Qa => 1,
        ApiType::Ve => 2,
        ApiType::Chatbot => 3,
        ApiType::Image => 4,
        ApiType::Tts => 5,
        ApiType::Tool(_) => 6,
    }
}

fn class_label(idx: usize) -> &'static str {
    match idx {
        0 => "math",
        1 => "qa",
        2 => "ve",
        3 => "chatbot",
        4 => "image",
        5 => "tts",
        _ => "tool",
    }
}

/// The static prior for a class — Table 2's mean, re-exported so
/// consumers outside `predictor/` (the server's wire fallback, the
/// engine) read it through the seam instead of `api_stats` directly
/// (lamps-lint `predictor-seam` bans the direct call).
pub fn class_prior_duration(api: ApiType) -> Micros {
    api_stats::predicted_duration(api)
}

/// Static response-length prior, same seam role as
/// [`class_prior_duration`].
pub fn class_prior_response_tokens(api: ApiType) -> u64 {
    api_stats::predicted_response_tokens(api)
}

/// Online per-class duration estimator (learned mode only).
#[derive(Debug, Clone)]
struct ClassEstimator {
    /// Outcomes observed.
    n: u64,
    /// Online mean of actual durations (us): exact running mean while
    /// `1/n > EWMA_ALPHA`, 5% EWMA afterwards.
    mean_us: f64,
    /// EWMA of the post-revision relative error |pred-actual|/actual.
    rel_err_ema: f64,
    /// Sliding window of the last `WINDOW` actual durations (us),
    /// insertion-ordered ring.
    window: Vec<f64>,
    /// Next ring slot to overwrite once the window is full.
    cursor: usize,
    /// Sorted copy of `window`, rebuilt on every observe — the quantile
    /// sketch. 64 doubles per class; rebuild cost is trivial next to a
    /// scheduler step.
    sorted: Vec<f64>,
}

impl ClassEstimator {
    fn new() -> ClassEstimator {
        ClassEstimator {
            n: 0,
            mean_us: 0.0,
            rel_err_ema: 0.0,
            window: Vec::new(),
            cursor: 0,
            sorted: Vec::new(),
        }
    }

    fn observe(&mut self, predicted: Micros, actual: Micros) {
        self.n += 1;
        let actual_us = actual.0 as f64;
        let alpha = (1.0 / self.n as f64).max(EWMA_ALPHA);
        self.mean_us += alpha * (actual_us - self.mean_us);

        let denom = (actual.0.max(1)) as f64;
        let rel = (predicted.0 as f64 - actual_us).abs() / denom;
        self.rel_err_ema += alpha * (rel - self.rel_err_ema);

        if self.window.len() < WINDOW {
            self.window.push(actual_us);
        } else {
            self.window[self.cursor] = actual_us;
            self.cursor = (self.cursor + 1) % WINDOW;
        }
        self.sorted.clear();
        self.sorted.extend_from_slice(&self.window);
        self.sorted.sort_by(|a, b| a.total_cmp(b));
    }

    /// Windowed quantile (nearest-rank on the sorted copy).
    fn quantile(&self, q: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let idx = ((self.sorted.len() - 1) as f64 * q).round() as usize;
        self.sorted[idx.min(self.sorted.len() - 1)]
    }

    /// Blend weight in [0, 1]: 0 while observed error stays under
    /// `HEAT_LO`, saturating at `HEAT_HI`.
    fn heat(&self) -> f64 {
        ((self.rel_err_ema - HEAT_LO) / (HEAT_HI - HEAT_LO)).clamp(0.0, 1.0)
    }
}

/// The seam every API-duration consumer reads through. Constructed once
/// per engine from `cfg.api_pred`; `Static` is stateless and free.
#[derive(Debug, Clone)]
pub struct DurationModel {
    kind: ApiPredKind,
    classes: Vec<ClassEstimator>,
}

impl DurationModel {
    pub fn new(kind: ApiPredKind) -> DurationModel {
        DurationModel {
            kind,
            classes: (0..NUM_CLASSES).map(|_| ClassEstimator::new())
                                     .collect(),
        }
    }

    /// True when revisions/observations are live (learned mode).
    pub fn is_learned(&self) -> bool {
        matches!(self.kind, ApiPredKind::Learned)
    }

    /// Revise a raw per-call duration estimate through the class
    /// estimator. Pure (`&self`): placement probes call this. Static
    /// mode, or a class with fewer than `MIN_OBS` outcomes, returns the
    /// input unchanged — the byte-identity guarantee.
    pub fn revise(&self, api: ApiType, raw: Micros) -> Micros {
        if !self.is_learned() {
            return raw;
        }
        let est = &self.classes[class_index(api)];
        if est.n < MIN_OBS {
            return raw;
        }
        let h = est.heat();
        if h == 0.0 {
            return raw;
        }
        let p90 = est.quantile(0.90);
        let class_est = est.mean_us
            + h * CONSERVATIVE_P90_WEIGHT * (p90 - est.mean_us).max(0.0);
        let raw_us = raw.0 as f64;
        let revised = raw_us + h * (class_est - raw_us);
        Micros(revised.max(0.0).round() as u64)
    }

    /// Record one finished call's (predicted, actual) pair. The single
    /// mutation point; called only from the outcome sites. No-op in
    /// static mode.
    pub fn observe(&mut self, api: ApiType, predicted: Micros,
                   actual: Micros) {
        if !self.is_learned() {
            return;
        }
        self.classes[class_index(api)].observe(predicted, actual);
    }

    /// Total outcomes observed across all classes.
    pub fn observations(&self) -> u64 {
        self.classes.iter().map(|c| c.n).sum()
    }

    /// Estimator state for the metrics JSON: one object per class that
    /// has observations (fixed class order; `Value::Obj` itself sorts
    /// keys, so the report stays deterministic either way). `None` in
    /// static mode so the off-path report shape is pinned.
    pub fn snapshot(&self) -> Option<Value> {
        if !self.is_learned() {
            return None;
        }
        let mut pairs: Vec<(&str, Value)> = Vec::new();
        for (idx, est) in self.classes.iter().enumerate() {
            if est.n == 0 {
                continue;
            }
            pairs.push((class_label(idx), json::obj(vec![
                ("n", json::num(est.n as f64)),
                ("mean_us", json::num(est.mean_us)),
                ("p50_us", json::num(est.quantile(0.50))),
                ("p90_us", json::num(est.quantile(0.90))),
                ("rel_err_ema", json::num(est.rel_err_ema)),
                ("blend", json::num(est.heat())),
            ])));
        }
        Some(json::obj(pairs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(us: u64) -> Micros {
        Micros(us)
    }

    #[test]
    fn static_mode_is_identity_and_stateless() {
        let mut model = DurationModel::new(ApiPredKind::Static);
        model.observe(ApiType::Qa, m(1_000_000), m(2_000_000));
        assert_eq!(model.observations(), 0);
        assert_eq!(model.revise(ApiType::Qa, m(123_456)), m(123_456));
        assert!(model.snapshot().is_none());
    }

    #[test]
    fn learned_passes_through_until_min_obs() {
        let mut model = DurationModel::new(ApiPredKind::Learned);
        for _ in 0..MIN_OBS - 1 {
            model.observe(ApiType::Qa, m(500_000), m(1_000_000));
        }
        assert_eq!(model.revise(ApiType::Qa, m(500_000)), m(500_000));
        model.observe(ApiType::Qa, m(500_000), m(1_000_000));
        // Error EWMA is hot (50%), so the estimate shifts toward the
        // observed mean of 1s.
        let revised = model.revise(ApiType::Qa, m(500_000));
        assert!(revised > m(500_000), "revised {revised:?}");
    }

    #[test]
    fn cold_error_keeps_raw_estimates() {
        let mut model = DurationModel::new(ApiPredKind::Learned);
        for _ in 0..32 {
            // Perfect predictions: rel error 0 stays under HEAT_LO.
            model.observe(ApiType::Ve, m(90_000), m(90_000));
        }
        assert_eq!(model.revise(ApiType::Ve, m(42_000)), m(42_000));
    }

    #[test]
    fn convergence_toward_class_mean_under_error() {
        let mut model = DurationModel::new(ApiPredKind::Learned);
        let actual = m(1_000_000);
        // Alternating 2x over/under-prediction: rel error ~ 0.75, well
        // past HEAT_HI, so blending saturates.
        for i in 0..200u64 {
            let pred = if i % 2 == 0 { m(2_000_000) } else { m(500_000) };
            model.observe(ApiType::Image, pred, actual);
        }
        let revised = model.revise(ApiType::Image, m(3_000_000));
        let err = (revised.0 as f64 - 1_000_000.0).abs() / 1_000_000.0;
        assert!(err < 0.05,
                "saturated blend should sit on the class mean, got \
                 {revised:?}");
    }

    #[test]
    fn estimator_state_is_deterministic() {
        let run = || {
            let mut model = DurationModel::new(ApiPredKind::Learned);
            for i in 0..100u64 {
                let api = super::super::api_stats::INFERCEPT_CLASSES
                    [(i % 6) as usize];
                model.observe(api, m(1_000 + i * 7), m(900 + i * 11));
            }
            json::write(&model.snapshot().unwrap())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn quantiles_track_the_window() {
        let mut model = DurationModel::new(ApiPredKind::Learned);
        for i in 1..=100u64 {
            model.observe(ApiType::Tts, m(0), m(i * 1_000));
        }
        let snap = json::write(&model.snapshot().unwrap());
        // Window holds the last 64 samples (37k..100k us); p50 sits near
        // the middle of that range, not of the full stream.
        let est = &model.classes[class_index(ApiType::Tts)];
        assert_eq!(est.window.len(), WINDOW);
        assert!(est.quantile(0.50) >= 37_000.0);
        assert!(snap.contains("\"tts\""));
    }

    #[test]
    fn seam_reexports_match_table2() {
        assert_eq!(class_prior_duration(ApiType::Image),
                   api_stats::predicted_duration(ApiType::Image));
        assert_eq!(class_prior_response_tokens(ApiType::Qa),
                   api_stats::predicted_response_tokens(ApiType::Qa));
    }
}
