//! The deployed predictor (paper §5): prompt -> OPT-125M-stand-in bin
//! classifier (via PJRT) for the pre-API output length, plus the Table 2
//! class means for API duration and response length.

use crate::core::request::{RequestSpec, SegmentPrediction};
use crate::core::types::{Micros, Tokens};
use crate::predictor::api_stats;
use crate::predictor::Predictor;
use crate::runtime::PredictorRuntime;

pub struct PjrtPredictor {
    runtime: PredictorRuntime,
    /// Per-inference latency charged to each prediction (the paper
    /// measures 13.7 ms on an A100; we charge the measured local time by
    /// default, see `fixed_latency`).
    pub fixed_latency: Option<Micros>,
}

impl PjrtPredictor {
    pub fn new(runtime: PredictorRuntime) -> PjrtPredictor {
        PjrtPredictor {
            runtime,
            fixed_latency: None,
        }
    }
}

impl Predictor for PjrtPredictor {
    fn predict(&mut self, spec: &RequestSpec) -> Vec<SegmentPrediction> {
        // The prompt predicts the *first* pre-API segment length (§4.2:
        // after each API the request re-enters and is re-classified; our
        // later-segment estimate reuses the same prediction scaled like
        // the generator's continuation segments).
        let first_len = if spec.prompt.is_empty() {
            // No prompt text (synthetic INFERCEPT traces): fall back to
            // the true value — those datasets "include detailed output
            // length information, making prediction unnecessary" (§5).
            spec.segment_decode(0).0
        } else {
            let bin = self
                .runtime
                .predict_bin(&spec.prompt)
                .unwrap_or(0);
            self.runtime.bin_to_tokens(bin).max(1)
        };

        (0..spec.num_segments())
            .map(|seg| {
                let decode = if seg == 0 {
                    first_len
                } else if seg < spec.api_calls.len() {
                    // Continuation segments: generator draws ~0.4x the
                    // first segment.
                    (first_len * 2 / 5).max(1)
                } else {
                    (first_len / 2).max(1)
                };
                let api = spec.api_calls.get(seg);
                SegmentPrediction {
                    decode_tokens: Tokens(decode),
                    api_duration: api.map(|c| {
                        api_stats::predicted_duration(c.api_type)
                    }),
                    response_tokens: Tokens(api.map_or(0, |c| {
                        api_stats::predicted_response_tokens(c.api_type)
                    })),
                }
            })
            .collect()
    }

    fn latency(&self) -> Micros {
        self.fixed_latency.unwrap_or(Micros::ZERO)
    }
}
