//! Serving metrics: end-to-end latency, TTFT, throughput (the paper's
//! §6.1 metrics, each reported as mean and P99), plus the KV-occupancy /
//! completion timelines behind Fig 2.

use crate::core::types::{Micros, RequestId};

/// Buckets of the per-call predicted-vs-actual API-duration error
/// histogram (`--api-source external`), over the relative error
/// `|actual - predicted| / max(predicted, 1 us)`. Upper bounds:
/// ≤10%, ≤25%, ≤50%, ≤100%, ≤200%, and a >200% overflow bucket.
pub const API_ERR_BUCKET_BOUNDS: [f64; 5] = [0.10, 0.25, 0.50, 1.0, 2.0];

/// Number of histogram buckets (the bounds plus the overflow bucket).
pub const API_ERR_BUCKETS: usize = API_ERR_BUCKET_BOUNDS.len() + 1;

/// Summary statistics over a set of duration samples.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Summary {
    pub n: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl Summary {
    pub fn from_samples(samples: &[Micros]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let mut xs: Vec<u64> = samples.iter().map(|m| m.0).collect();
        xs.sort_unstable();
        let n = xs.len();
        Summary {
            n,
            mean_us: xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64,
            p50_us: percentile(&xs, 0.50),
            p99_us: percentile(&xs, 0.99),
            max_us: xs[n - 1] as f64,
        }
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean_us / 1e6
    }

    pub fn p99_secs(&self) -> f64 {
        self.p99_us / 1e6
    }
}

/// Nearest-rank percentile on a sorted slice.
fn percentile(sorted: &[u64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize)
        .clamp(1, sorted.len());
    sorted[rank - 1] as f64
}

/// Fleet-level stats of the cross-replica shared prefix index
/// (`--shared-prefix`): how much context the prefix-affinity placement
/// steered onto replicas that already held it. Carried by
/// [`FleetReport`](crate::cluster::FleetReport) only when the index is
/// active, so the index-less fleet JSON stays byte-identical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SharedPrefixStats {
    /// Arrivals placed with a non-zero cached-prefix credit.
    pub steered_requests: u64,
    /// Total cached-token credit of those placements — the tokens the
    /// placement expected to be served from the owning replica's
    /// resident prefix blocks instead of being re-prefilled. Advisory
    /// (an optimistic upper bound): eviction between placement and
    /// admission turns credit back into prefill, never into an error.
    pub steered_tokens: u64,
    /// Per-replica split of `steered_tokens` (the hit-delta view of
    /// where the index concentrated shared prefixes).
    pub per_replica_steered_tokens: Vec<u64>,
}

impl SharedPrefixStats {
    pub fn new(replicas: usize) -> SharedPrefixStats {
        SharedPrefixStats {
            steered_requests: 0,
            steered_tokens: 0,
            per_replica_steered_tokens: vec![0; replicas],
        }
    }

    /// Record one placement of `tokens` expected-cached credit onto
    /// `replica`; zero-credit placements are not steering.
    pub fn note(&mut self, replica: usize, tokens: u64) {
        if tokens == 0 {
            return;
        }
        self.steered_requests += 1;
        self.steered_tokens += tokens;
        if let Some(t) = self.per_replica_steered_tokens.get_mut(replica) {
            *t += tokens;
        }
    }

    /// Reverse one [`SharedPrefixStats::note`]: the request was moved
    /// off `replica` (admission re-queue) before it could use the
    /// credit, so the dispatch-time claim is withdrawn. Saturating —
    /// the stats are advisory and must never panic a run.
    pub fn unnote(&mut self, replica: usize, tokens: u64) {
        if tokens == 0 {
            return;
        }
        self.steered_requests = self.steered_requests.saturating_sub(1);
        self.steered_tokens = self.steered_tokens.saturating_sub(tokens);
        if let Some(t) = self.per_replica_steered_tokens.get_mut(replica) {
            *t = t.saturating_sub(tokens);
        }
    }

    /// JSON value form (embedded in the fleet report).
    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{self, Value};
        json::obj(vec![
            ("steered_requests",
             json::num(self.steered_requests as f64)),
            ("steered_tokens", json::num(self.steered_tokens as f64)),
            ("per_replica_steered_tokens",
             Value::Arr(self
                 .per_replica_steered_tokens
                 .iter()
                 .map(|&t| json::num(t as f64))
                 .collect())),
        ])
    }
}

/// Fleet-level stats of the modeled network (`--net-model`): gossip
/// traffic, stale-steer re-prefill cost, bounded-staleness rescue
/// refusals, and elastic scaling events. Carried by
/// [`FleetReport`](crate::cluster::FleetReport) only when the network
/// is armed, so the net-less fleet JSON stays byte-identical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetStats {
    /// Placements whose gossip-lagged cached-prefix credit exceeded
    /// what was actually resident on the chosen replica at dispatch —
    /// a stale steer. The shortfall is re-prefilled, never an error.
    pub stale_steer_requests: u64,
    /// Total over-claimed tokens of those placements (the measured
    /// re-prefill cost of mirror staleness).
    pub stale_steer_tokens: u64,
    /// Gossip messages delivered (delta batches + digests).
    pub gossip_messages: u64,
    /// `PrefixDelta`s that rode those messages.
    pub gossip_deltas: u64,
    /// Load-digest publications.
    pub digest_publishes: u64,
    /// Rescue adoptions refused by the live `can_fit_fresh`
    /// re-validation after a stale digest claimed the sibling fit.
    pub rescue_refusals: u64,
    /// Elastic scale-up events (parked replica warmed + pre-seeded).
    pub scale_ups: u64,
    /// Elastic scale-down events (active replica sent draining).
    pub scale_downs: u64,
}

impl NetStats {
    /// Record one stale-steer shortfall; an exact (or conservative)
    /// credit is not staleness.
    pub fn note_stale_steer(&mut self, overclaimed_tokens: u64) {
        if overclaimed_tokens == 0 {
            return;
        }
        self.stale_steer_requests += 1;
        self.stale_steer_tokens += overclaimed_tokens;
    }

    /// JSON value form (embedded in the fleet report).
    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json;
        json::obj(vec![
            ("stale_steer_requests",
             json::num(self.stale_steer_requests as f64)),
            ("stale_steer_tokens",
             json::num(self.stale_steer_tokens as f64)),
            ("gossip_messages", json::num(self.gossip_messages as f64)),
            ("gossip_deltas", json::num(self.gossip_deltas as f64)),
            ("digest_publishes",
             json::num(self.digest_publishes as f64)),
            ("rescue_refusals", json::num(self.rescue_refusals as f64)),
            ("scale_ups", json::num(self.scale_ups as f64)),
            ("scale_downs", json::num(self.scale_downs as f64)),
        ])
    }
}

/// Per-request lifecycle record.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub id: RequestId,
    pub arrival: Micros,
    pub first_token: Option<Micros>,
    pub finished: Option<Micros>,
}

impl RequestRecord {
    pub fn latency(&self) -> Option<Micros> {
        self.finished.map(|f| f - self.arrival)
    }

    pub fn ttft(&self) -> Option<Micros> {
        self.first_token.map(|t| t - self.arrival)
    }
}

/// One sampled point of the Fig 2 timelines.
#[derive(Debug, Clone, Copy)]
pub struct TimelinePoint {
    pub at: Micros,
    /// KV cache physical occupancy in [0, 1].
    pub kv_occupancy: f64,
    /// Requests completed so far.
    pub completed: usize,
    /// Requests currently blocked on API calls.
    pub in_api: usize,
    /// Requests currently decoding.
    pub running: usize,
    /// KV tokens held by running requests.
    pub held_running: u64,
    /// KV tokens held by API-waiting (Preserve) requests.
    pub held_api: u64,
    /// KV tokens held by paused/waiting requests.
    pub held_waiting: u64,
}

/// Collects lifecycle events during a run and produces the final report.
#[derive(Debug, Default)]
pub struct MetricsCollector {
    records: Vec<RequestRecord>,
    index: std::collections::HashMap<RequestId, usize>,
    timeline: Vec<TimelinePoint>,
    /// Virtual/wall time the run ended.
    pub end_time: Micros,
    /// Total decode iterations executed.
    pub iterations: u64,
    /// Total tokens decoded.
    pub tokens_decoded: u64,
    /// Total prefill/recompute tokens actually materialized (prefix-cache
    /// hits are *not* counted — they skip materialization).
    pub tokens_prefilled: u64,
    /// Total tokens recomputed after Discard (wasted work accounting).
    pub tokens_recomputed: u64,
    /// Context tokens served from KV prefix-cache hits instead of being
    /// prefilled.
    pub prefix_hit_tokens: u64,
    /// Zero-ref cached blocks evicted from the prefix cache (retention
    /// capacity or memory pressure).
    pub prefix_evictions: u64,
    /// Zero-ref blocks currently retained in the prefix cache (gauge).
    pub prefix_cached_blocks: u64,
    /// Fresh physical KV blocks materialized (cache hits excluded).
    pub blocks_allocated: u64,
    /// Total preemptions (admitted requests evicted under memory pressure).
    pub preemptions: u64,
    /// Strategy usage counts (preserve, discard, swap).
    pub strategy_counts: [u64; 3],
    /// Engine time spent stalled on swap transfers.
    pub swap_stall_us: u64,
    /// Swap transfer time that ran as background transfers overlapping
    /// decode (async swap) instead of stalling the batch.
    pub swap_overlap_us: u64,
    /// Swap-in tokens restored from still-resident prefix-cache blocks
    /// instead of crossing PCIe (the transfer bytes the cache saved).
    pub swap_restore_cached_tokens: u64,
    /// Engine time spent on prefill/recompute materialization.
    pub materialize_us: u64,
    /// Admission rejections by cause (per request-round).
    pub rejected_slot: u64,
    pub rejected_memory: u64,
    pub rejected_reservation: u64,
    /// API calls with an observable predicted-vs-actual gap: every
    /// externally-resolved call (`--api-source external`), plus
    /// simulated returns whenever the configured predictor is not the
    /// exact oracle (whose gap is identically zero). Zero on
    /// oracle-predictor sim runs, which also keeps their report JSON
    /// free of the fields below.
    pub api_calls_completed: u64,
    /// Histogram of per-call relative duration error (see
    /// [`API_ERR_BUCKET_BOUNDS`]).
    pub api_pred_err_hist: [u64; API_ERR_BUCKETS],
    /// Sum of absolute predicted-vs-actual duration error, µs.
    pub api_pred_abs_err_us: u64,
    /// Estimator-state snapshot of the learned duration seam
    /// (`--api-pred learned`): per-class n/mean/p50/p90/blend, refreshed
    /// by the engine at each observed outcome. `None` — and absent from
    /// the JSON — in static mode, so the off-path report shape stays
    /// pinned.
    pub api_pred_model: Option<crate::util::json::Value>,
}

impl MetricsCollector {
    pub fn new() -> MetricsCollector {
        MetricsCollector::default()
    }

    pub fn on_arrival(&mut self, id: RequestId, at: Micros) {
        let idx = self.records.len();
        self.records.push(RequestRecord {
            id,
            arrival: at,
            first_token: None,
            finished: None,
        });
        self.index.insert(id, idx);
    }

    pub fn on_first_token(&mut self, id: RequestId, at: Micros) {
        if let Some(&idx) = self.index.get(&id) {
            let rec = &mut self.records[idx];
            if rec.first_token.is_none() {
                rec.first_token = Some(at);
            }
        }
    }

    pub fn on_finished(&mut self, id: RequestId, at: Micros) {
        if let Some(&idx) = self.index.get(&id) {
            self.records[idx].finished = Some(at);
        }
    }

    /// Remove `id`'s lifecycle record entirely — a request withdrawn
    /// before it ever ran, re-queued to a sibling replica (its new
    /// owner records the arrival instead, so fleet-wide counts stay a
    /// partition of the trace). O(1) swap-remove: record order is not
    /// load-bearing — every consumer either counts records or sorts
    /// the extracted samples ([`Summary::from_samples`]) — so only the
    /// displaced record's index needs re-pointing.
    pub fn forget(&mut self, id: RequestId) {
        let Some(idx) = self.index.remove(&id) else {
            return;
        };
        self.records.swap_remove(idx);
        if idx < self.records.len() {
            let moved = self.records[idx].id;
            self.index.insert(moved, idx);
        }
    }

    /// Record one externally-resolved API call's predicted-vs-actual
    /// duration outcome (the §3 gap LAMPS's strategy choice rides on,
    /// finally measured end to end).
    pub fn record_api_outcome(&mut self, predicted: Micros,
                              actual: Micros) {
        self.api_calls_completed += 1;
        let err = actual.0.abs_diff(predicted.0);
        self.api_pred_abs_err_us += err;
        let rel = err as f64 / predicted.0.max(1) as f64;
        let bucket = API_ERR_BUCKET_BOUNDS
            .iter()
            .position(|&b| rel <= b)
            .unwrap_or(API_ERR_BUCKETS - 1);
        self.api_pred_err_hist[bucket] += 1;
    }

    pub fn sample_timeline(&mut self, point: TimelinePoint) {
        self.timeline.push(point);
    }

    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.finished.is_some()).count()
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    pub fn report(&self) -> RunReport {
        let latencies: Vec<Micros> =
            self.records.iter().filter_map(|r| r.latency()).collect();
        let ttfts: Vec<Micros> =
            self.records.iter().filter_map(|r| r.ttft()).collect();
        let completed = latencies.len();
        let span = self.end_time.as_secs_f64().max(1e-9);
        RunReport {
            submitted: self.records.len(),
            completed,
            latency: Summary::from_samples(&latencies),
            ttft: Summary::from_samples(&ttfts),
            throughput_rps: completed as f64 / span,
            duration: self.end_time,
            iterations: self.iterations,
            tokens_decoded: self.tokens_decoded,
            tokens_prefilled: self.tokens_prefilled,
            tokens_recomputed: self.tokens_recomputed,
            prefix_hit_tokens: self.prefix_hit_tokens,
            prefix_evictions: self.prefix_evictions,
            prefix_cached_blocks: self.prefix_cached_blocks,
            blocks_allocated: self.blocks_allocated,
            preemptions: self.preemptions,
            strategy_counts: self.strategy_counts,
            swap_stall_us: self.swap_stall_us,
            swap_overlap_us: self.swap_overlap_us,
            swap_restore_cached_tokens: self.swap_restore_cached_tokens,
            materialize_us: self.materialize_us,
            rejected_slot: self.rejected_slot,
            rejected_memory: self.rejected_memory,
            rejected_reservation: self.rejected_reservation,
            api_calls_completed: self.api_calls_completed,
            api_pred_err_hist: self.api_pred_err_hist,
            api_pred_abs_err_us: self.api_pred_abs_err_us,
            api_pred_model: self.api_pred_model.clone(),
            timeline: self.timeline.clone(),
        }
    }
}

/// Final report of one serving run — the unit every figure bench consumes.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub submitted: usize,
    pub completed: usize,
    pub latency: Summary,
    pub ttft: Summary,
    /// Completed requests per second of (virtual) run time.
    pub throughput_rps: f64,
    pub duration: Micros,
    pub iterations: u64,
    pub tokens_decoded: u64,
    /// Prefill/recompute tokens actually materialized.
    pub tokens_prefilled: u64,
    pub tokens_recomputed: u64,
    /// Context tokens served from KV prefix-cache hits.
    pub prefix_hit_tokens: u64,
    /// Prefix-cache evictions (capacity or memory pressure).
    pub prefix_evictions: u64,
    /// Zero-ref cached blocks retained at end of run.
    pub prefix_cached_blocks: u64,
    /// Fresh physical KV blocks materialized (cache hits excluded).
    pub blocks_allocated: u64,
    pub preemptions: u64,
    /// Strategy usage counts (preserve, discard, swap).
    pub strategy_counts: [u64; 3],
    /// Engine time stalled on swap transfers.
    pub swap_stall_us: u64,
    /// Swap transfer time overlapped with decode (async swap).
    pub swap_overlap_us: u64,
    /// Swap-in tokens served from resident prefix-cache blocks (PCIe
    /// transfer skipped).
    pub swap_restore_cached_tokens: u64,
    /// Engine time spent on prefill/recompute materialization.
    pub materialize_us: u64,
    /// Admission rejections by cause (per request-round).
    pub rejected_slot: u64,
    pub rejected_memory: u64,
    pub rejected_reservation: u64,
    /// API calls whose predicted-vs-actual gap was recorded (external
    /// calls, plus simulated ones under a non-oracle predictor); zero
    /// on oracle-predictor sim runs, which also omits the histogram
    /// fields from the JSON so that report shape stays byte-identical
    /// to the pre-seam one.
    pub api_calls_completed: u64,
    /// Per-call predicted-vs-actual relative-error histogram (see
    /// [`API_ERR_BUCKET_BOUNDS`]).
    pub api_pred_err_hist: [u64; API_ERR_BUCKETS],
    /// Sum of absolute predicted-vs-actual duration error, µs.
    pub api_pred_abs_err_us: u64,
    /// Learned duration-seam estimator state (`--api-pred learned`
    /// only; `None` in static mode keeps the JSON shape pinned).
    pub api_pred_model: Option<crate::util::json::Value>,
    pub timeline: Vec<TimelinePoint>,
}

impl RunReport {
    /// Fleet-wide aggregate of per-replica reports (the
    /// [`ReplicaSet`](crate::cluster::ReplicaSet) fan-in). Counters sum;
    /// the latency/TTFT summaries are rebuilt from the merged
    /// per-request samples (percentiles cannot be merged from
    /// summaries); the duration is the latest replica end time and
    /// throughput is fleet completions over that span.
    pub fn aggregate(per_replica: &[RunReport], latencies: &[Micros],
                     ttfts: &[Micros]) -> RunReport {
        let sum = |f: fn(&RunReport) -> u64| -> u64 {
            per_replica.iter().map(f).sum()
        };
        let duration = per_replica
            .iter()
            .map(|r| r.duration)
            .max()
            .unwrap_or(Micros::ZERO);
        let completed: usize =
            per_replica.iter().map(|r| r.completed).sum();
        let span = duration.as_secs_f64().max(1e-9);
        let mut strategy_counts = [0u64; 3];
        for r in per_replica {
            for (total, c) in
                strategy_counts.iter_mut().zip(r.strategy_counts)
            {
                *total += c;
            }
        }
        let mut api_pred_err_hist = [0u64; API_ERR_BUCKETS];
        for r in per_replica {
            for (total, c) in
                api_pred_err_hist.iter_mut().zip(r.api_pred_err_hist)
            {
                *total += c;
            }
        }
        // Timeline points carry per-replica gauges (kv_occupancy,
        // cumulative completed, running) that do not compose into one
        // fleet series — an interleaved merge would oscillate between
        // replicas' values and misrepresent fleet state. The fleet
        // aggregate therefore carries no timeline; the per-replica
        // reports keep theirs (FleetReport renders them).
        RunReport {
            submitted: per_replica.iter().map(|r| r.submitted).sum(),
            completed,
            latency: Summary::from_samples(latencies),
            ttft: Summary::from_samples(ttfts),
            throughput_rps: completed as f64 / span,
            duration,
            iterations: sum(|r| r.iterations),
            tokens_decoded: sum(|r| r.tokens_decoded),
            tokens_prefilled: sum(|r| r.tokens_prefilled),
            tokens_recomputed: sum(|r| r.tokens_recomputed),
            prefix_hit_tokens: sum(|r| r.prefix_hit_tokens),
            prefix_evictions: sum(|r| r.prefix_evictions),
            prefix_cached_blocks: sum(|r| r.prefix_cached_blocks),
            blocks_allocated: sum(|r| r.blocks_allocated),
            preemptions: sum(|r| r.preemptions),
            strategy_counts,
            swap_stall_us: sum(|r| r.swap_stall_us),
            swap_overlap_us: sum(|r| r.swap_overlap_us),
            swap_restore_cached_tokens:
                sum(|r| r.swap_restore_cached_tokens),
            materialize_us: sum(|r| r.materialize_us),
            rejected_slot: sum(|r| r.rejected_slot),
            rejected_memory: sum(|r| r.rejected_memory),
            rejected_reservation: sum(|r| r.rejected_reservation),
            api_calls_completed: sum(|r| r.api_calls_completed),
            api_pred_err_hist,
            api_pred_abs_err_us: sum(|r| r.api_pred_abs_err_us),
            // Per-replica estimators are independent state machines;
            // averaging them would misrepresent each replica's actual
            // scheduling inputs. The fleet aggregate carries none; the
            // per-replica reports keep theirs (FleetReport renders
            // them).
            api_pred_model: None,
            timeline: Vec::new(),
        }
    }

    /// JSON rendering (timeline omitted unless `with_timeline`).
    pub fn to_json(&self, with_timeline: bool) -> String {
        crate::util::json::write(&self.to_value(with_timeline))
    }

    /// JSON value form, composable into larger documents (the
    /// fleet-report JSON embeds one per replica).
    pub fn to_value(&self, with_timeline: bool)
                    -> crate::util::json::Value {
        use crate::util::json::{self, Value};
        let summary = |s: &Summary| {
            json::obj(vec![
                ("n", json::num(s.n as f64)),
                ("mean_us", json::num(s.mean_us)),
                ("p50_us", json::num(s.p50_us)),
                ("p99_us", json::num(s.p99_us)),
                ("max_us", json::num(s.max_us)),
            ])
        };
        let mut pairs = vec![
            ("submitted", json::num(self.submitted as f64)),
            ("completed", json::num(self.completed as f64)),
            ("latency", summary(&self.latency)),
            ("ttft", summary(&self.ttft)),
            ("throughput_rps", json::num(self.throughput_rps)),
            ("duration_us", json::num(self.duration.0 as f64)),
            ("iterations", json::num(self.iterations as f64)),
            ("tokens_decoded", json::num(self.tokens_decoded as f64)),
            ("tokens_prefilled",
             json::num(self.tokens_prefilled as f64)),
            ("tokens_recomputed",
             json::num(self.tokens_recomputed as f64)),
            ("prefix_hit_tokens",
             json::num(self.prefix_hit_tokens as f64)),
            ("prefix_evictions",
             json::num(self.prefix_evictions as f64)),
            ("prefix_cached_blocks",
             json::num(self.prefix_cached_blocks as f64)),
            ("blocks_allocated",
             json::num(self.blocks_allocated as f64)),
            ("preemptions", json::num(self.preemptions as f64)),
            ("preserve_count", json::num(self.strategy_counts[0] as f64)),
            ("discard_count", json::num(self.strategy_counts[1] as f64)),
            ("swap_count", json::num(self.strategy_counts[2] as f64)),
            ("swap_stall_us", json::num(self.swap_stall_us as f64)),
            ("swap_overlap_us", json::num(self.swap_overlap_us as f64)),
            ("swap_restore_cached_tokens",
             json::num(self.swap_restore_cached_tokens as f64)),
            ("materialize_us", json::num(self.materialize_us as f64)),
            ("rejected_slot", json::num(self.rejected_slot as f64)),
            ("rejected_memory", json::num(self.rejected_memory as f64)),
            ("rejected_reservation",
             json::num(self.rejected_reservation as f64)),
        ];
        if self.api_calls_completed > 0 {
            // External calls and non-oracle simulated returns populate
            // these; omitting them while zero keeps oracle-run report
            // JSON byte-identical to the pre-`--api-source` shape.
            pairs.push(("api_calls_completed",
                        json::num(self.api_calls_completed as f64)));
            pairs.push(("api_pred_abs_err_us",
                        json::num(self.api_pred_abs_err_us as f64)));
            pairs.push(("api_pred_err_hist", Value::Arr(
                self.api_pred_err_hist
                    .iter()
                    .map(|&c| json::num(c as f64))
                    .collect())));
        }
        if let Some(model) = &self.api_pred_model {
            // Learned-seam estimator state; absent in static mode.
            pairs.push(("api_pred_model", model.clone()));
        }
        if with_timeline {
            pairs.push(("timeline", Value::Arr(
                self.timeline
                    .iter()
                    .map(|p| json::obj(vec![
                        ("at_us", json::num(p.at.0 as f64)),
                        ("kv_occupancy", json::num(p.kv_occupancy)),
                        ("completed", json::num(p.completed as f64)),
                        ("in_api", json::num(p.in_api as f64)),
                        ("running", json::num(p.running as f64)),
                        ("held_running", json::num(p.held_running as f64)),
                        ("held_api", json::num(p.held_api as f64)),
                        ("held_waiting",
                         json::num(p.held_waiting as f64)),
                    ]))
                    .collect())));
        }
        json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles() {
        let samples: Vec<Micros> = (1..=100).map(Micros).collect();
        let s = Summary::from_samples(&samples);
        assert_eq!(s.n, 100);
        assert_eq!(s.p50_us, 50.0);
        assert_eq!(s.p99_us, 99.0);
        assert_eq!(s.max_us, 100.0);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::from_samples(&[]).n, 0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::from_samples(&[Micros(42)]);
        assert_eq!(s.p50_us, 42.0);
        assert_eq!(s.p99_us, 42.0);
    }

    #[test]
    fn lifecycle_to_report() {
        let mut m = MetricsCollector::new();
        m.on_arrival(RequestId(1), Micros(0));
        m.on_arrival(RequestId(2), Micros(100));
        m.on_first_token(RequestId(1), Micros(50));
        m.on_first_token(RequestId(1), Micros(70)); // second call ignored
        m.on_finished(RequestId(1), Micros(200));
        m.end_time = Micros(1_000_000);
        let rep = m.report();
        assert_eq!(rep.submitted, 2);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.latency.mean_us, 200.0);
        assert_eq!(rep.ttft.mean_us, 50.0);
        assert!((rep.throughput_rps - 1.0).abs() < 1e-9);
    }

    #[test]
    fn aggregate_sums_counters_and_rebuilds_summaries() {
        let mk = |end: u64, lat: u64| {
            let mut m = MetricsCollector::new();
            m.on_arrival(RequestId(1), Micros(0));
            m.on_finished(RequestId(1), Micros(lat));
            m.end_time = Micros(end);
            m.tokens_decoded = 10;
            m.preemptions = 2;
            m.strategy_counts = [1, 2, 3];
            m.report()
        };
        let a = mk(1_000_000, 100);
        let b = mk(3_000_000, 300);
        let fleet = RunReport::aggregate(&[a, b],
                                         &[Micros(100), Micros(300)],
                                         &[]);
        assert_eq!(fleet.submitted, 2);
        assert_eq!(fleet.completed, 2);
        assert_eq!(fleet.duration, Micros(3_000_000), "latest end");
        assert_eq!(fleet.tokens_decoded, 20);
        assert_eq!(fleet.preemptions, 4);
        assert_eq!(fleet.strategy_counts, [2, 4, 6]);
        assert_eq!(fleet.latency.mean_us, 200.0);
        assert_eq!(fleet.latency.max_us, 300.0);
        assert_eq!(fleet.ttft.n, 0);
        // Fleet throughput: 2 completions over the 3 s fleet span.
        assert!((fleet.throughput_rps - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn forget_removes_record_and_keeps_index_consistent() {
        let mut m = MetricsCollector::new();
        m.on_arrival(RequestId(1), Micros(0));
        m.on_arrival(RequestId(2), Micros(10));
        m.on_arrival(RequestId(3), Micros(20));
        m.forget(RequestId(2));
        m.forget(RequestId(9)); // absent: no-op
        assert_eq!(m.records().len(), 2);
        m.on_finished(RequestId(3), Micros(120));
        let rep = m.report();
        assert_eq!(rep.submitted, 2);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.latency.mean_us, 100.0,
                   "record 3 must still resolve after the removal");
    }

    #[test]
    fn shared_prefix_stats_note_and_json() {
        let mut s = SharedPrefixStats::new(3);
        s.note(1, 0); // zero credit is not steering
        s.note(1, 32);
        s.note(2, 16);
        s.note(1, 16);
        assert_eq!(s.steered_requests, 3);
        assert_eq!(s.steered_tokens, 64);
        assert_eq!(s.per_replica_steered_tokens, vec![0, 48, 16]);
        let v = crate::util::json::parse(
            &crate::util::json::write(&s.to_value())).unwrap();
        assert_eq!(v.u64_field("steered_tokens").unwrap(), 64);
        assert_eq!(v.field("per_replica_steered_tokens").unwrap()
                       .as_arr().unwrap().len(), 3);
        // A rescue re-books a steering claim: unnote reverses one note.
        s.unnote(1, 32);
        assert_eq!(s.steered_requests, 2);
        assert_eq!(s.steered_tokens, 32);
        assert_eq!(s.per_replica_steered_tokens, vec![0, 16, 16]);
        s.unnote(2, 0); // zero credit was never a claim
        assert_eq!(s.steered_requests, 2);
    }

    #[test]
    fn api_outcome_histogram_buckets_and_json_gating() {
        let mut m = MetricsCollector::new();
        m.on_arrival(RequestId(1), Micros(0));
        m.end_time = Micros(1);
        // No external calls: the histogram keys must be absent so the
        // simulated report shape is untouched.
        let v = crate::util::json::parse(&m.report().to_json(false))
            .unwrap();
        assert!(v.get("api_calls_completed").is_none());
        assert!(v.get("api_pred_err_hist").is_none());
        assert!(v.get("api_pred_abs_err_us").is_none());

        m.record_api_outcome(Micros(1_000_000), Micros(1_050_000)); // 5%
        m.record_api_outcome(Micros(1_000_000), Micros(800_000)); // 20%
        m.record_api_outcome(Micros(1_000_000), Micros(1_400_000)); // 40%
        m.record_api_outcome(Micros(1_000_000), Micros(2_000_000)); // 100%
        m.record_api_outcome(Micros(1_000_000), Micros(2_500_000)); // 150%
        m.record_api_outcome(Micros(1_000_000), Micros(9_000_000)); // 800%
        m.record_api_outcome(Micros(0), Micros(0)); // degenerate: 0%
        assert_eq!(m.api_calls_completed, 7);
        assert_eq!(m.api_pred_err_hist, [2, 1, 1, 1, 1, 1]);
        assert_eq!(m.api_pred_abs_err_us,
                   50_000 + 200_000 + 400_000 + 1_000_000 + 1_500_000
                       + 8_000_000);
        let v = crate::util::json::parse(&m.report().to_json(false))
            .unwrap();
        assert_eq!(v.u64_field("api_calls_completed").unwrap(), 7);
        assert_eq!(v.field("api_pred_err_hist").unwrap()
                       .as_arr().unwrap().len(), API_ERR_BUCKETS);

        // Aggregation sums the buckets.
        let a = m.report();
        let fleet = RunReport::aggregate(&[a.clone(), a], &[], &[]);
        assert_eq!(fleet.api_calls_completed, 14);
        assert_eq!(fleet.api_pred_err_hist, [4, 2, 2, 2, 2, 2]);
    }

    #[test]
    fn ttft_only_counts_first() {
        let mut m = MetricsCollector::new();
        m.on_arrival(RequestId(1), Micros(10));
        m.on_first_token(RequestId(1), Micros(30));
        m.on_first_token(RequestId(1), Micros(90));
        assert_eq!(m.records()[0].ttft(), Some(Micros(20)));
    }
}
