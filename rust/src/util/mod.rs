//! Small self-contained utilities: a deterministic RNG (the whole
//! simulator must replay bit-identically from a seed) and the FNV-1a word
//! tokenizer shared with the Python compile path.

pub mod json;
pub mod rng;
pub mod tokenizer;

pub use rng::Rng;
