//! Deterministic xorshift* RNG with the distribution samplers the workload
//! generators need (uniform, truncated normal, exponential, categorical).
//!
//! A hand-rolled generator (instead of the `rand` crate) keeps trace
//! generation bit-stable across crate upgrades — benchmark figures must be
//! regenerable exactly.

/// xorshift64* — fast, passes BigCrush for this use, trivially portable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // Avoid the all-zero fixed point; mix the seed a little.
        Rng {
            state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal(mean, std) clamped below at `min` (Table 2 durations and
    /// call counts are reported as (mean, std) and are non-negative).
    /// Clamping (not rejection-resampling) keeps the mean closest to the
    /// published value for heavily-truncated classes like ToolBench
    /// (1.72 +/- 3.33 s).
    pub fn truncated_normal(&mut self, mean: f64, std: f64, min: f64) -> f64 {
        (mean + std * self.normal()).max(min)
    }

    /// Exponential with the given rate (Poisson inter-arrival gaps).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-12).ln() / rate
    }

    /// Index drawn from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Uniform choice from a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.next_u64() % items.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn truncated_normal_respects_floor() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.truncated_normal(0.1, 5.0, 0.0) >= 0.0);
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mean =
            (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn categorical_distribution() {
        let mut r = Rng::new(17);
        let mut counts = [0u32; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&[1.0, 2.0, 1.0])] += 1;
        }
        assert!(counts[1] > counts[0] && counts[1] > counts[2]);
        assert!(counts.iter().all(|&c| c > 1500));
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(19);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let x = r.int_range(2, 4);
            assert!((2..=4).contains(&x));
            seen_lo |= x == 2;
            seen_hi |= x == 4;
        }
        assert!(seen_lo && seen_hi);
    }
}
