//! Minimal JSON parser/writer (the offline vendor set has no serde_json).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! f64 (adequate for this crate's traces/metadata — token counts and
//! microsecond stamps stay well under 2^53).
//!
//! # Owned vs borrowed
//!
//! This module is the *owned* side of the crate's JSON split: `parse`
//! allocates a full [`Value`] tree (every string copied, every object a
//! `BTreeMap`) and `write` renders one — convenient for traces,
//! reports, artifacts, and anything cold. The serving hot path must
//! not pay for that: [`crate::wire`] lexes frames as borrowed slices
//! (`Cow` strings that only allocate on escapes) and encodes events
//! into a reusable buffer, while reproducing this writer's byte format
//! exactly (alphabetical keys, the same number and escape rules). The
//! `wire-hot-path` lint keeps `server/` code on that side of the
//! split.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Field access that errors with the path (parser-side ergonomics).
    pub fn field(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON field '{key}'"))
    }

    pub fn str_field(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .field(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a string"))?
            .to_string())
    }

    pub fn u64_field(&self, key: &str) -> anyhow::Result<u64> {
        self.field(key)?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a number"))
    }

    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.field(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' not a number"))
    }
}

pub fn parse(text: &str) -> anyhow::Result<Value> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        anyhow::bail!("trailing characters at byte {pos}");
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len()
        && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r')
    {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> anyhow::Result<()> {
    if *pos < b.len() && b[*pos] == ch {
        *pos += 1;
        Ok(())
    } else {
        anyhow::bail!("expected '{}' at byte {}", ch as char, pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Value> {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Value::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Value::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Value::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Value::Null),
        Some(_) => parse_num(b, pos),
        None => anyhow::bail!("unexpected end of input"),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Value)
             -> anyhow::Result<Value> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        anyhow::bail!("bad literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Value> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos],
                    b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Value::Num(s.parse::<f64>().map_err(|e| {
        anyhow::anyhow!("bad number '{s}' at byte {start}: {e}")
    })?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => anyhow::bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(
                            b.get(*pos + 1..*pos + 5)
                                .ok_or_else(|| {
                                    anyhow::anyhow!("bad \\u escape")
                                })?)?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => anyhow::bail!("bad escape at byte {pos}"),
                }
                *pos += 1;
            }
            Some(_) => {
                // Copy a UTF-8 run verbatim.
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*pos])?);
            }
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> anyhow::Result<Value> {
    expect(b, pos, b'{')?;
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Value::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Value::Obj(map));
            }
            _ => anyhow::bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> anyhow::Result<Value> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Value::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Value::Arr(items));
            }
            _ => anyhow::bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

/// Serialize a value (stable key order: Obj is a BTreeMap).
pub fn write(value: &Value) -> String {
    let mut out = String::new();
    write_value(value, &mut out);
    out
}

fn write_value(value: &Value, out: &mut String) {
    match value {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, v)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(text: &str) -> Value {
    Value::Str(text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true},
                       "e": null}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(),
                   Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(),
                   Some("x\ny"));
        assert_eq!(v.get("e"), Some(&Value::Null));
        // write -> parse -> same
        let back = parse(&write(&v)).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(write(&Value::Num(42.0)), "42");
        assert_eq!(write(&Value::Num(2.5)), "2.5");
    }

    #[test]
    fn string_escapes() {
        let v = Value::Str("a\"b\\c\nd".to_string());
        let text = write(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("123 xyz").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn field_helpers() {
        let v = parse(r#"{"n": 7, "s": "hi"}"#).unwrap();
        assert_eq!(v.u64_field("n").unwrap(), 7);
        assert_eq!(v.str_field("s").unwrap(), "hi");
        assert!(v.u64_field("missing").is_err());
        assert!(v.u64_field("s").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
        assert_eq!(write(&Value::Arr(vec![])), "[]");
    }
}
