//! Synthetic ToolBench-style dataset (DESIGN.md §2 substitution).
//!
//! ToolBench [Qin et al. 2023] is an instruction-tuning corpus of 16k+
//! real-world APIs in 49 categories with single- and multi-API scenarios;
//! the paper uses it as the prediction-required dataset (prompts + API
//! call types only, no recorded output lengths). This generator mirrors
//! `python/compile/corpus.py` — the corpus the exported predictor was
//! trained on — so PJRT predictions at serving time are in-distribution,
//! and samples API durations/call counts from Table 2's ToolBench row.

use crate::core::request::{ApiCallSpec, ApiType, RequestSpec};
use crate::core::types::{Micros, RequestId, Tokens};
use crate::predictor::api_stats::stats_for;
use crate::util::Rng;
use crate::workload::{ArrivalProcess, Trace};

/// Mirrored from python/compile/corpus.py — keep in sync.
pub const CATEGORIES: [(&str, f64); 8] = [
    ("weather", 20.0),
    ("finance", 60.0),
    ("translate", 35.0),
    ("search", 90.0),
    ("media", 140.0),
    ("sports", 50.0),
    ("travel", 110.0),
    ("code", 180.0),
];

pub const DETAILS: [(&str, f64); 7] = [
    ("brief", 0.0),
    ("short", 25.0),
    ("plain", 50.0),
    ("medium", 90.0),
    ("long", 150.0),
    ("verbose", 220.0),
    ("exhaustive", 300.0),
];

const FILLER: [&str; 19] = [
    "please", "fetch", "the", "current", "value", "for", "my", "account",
    "and", "report", "it", "back", "with", "any", "relevant", "context",
    "from", "service", "today",
];

pub const BIN_WIDTH: u64 = 10;
pub const NUM_BINS: u64 = 50;

/// One generated prompt + its true pre-API output length (the quantity the
/// predictor estimates).
#[derive(Debug, Clone)]
pub struct ToolbenchSample {
    pub prompt: String,
    pub category: usize,
    pub length: u64,
}

impl ToolbenchSample {
    pub fn bin(&self) -> u64 {
        (self.length / BIN_WIDTH).min(NUM_BINS - 1)
    }
}

/// Same length model as `corpus.gen_sample`: category/detail base + noise,
/// plus a quantized size-hint word whose error grows with length.
pub fn gen_sample(rng: &mut Rng) -> ToolbenchSample {
    let cat_idx = (rng.next_u64() % CATEGORIES.len() as u64) as usize;
    let (cat, base) = CATEGORIES[cat_idx];
    let (det, extra) = *rng.choice(&DETAILS);
    let mean = base + extra;
    let noise = rng.normal() * (2.0 + 0.06 * mean);
    let length = ((mean + noise) as i64)
        .clamp(1, (NUM_BINS * BIN_WIDTH - 1) as i64) as u64;
    let hint_noise = rng.normal() * (1.0 + 0.02 * length as f64);
    let hint = (((length as f64 + hint_noise) / 8.0) as i64).max(0) as u64;
    let n_fill = rng.int_range(3, 10);
    let fill: Vec<&str> =
        (0..n_fill).map(|_| *rng.choice(&FILLER)).collect();
    let prompt = format!("call the {cat} api with a {det} answer scale \
                          n{hint} {}",
                         fill.join(" "));
    ToolbenchSample {
        prompt,
        category: cat_idx,
        length,
    }
}

/// Full dataset: single- and multi-API tool-use requests with prompts the
/// exported predictor can score.
pub fn dataset(n: usize, rate: f64, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0x7001_BE4C);
    let arrivals = ArrivalProcess::Poisson { rate }.sample(n, &mut rng);
    let tool_stats = stats_for(ApiType::Tool(0));
    let requests = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, arrival)| {
            let sample = gen_sample(&mut rng);
            let n_calls = rng
                .truncated_normal(tool_stats.calls_per_request.0,
                                  tool_stats.calls_per_request.1, 1.0)
                .round() as usize;
            // The sampled length is the *first* pre-API segment (what the
            // prompt predicts); later segments are shorter continuations.
            let api_calls: Vec<ApiCallSpec> = (0..n_calls)
                .map(|k| {
                    let decode = if k == 0 {
                        sample.length
                    } else {
                        rng.truncated_normal(sample.length as f64 * 0.4,
                                             sample.length as f64 * 0.2,
                                             1.0)
                            .round() as u64
                    };
                    let duration = rng.truncated_normal(
                        tool_stats.duration_secs.0,
                        tool_stats.duration_secs.1,
                        1e-3);
                    let response = rng.truncated_normal(
                        tool_stats.response_tokens.0,
                        tool_stats.response_tokens.1,
                        0.0);
                    ApiCallSpec {
                        decode_before: Tokens(decode),
                        api_type: ApiType::Tool(sample.category as u8),
                        duration: Micros::from_secs_f64(duration),
                        response_tokens: Tokens(response.round() as u64),
                    }
                })
                .collect();
            let prompt_tokens =
                crate::util::tokenizer::valid_len(&sample.prompt, 64) as u64;
            RequestSpec {
                id: RequestId(i as u64),
                arrival,
                prompt: sample.prompt,
                prompt_tokens: Tokens(prompt_tokens),
                api_calls,
                final_decode: Tokens(
                    rng.truncated_normal(sample.length as f64 * 0.5,
                                         sample.length as f64 * 0.25, 1.0)
                        .round() as u64),
            }
        })
        .collect();
    Trace::new("toolbench", rate, requests)
}

/// Evaluation split for Table 3: (prompt, true-length) pairs only.
pub fn eval_samples(n: usize, seed: u64) -> Vec<ToolbenchSample> {
    let mut rng = Rng::new(seed ^ 0x7001_E7A1_u64);
    (0..n).map(|_| gen_sample(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shape() {
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let s = gen_sample(&mut rng);
            assert!((1..NUM_BINS * BIN_WIDTH).contains(&s.length));
            assert!(s.bin() < NUM_BINS);
            assert!(s.prompt.starts_with("call the "));
            assert!(s.prompt.contains(" api with a "));
            assert!(s.prompt.contains(" scale n"));
        }
    }

    #[test]
    fn category_correlates_with_length() {
        let mut rng = Rng::new(6);
        let mut by_cat = vec![Vec::new(); CATEGORIES.len()];
        for _ in 0..4000 {
            let s = gen_sample(&mut rng);
            by_cat[s.category].push(s.length as f64);
        }
        let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        // code (base 180) >> weather (base 20)
        assert!(avg(&by_cat[7]) > avg(&by_cat[0]) + 80.0);
    }

    #[test]
    fn dataset_durations_match_table2_toolbench_row() {
        let t = dataset(3000, 3.0, 5);
        let stats = t.api_class_stats();
        let (label, s) = &stats[0];
        assert_eq!(label, "tool");
        // A clamped normal with std 3.33 >> mean 1.72 is biased upward
        // (E ~ 2.36); the published std itself comes from a skewed
        // empirical distribution a normal cannot match. Allow the band.
        assert!((s.duration_mean - 1.72).abs() < 1.0,
                "duration mean {}", s.duration_mean);
        assert!((s.calls_mean - 2.45).abs() < 0.6,
                "calls mean {}", s.calls_mean);
    }

    #[test]
    fn prompts_tokenize_within_window() {
        let t = dataset(100, 3.0, 8);
        for r in &t.requests {
            assert!(!r.prompt.is_empty());
            assert!(r.prompt_tokens.0 <= 64);
            let ids = crate::util::tokenizer::encode(&r.prompt, 64);
            assert_eq!(ids.len(), 64);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = dataset(30, 2.0, 11);
        let b = dataset(30, 2.0, 11);
        assert_eq!(a.requests, b.requests);
    }
}
