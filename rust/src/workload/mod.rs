//! Workload generation: synthetic equivalents of the paper's two datasets
//! (INFERCEPT-style and ToolBench-style, DESIGN.md §2), Poisson arrivals,
//! and JSON trace (de)serialization.

pub mod infercept;
pub mod toolbench;

use crate::core::request::{ApiType, RequestSpec};
use crate::core::types::Micros;
use crate::util::Rng;

/// A complete workload: requests sorted by arrival time.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    /// Request rate (req/s) the arrivals were drawn at, for reporting.
    pub rate: f64,
    pub requests: Vec<RequestSpec>,
}

impl Trace {
    pub fn new(name: &str, rate: f64,
               mut requests: Vec<RequestSpec>) -> Trace {
        requests.sort_by_key(|r| (r.arrival, r.id));
        Trace {
            name: name.to_string(),
            rate,
            requests,
        }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn save_json(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, trace_json::to_json(self))?;
        Ok(())
    }

    pub fn load_json(path: &str) -> anyhow::Result<Trace> {
        trace_json::from_json(&std::fs::read_to_string(path)?)
    }

    /// Per-class (duration mean/std, calls-per-request mean/std) — the
    /// Table 2 self-check used by `--bench table2_datasets`.
    pub fn api_class_stats(&self) -> Vec<(String, ClassSummary)> {
        use std::collections::BTreeMap;
        let mut durations: BTreeMap<&'static str, Vec<f64>> =
            BTreeMap::new();
        let mut counts: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
        for req in &self.requests {
            let mut per_req: BTreeMap<&'static str, f64> = BTreeMap::new();
            for call in &req.api_calls {
                durations
                    .entry(call.api_type.label())
                    .or_default()
                    .push(call.duration.as_secs_f64());
                *per_req.entry(call.api_type.label()).or_default() += 1.0;
            }
            for (label, n) in per_req {
                counts.entry(label).or_default().push(n);
            }
        }
        durations
            .into_iter()
            .map(|(label, durs)| {
                let cnts = counts.get(label).cloned().unwrap_or_default();
                (label.to_string(), ClassSummary {
                    duration_mean: mean(&durs),
                    duration_std: std_dev(&durs),
                    calls_mean: mean(&cnts),
                    calls_std: std_dev(&cnts),
                    n_calls: durs.len(),
                })
            })
            .collect()
    }
}

/// Summary row for Table 2 comparison.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassSummary {
    pub duration_mean: f64,
    pub duration_std: f64,
    pub calls_mean: f64,
    pub calls_std: f64,
    pub n_calls: usize,
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Arrival-time generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson with the given rate in requests/second.
    Poisson { rate: f64 },
    /// All at t=0 (the Fig 3 worked example).
    Simultaneous,
    /// Inhomogeneous Poisson on a raised-cosine day curve: the
    /// instantaneous rate is
    /// `base + (peak - base) · ½(1 − cos(2πt/period))` — trough
    /// `base_rate` at t = 0, crest `peak_rate` half a period in. The
    /// elastic-fleet autoscaler (`--autoscale`) is exercised against
    /// this curve: warm-ups ride the climb, drains ride the descent.
    Diurnal {
        base_rate: f64,
        peak_rate: f64,
        period_secs: f64,
    },
}

impl ArrivalProcess {
    /// Draw `n` arrival times (sorted).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<Micros> {
        match self {
            ArrivalProcess::Poisson { rate } => {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exponential(*rate);
                        Micros::from_secs_f64(t)
                    })
                    .collect()
            }
            ArrivalProcess::Simultaneous => vec![Micros::ZERO; n],
            ArrivalProcess::Diurnal {
                base_rate,
                peak_rate,
                period_secs,
            } => {
                // Lewis–Shedler thinning: draw candidate gaps at the
                // envelope rate, accept each candidate with probability
                // λ(t)/envelope. Exact for any bounded λ and keeps the
                // stream strictly increasing.
                let base = base_rate.max(0.0);
                let peak = peak_rate.max(base);
                let period = period_secs.max(f64::EPSILON);
                if peak <= 0.0 {
                    return vec![Micros::ZERO; n];
                }
                let lambda = |t: f64| {
                    let phase =
                        (2.0 * std::f64::consts::PI * t) / period;
                    base + (peak - base) * 0.5 * (1.0 - phase.cos())
                };
                let mut t = 0.0;
                (0..n)
                    .map(|_| loop {
                        t += rng.exponential(peak);
                        if rng.f64() * peak <= lambda(t) {
                            break Micros::from_secs_f64(t);
                        }
                    })
                    .collect()
            }
        }
    }
}

/// Re-draw a trace's arrival times from `process`, leaving request
/// bodies untouched (requests keep their ids; `Trace::new` re-sorts by
/// the fresh times). The elastic-fleet bench re-times a flat
/// INFERCEPT-style dataset onto a diurnal day curve this way.
pub fn retime(trace: &Trace, process: ArrivalProcess, seed: u64)
              -> Trace {
    let mut rng = Rng::new(seed);
    let arrivals = process.sample(trace.len(), &mut rng);
    let requests = trace
        .requests
        .iter()
        .zip(arrivals)
        .map(|(req, arrival)| RequestSpec {
            arrival,
            ..req.clone()
        })
        .collect();
    Trace::new(&trace.name, trace.rate, requests)
}

/// Manual JSON mapping for traces (no serde in the offline vendor set).
pub mod trace_json {
    use super::Trace;
    use crate::core::request::{ApiCallSpec, ApiType, RequestSpec};
    use crate::core::types::{Micros, RequestId, Tokens};
    use crate::util::json::{self, Value};

    fn api_type_to_str(t: ApiType) -> String {
        match t {
            ApiType::Tool(cat) => format!("tool:{cat}"),
            other => other.label().to_string(),
        }
    }

    fn api_type_from_str(s: &str) -> anyhow::Result<ApiType> {
        Ok(match s {
            "math" => ApiType::Math,
            "qa" => ApiType::Qa,
            "ve" => ApiType::Ve,
            "chatbot" => ApiType::Chatbot,
            "image" => ApiType::Image,
            "tts" => ApiType::Tts,
            other => {
                let cat = other
                    .strip_prefix("tool:")
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown api type '{other}'")
                    })?
                    .parse::<u8>()?;
                ApiType::Tool(cat)
            }
        })
    }

    fn call_to_value(c: &ApiCallSpec) -> Value {
        json::obj(vec![
            ("decode_before", json::num(c.decode_before.0 as f64)),
            ("api_type", json::s(&api_type_to_str(c.api_type))),
            ("duration_us", json::num(c.duration.0 as f64)),
            ("response_tokens", json::num(c.response_tokens.0 as f64)),
        ])
    }

    fn call_from_value(v: &Value) -> anyhow::Result<ApiCallSpec> {
        Ok(ApiCallSpec {
            decode_before: Tokens(v.u64_field("decode_before")?),
            api_type: api_type_from_str(&v.str_field("api_type")?)?,
            duration: Micros(v.u64_field("duration_us")?),
            response_tokens: Tokens(v.u64_field("response_tokens")?),
        })
    }

    fn spec_to_value(r: &RequestSpec) -> Value {
        json::obj(vec![
            ("id", json::num(r.id.0 as f64)),
            ("arrival_us", json::num(r.arrival.0 as f64)),
            ("prompt", json::s(&r.prompt)),
            ("prompt_tokens", json::num(r.prompt_tokens.0 as f64)),
            ("api_calls",
             Value::Arr(r.api_calls.iter().map(call_to_value).collect())),
            ("final_decode", json::num(r.final_decode.0 as f64)),
        ])
    }

    fn spec_from_value(v: &Value) -> anyhow::Result<RequestSpec> {
        let calls = v
            .field("api_calls")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("api_calls not an array"))?
            .iter()
            .map(call_from_value)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(RequestSpec {
            id: RequestId(v.u64_field("id")?),
            arrival: Micros(v.u64_field("arrival_us")?),
            prompt: v.str_field("prompt")?,
            prompt_tokens: Tokens(v.u64_field("prompt_tokens")?),
            api_calls: calls,
            final_decode: Tokens(v.u64_field("final_decode")?),
        })
    }

    pub fn to_json(trace: &Trace) -> String {
        let value = json::obj(vec![
            ("name", json::s(&trace.name)),
            ("rate", json::num(trace.rate)),
            ("requests",
             Value::Arr(trace.requests.iter().map(spec_to_value).collect())),
        ]);
        json::write(&value)
    }

    pub fn from_json(text: &str) -> anyhow::Result<Trace> {
        let v = json::parse(text)?;
        let requests = v
            .field("requests")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("requests not an array"))?
            .iter()
            .map(spec_from_value)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Trace {
            name: v.str_field("name")?,
            rate: v.f64_field("rate")?,
            requests,
        })
    }
}

/// Convenience: all API types present in a trace.
pub fn api_types_in(trace: &Trace) -> Vec<ApiType> {
    let mut types: Vec<ApiType> = trace
        .requests
        .iter()
        .flat_map(|r| r.api_calls.iter().map(|c| c.api_type))
        .collect();
    types.sort_by_key(|t| t.label());
    types.dedup();
    types
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approx() {
        let mut rng = Rng::new(1);
        let arrivals =
            ArrivalProcess::Poisson { rate: 5.0 }.sample(5000, &mut rng);
        assert!(arrivals.windows(2).all(|w| w[0] <= w[1]));
        let span = arrivals.last().unwrap().as_secs_f64();
        let measured_rate = 5000.0 / span;
        assert!((measured_rate - 5.0).abs() < 0.3, "rate {measured_rate}");
    }

    #[test]
    fn simultaneous_all_zero() {
        let mut rng = Rng::new(1);
        let arrivals = ArrivalProcess::Simultaneous.sample(3, &mut rng);
        assert_eq!(arrivals, vec![Micros::ZERO; 3]);
    }

    #[test]
    fn diurnal_peaks_mid_period_and_stays_sorted() {
        let mut rng = Rng::new(7);
        let period = 100.0;
        let arrivals = ArrivalProcess::Diurnal {
            base_rate: 1.0,
            peak_rate: 20.0,
            period_secs: period,
        }
        .sample(4000, &mut rng);
        assert!(arrivals.windows(2).all(|w| w[0] <= w[1]));
        // Crest (phase 0.45–0.55) must be far denser than the trough
        // (phase within 0.05 of 0) — same-width windows, λ ratio 20:1.
        let phase_count = |lo: f64, hi: f64| {
            arrivals
                .iter()
                .filter(|a| {
                    let p = (a.as_secs_f64() % period) / period;
                    p >= lo && p < hi
                })
                .count()
        };
        let crest = phase_count(0.45, 0.55);
        let trough = phase_count(0.0, 0.05) + phase_count(0.95, 1.0);
        assert!(crest > 3 * trough.max(1),
                "crest {crest} vs trough {trough}");
    }

    #[test]
    fn diurnal_flat_curve_matches_poisson_rate() {
        let mut rng = Rng::new(3);
        let arrivals = ArrivalProcess::Diurnal {
            base_rate: 5.0,
            peak_rate: 5.0,
            period_secs: 60.0,
        }
        .sample(5000, &mut rng);
        let span = arrivals.last().unwrap().as_secs_f64();
        let measured = 5000.0 / span;
        assert!((measured - 5.0).abs() < 0.3,
                "flat diurnal degenerates to Poisson, got {measured}");
    }

    #[test]
    fn retime_keeps_bodies_and_resorts() {
        let t = infercept::single_api_dataset(20, 2.0, 7);
        let d = retime(&t, ArrivalProcess::Diurnal {
            base_rate: 0.5,
            peak_rate: 8.0,
            period_secs: 30.0,
        }, 11);
        assert_eq!(d.len(), t.len());
        assert!(d.requests.windows(2)
                 .all(|w| (w[0].arrival, w[0].id)
                      <= (w[1].arrival, w[1].id)));
        let mut orig: Vec<_> = t.requests.iter()
            .map(|r| (r.id, r.prompt_tokens, r.api_calls.clone()))
            .collect();
        let mut back: Vec<_> = d.requests.iter()
            .map(|r| (r.id, r.prompt_tokens, r.api_calls.clone()))
            .collect();
        orig.sort_by_key(|(id, ..)| *id);
        back.sort_by_key(|(id, ..)| *id);
        assert_eq!(orig, back, "retime must not touch request bodies");
    }

    #[test]
    fn stats_helpers() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }

    #[test]
    fn trace_sorts_by_arrival() {
        use crate::core::types::RequestId;
        let mk = |id: u64, at: u64| RequestSpec {
            id: RequestId(id),
            arrival: Micros(at),
            prompt: String::new(),
            prompt_tokens: crate::core::types::Tokens(1),
            api_calls: vec![],
            final_decode: crate::core::types::Tokens(1),
        };
        let t = Trace::new("t", 1.0, vec![mk(1, 50), mk(2, 10)]);
        assert_eq!(t.requests[0].id, RequestId(2));
    }

    #[test]
    fn trace_json_roundtrip() {
        let t = infercept::single_api_dataset(10, 2.0, 7);
        let dir = std::env::temp_dir().join("lamps_trace_test.json");
        let path = dir.to_str().unwrap();
        t.save_json(path).unwrap();
        let back = Trace::load_json(path).unwrap();
        assert_eq!(t.requests, back.requests);
        std::fs::remove_file(path).ok();
    }
}
